#!/usr/bin/env python
"""Docs link checker: every relative link in README.md + docs/*.md resolves.

``python scripts/check_links.py [root]``

Checks, for each markdown file:

* relative link targets (``[text](path)``) exist on disk, resolved against
  the file's own directory;
* fragment links into markdown files (``path.md#anchor`` and in-page
  ``#anchor``) match a real heading, using GitHub's anchor slug rules
  (lowercase, punctuation stripped, spaces → hyphens);
* absolute URLs are left alone (this is a repo-consistency check, not a
  web crawler).

Exit code 0 when every link resolves; 1 with a per-link report otherwise.
Stdlib only, so CI can run it without installing anything.
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target ends at the first unescaped ')'; images share the
# syntax (preceded by '!'), which is fine: their paths must resolve too
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
_CODE_FENCE = re.compile(r"^(```|~~~)")


def anchor_slug(heading: str) -> str:
    """GitHub-style anchor for a heading (approximation of gfm rules)."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading)          # inline code
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)  # links
    heading = heading.lower()
    heading = re.sub(r"[^\w\s-]", "", heading)
    return re.sub(r"\s", "-", heading)


def heading_anchors(path: pathlib.Path) -> set:
    anchors, seen = set(), {}
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if not m:
            continue
        slug = anchor_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def iter_links(path: pathlib.Path):
    in_fence = False
    for ln, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            yield ln, m.group(1)


def check_file(path: pathlib.Path) -> list:
    errors = []
    for ln, target in iter_links(path):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, …
            continue
        target, _, frag = target.partition("#")
        dest = path if not target else (path.parent / target).resolve()
        if not dest.exists():
            errors.append(f"{path}:{ln}: broken link -> {target}")
            continue
        if frag and dest.suffix == ".md":
            if frag not in heading_anchors(dest):
                errors.append(
                    f"{path}:{ln}: missing anchor -> {target or dest.name}"
                    f"#{frag}")
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    files = [root / "README.md"] + sorted((root / "docs").glob("*.md"))
    files = [f for f in files if f.exists()]
    if not any(f.name != "README.md" for f in files):
        print("FAIL: no docs/*.md found — the docs set is part of the "
              "acceptance criteria", file=sys.stderr)
        return 1
    errors = []
    n_links = 0
    for f in files:
        links = list(iter_links(f))
        n_links += len(links)
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files, {n_links} links: "
          f"{'FAIL (%d broken)' % len(errors) if errors else 'all resolve'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
