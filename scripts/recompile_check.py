#!/usr/bin/env python
"""Runtime recompile smoke: a fixed call sequence must trace exactly once.

``python scripts/recompile_check.py``

jaxlint's JL3 family proves recompile hygiene *statically* (frozen-dataclass
statics, no jit-under-loop); this script proves it *dynamically* for the hot
entry point.  It wraps :func:`repro.core.bfis.search_topm_batch` in a jit
whose trace count is observable (a Python side effect inside the wrapped
function fires once per trace, never per call) and asserts:

* repeated calls with the same shapes and the same config hit the cache
  (1 trace, however many calls);
* an equal-but-newly-constructed ``SearchConfig`` static also hits the
  cache — the frozen dataclass hashes by value, which is exactly the
  property JL302 defends;
* a new batch shape retraces exactly once more (shape-keyed, not
  call-keyed).

Exit code 0 when the trace counts match, 1 with a report otherwise.
"""
from __future__ import annotations

import sys
from functools import partial
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import jax                                            # noqa: E402
import numpy as np                                    # noqa: E402

from repro.core.bfis import search_topm_batch         # noqa: E402
from repro.core.config import SearchConfig            # noqa: E402
from repro.core.graph import make_padded_csr          # noqa: E402

N, D, DEG, K = 64, 8, 6, 4


def tiny_graph(seed: int = 0):
    rng = np.random.RandomState(seed)
    vectors = rng.randn(N, D).astype(np.float32)
    nbrs = np.stack([rng.choice(N, size=DEG, replace=False)
                     for _ in range(N)])
    return make_padded_csr(nbrs, vectors)


def make_cfg() -> SearchConfig:
    return SearchConfig(k=K, queue_len=16, m_max=2, max_steps=16,
                        dist_backend="ref")


def main() -> int:
    graph = tiny_graph()
    rng = np.random.RandomState(1)
    traces = []

    # the graph is closed over, not passed through jit: PaddedCSR is a
    # NamedTuple whose static n_top field would be traced as a leaf (the
    # serving engines hold the graph the same way)
    @partial(jax.jit, static_argnames=("cfg",))
    def run(queries, cfg: SearchConfig):
        traces.append(len(traces))   # fires once per trace, not per call
        return search_topm_batch(graph, queries, cfg)

    failures = []

    def expect(n_traces: int, label: str) -> None:
        status = "ok" if len(traces) == n_traces else "FAIL"
        print(f"{status}: {label} -> {len(traces)} trace(s), "
              f"expected {n_traces}")
        if len(traces) != n_traces:
            failures.append(label)

    cfg = make_cfg()
    q8 = rng.randn(8, D).astype(np.float32)

    ids, dists, stats = run(q8, cfg)
    ids.block_until_ready()
    expect(1, "first (8, d) batch traces once")

    run(rng.randn(8, D).astype(np.float32), cfg)
    expect(1, "same shapes, new values: cache hit")

    run(q8, make_cfg())
    expect(1, "equal-but-new SearchConfig static: cache hit "
              "(frozen dataclass hashes by value)")

    run(rng.randn(3, D).astype(np.float32), cfg)
    expect(2, "new batch shape retraces exactly once")

    run(rng.randn(3, D).astype(np.float32), make_cfg())
    expect(2, "second (3, d) call: cache hit")

    assert ids.shape == (8, K) and dists.shape == (8, K)
    if failures:
        print(f"recompile check FAILED: {failures}")
        return 1
    print("recompile check passed: 2 traces across 5 calls")
    return 0


if __name__ == "__main__":
    sys.exit(main())
