#!/usr/bin/env python
"""Chrome-trace JSON validator for the serving stack's trace exports.

``python scripts/check_trace.py trace.json [--require NAME ...]``

Validates the file a ``--trace-out`` run writes (``examples/serve_ann.py``,
``benchmarks/serve_load.py``, or any ``TraceRecorder.write``):

* **Schema** — top level is ``{"traceEvents": [...]}``; every event has
  ``name``/``ph``/``pid``/``tid`` and a numeric ``ts`` (except pure
  metadata), with ``ph`` one of the phases the recorder emits
  (``X i b n e M``); ``X`` events carry a non-negative numeric ``dur``;
  async events (``b``/``n``/``e``) carry an ``id``.
* **Nesting** — per ``tid``, ``X`` (complete) spans form a proper stack:
  any two either nest by containment or are disjoint.  Partial overlap is
  exactly the malformed-trace shape Perfetto renders as garbage, and would
  mean the recorder's span context managers interleaved incorrectly.
* **Async pairing** — every ``(cat, id)`` lifeline opened with ``b`` is
  closed by an ``e`` (and vice versa), with begin <= end timestamps.
* **--require NAME** (repeatable) — at least one event with that name
  exists; the CI smoke requires the span names the serving stack promises
  (``batch_formation``, ``dispatch``, ``device_compute``...).

Exit code 0 when the trace is well-formed (a per-check summary is
printed); 1 with a report otherwise.  Stdlib only, so CI can run it
without installing anything.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

_PHASES = {"X", "i", "b", "n", "e", "M"}
# a float tolerance for containment checks: perf_counter microsecond
# arithmetic can put a child's end a hair past its parent's
_EPS_US = 0.5


def _check_event_schema(i: int, ev: object, errors: List[str]) -> bool:
    if not isinstance(ev, dict):
        errors.append(f"event[{i}]: not an object: {ev!r}")
        return False
    ok = True
    for key in ("name", "ph", "pid", "tid"):
        if key not in ev:
            errors.append(f"event[{i}] ({ev.get('name', '?')}): "
                          f"missing {key!r}")
            ok = False
    ph = ev.get("ph")
    if ph not in _PHASES:
        errors.append(f"event[{i}] ({ev.get('name', '?')}): "
                      f"unknown phase {ph!r}")
        return False
    if ph != "M":
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"event[{i}] ({ev.get('name', '?')}): "
                          f"non-numeric ts {ev.get('ts')!r}")
            ok = False
    if ph == "X":
        dur = ev.get("dur")
        if not isinstance(dur, (int, float)) or dur < 0:
            errors.append(f"event[{i}] ({ev.get('name', '?')}): X event "
                          f"needs numeric dur >= 0, got {dur!r}")
            ok = False
    if ph in ("b", "n", "e") and "id" not in ev:
        errors.append(f"event[{i}] ({ev.get('name', '?')}): async {ph!r} "
                      f"event missing id")
        ok = False
    return ok


def _check_nesting(events: List[dict], errors: List[str]) -> int:
    """Per-(pid, tid) stack check over X spans; returns spans checked."""
    by_tid: Dict[Tuple, List[dict]] = {}
    for ev in events:
        if ev.get("ph") == "X" and isinstance(ev.get("ts"), (int, float)):
            by_tid.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
    n = 0
    for tid, spans in sorted(by_tid.items(), key=lambda kv: str(kv[0])):
        # sort by start asc, then duration desc so a parent precedes the
        # children that start at the same timestamp
        spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[dict] = []
        for ev in spans:
            n += 1
            start, end = ev["ts"], ev["ts"] + ev["dur"]
            while stack and start >= stack[-1]["ts"] + stack[-1]["dur"] - _EPS_US:
                stack.pop()
            if stack:
                p_end = stack[-1]["ts"] + stack[-1]["dur"]
                if end > p_end + _EPS_US:
                    errors.append(
                        f"tid {tid}: span {ev['name']!r} "
                        f"[{start:.1f}, {end:.1f}] partially overlaps "
                        f"enclosing {stack[-1]['name']!r} "
                        f"[{stack[-1]['ts']:.1f}, {p_end:.1f}]")
            stack.append(ev)
    return n


def _check_async_pairing(events: List[dict], errors: List[str]) -> int:
    """Every (cat, id) lifeline: b ... e, begin before end."""
    begins: Dict[Tuple, dict] = {}
    ends: Dict[Tuple, dict] = {}
    n = 0
    for ev in events:
        ph = ev.get("ph")
        if ph not in ("b", "e") or "id" not in ev:
            continue
        n += 1
        key = (ev.get("cat"), ev["id"])
        side = begins if ph == "b" else ends
        if key in side:
            errors.append(f"async {('begin' if ph == 'b' else 'end')} "
                          f"duplicated for (cat, id)={key}")
        side[key] = ev
    for key, ev in sorted(begins.items(), key=str):
        if key not in ends:
            errors.append(f"async begin without end: (cat, id)={key} "
                          f"({ev.get('name', '?')!r})")
        elif ends[key]["ts"] < ev["ts"] - _EPS_US:
            errors.append(f"async end before begin: (cat, id)={key}")
    for key in sorted(ends, key=str):
        if key not in begins:
            errors.append(f"async end without begin: (cat, id)={key}")
    return n


def validate(trace: object, require: List[str] = ()) -> List[str]:
    """All findings for one parsed trace object (empty list = valid)."""
    errors: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a 'traceEvents' array "
                "(the Chrome-trace JSON object format)"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be an array"]
    well_formed = [ev for i, ev in enumerate(events)
                   if _check_event_schema(i, ev, errors)]
    _check_nesting(well_formed, errors)
    _check_async_pairing(well_formed, errors)
    names = {ev.get("name") for ev in well_formed}
    for name in require:
        if name not in names:
            errors.append(f"required event name {name!r} not present "
                          f"(have: {', '.join(sorted(filter(None, names)))})")
    return errors


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        description="validate a Chrome-trace JSON file (see docstring)")
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME",
                    help="require at least one event with this name "
                         "(repeatable)")
    args = ap.parse_args(argv)

    path = pathlib.Path(args.trace)
    try:
        trace = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_trace: cannot read {path}: {e}")
        return 1

    errors = validate(trace, args.require)
    if errors:
        for e in errors:
            print(f"check_trace: {e}")
        print(f"check_trace: FAIL ({len(errors)} finding(s) in {path})")
        return 1
    events = trace["traceEvents"]
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    n_async = sum(1 for e in events if e.get("ph") in ("b", "n", "e"))
    print(f"check_trace: OK — {len(events)} events "
          f"({n_spans} spans, {n_async} async) in {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
