"""Configuration system for the repro framework.

Plain frozen dataclasses so configs are hashable (usable as jit static args),
serializable, and diffable.  Every assigned architecture has a module in
``repro.configs`` that returns a :class:`ModelConfig`; search / train / serve
behaviour is configured with the companion dataclasses here.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Families understood by the model registry.
FAMILY_DENSE = "dense"          # llama-style decoder-only GQA transformer
FAMILY_MOE = "moe"              # dense + mixture-of-experts FFN
FAMILY_ENCDEC = "encdec"        # whisper-style encoder-decoder
FAMILY_VLM = "vlm"              # decoder backbone w/ M-RoPE + patch frontend stub
FAMILY_SSM = "ssm"              # mamba2 (SSD) attention-free
FAMILY_HYBRID = "hybrid"        # zamba2: mamba2 trunk + shared attention blocks

ALL_FAMILIES = (
    FAMILY_DENSE, FAMILY_MOE, FAMILY_ENCDEC, FAMILY_VLM, FAMILY_SSM,
    FAMILY_HYBRID,
)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""
    num_experts: int = 0
    top_k: int = 0
    # capacity factor for expert buffers (tokens per expert =
    # cf * tokens * top_k / num_experts), standard for dropping/padding.
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    state_dim: int = 128          # N, per-head SSM state size
    head_dim: int = 64            # P, channels per SSM head
    expand: int = 2               # d_inner = expand * d_model
    chunk: int = 256              # SSD chunk length
    conv_width: int = 4           # depthwise causal conv width
    ngroups: int = 1              # B/C groups (GVA-style)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture configuration (exact values from the assignment table)."""
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # --- attention details ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False        # qwen2 uses bias on QKV
    mrope: bool = False           # qwen2-vl multimodal rope (3 sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    sliding_window: int = 0       # 0 = full attention
    # --- norm / act ---
    norm_eps: float = 1e-5
    act: str = "silu"             # silu (swiglu) | gelu (whisper)
    # --- families ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k mamba layers
    hybrid_attn_every: int = 6
    # encoder-decoder (whisper): encoder config mirrors decoder dims
    encoder_layers: int = 0
    encoder_ctx: int = 1500       # whisper: 30s audio -> 1500 frames
    # vlm / audio frontends are STUBS: input_specs provides embeddings directly
    frontend_stub: bool = False
    frontend_dim: int = 0         # embedding dim delivered by the stub
    max_seq_len: int = 131072
    tie_embeddings: bool = False
    # scan-over-layers for compile-time/HLO-size control (heterogeneous
    # families override how the scan is blocked)
    scan_layers: bool = True
    # dtypes
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"  # parameter storage dtype

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_subquadratic(self) -> bool:
        """True when 500k-token contexts are tractable (SSM/hybrid/windowed)."""
        return self.family in (FAMILY_SSM, FAMILY_HYBRID) or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and memory)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d
        out = 0 if self.tie_embeddings else self.vocab_size * d
        per_layer = 0
        if self.family in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
            attn = d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d
            if self.moe:
                ffn = self.moe.num_experts * 3 * d * self.d_ff + d * self.moe.num_experts
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn + 2 * d
            total = emb + out + per_layer * self.num_layers + d
        elif self.family == FAMILY_ENCDEC:
            attn = d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d
            ffn = 2 * d * self.d_ff  # whisper uses gelu MLP (fc1, fc2)
            dec_layer = 2 * attn + ffn + 3 * d   # self + cross attn
            enc_layer = attn + ffn + 2 * d
            total = (emb + out + dec_layer * self.num_layers
                     + enc_layer * self.encoder_layers + 2 * d)
        elif self.family == FAMILY_SSM:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            proj_in = d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
            conv = s.conv_width * (d_in + 2 * s.ngroups * s.state_dim)
            per_layer = proj_in + conv + d_in * d + nheads * 2 + d_in + d
            total = emb + out + per_layer * self.num_layers + d
        elif self.family == FAMILY_HYBRID:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            proj_in = d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
            conv = s.conv_width * (d_in + 2 * s.ngroups * s.state_dim)
            mamba_layer = proj_in + conv + d_in * d + nheads * 2 + d_in + d
            attn = (2 * d) * (n_q * h) + 2 * (2 * d) * (n_kv * h) + (n_q * h) * d
            shared_attn = attn + 3 * (2 * d) * self.d_ff + 2 * (2 * d)
            n_attn_applications = self.num_layers // (self.hybrid_attn_every + 1)
            n_mamba = self.num_layers - n_attn_applications
            # zamba2 shares ONE attention block's weights across applications
            total = emb + out + mamba_layer * n_mamba + shared_attn + d
        else:
            raise ValueError(f"unknown family {self.family}")
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) parameters — differs from total only for MoE."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_ffn_total = self.num_layers * self.moe.num_experts * 3 * d * self.d_ff
        active_ffn = self.num_layers * self.moe.top_k * 3 * d * self.d_ff
        return self.param_count() - dense_ffn_total + active_ffn


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape sets)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Speed-ANN search configuration — MOVED to repro.core.config
# ---------------------------------------------------------------------------

# Deprecated import location: SearchConfig now lives with the traversal
# algorithms it parameterizes (``repro.core.config``).  This re-export keeps
# every existing ``from repro.config import SearchConfig`` site working;
# new code should import from ``repro.core.config`` (or, better, use the
# ``repro.ann`` facade's SearchParams).
from repro.core.config import SearchConfig  # noqa: E402,F401


# ---------------------------------------------------------------------------
# Mesh / training configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # ZeRO-1: optimizer-state sharding dtype ("float32" | "bfloat16");
    # >=100B configs use bf16 moments to fit a 256x16GB pod.
    moment_dtype: str = "float32"
    optimizer: str = "adamw"      # "adamw" | "adafactor"
    microbatches: int = 1         # gradient accumulation steps
    remat: str = "full"           # "none" | "full" | "selective"
    grad_compression: str = "none"  # "none" | "int8"
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


def to_json(cfg: Any) -> str:
    def default(o):
        if dataclasses.is_dataclass(o):
            return dataclasses.asdict(o)
        raise TypeError(type(o))
    return json.dumps(cfg, default=default, indent=2)
