"""Architecture registry: the 10 assigned configs + the paper's own datasets.

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` resolve by id;
``--arch <id>`` flags on the launchers go through here.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCH_IDS: List[str] = [
    "whisper-large-v3",
    "yi-9b",
    "qwen2.5-3b",
    "llama3.2-3b",
    "mistral-large-123b",
    "qwen3-moe-30b-a3b",
    "grok-1-314b",
    "qwen2-vl-7b",
    "mamba2-2.7b",
    "zamba2-7b",
]

_MODULES: Dict[str, str] = {
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "yi-9b": "repro.configs.yi_9b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "zamba2-7b": "repro.configs.zamba2_7b",
}


def get_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).smoke_config()
