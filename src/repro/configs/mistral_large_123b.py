"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407
(unverified tier).

88L, d_model=12288, 96 heads (GQA kv=8, head_dim=128), d_ff=28672,
vocab=32768.
"""
from repro.config import FAMILY_DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family=FAMILY_DENSE,
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=32768,
        rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke", family=FAMILY_DENSE,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128)
