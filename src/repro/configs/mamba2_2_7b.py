"""mamba2-2.7b [ssm] — SSD (state-space duality), arXiv:2405.21060
(unverified tier).

64L, d_model=2560 (attention-free), vocab=50280, ssm_state=128, head_dim=64,
expand=2 (d_inner=5120, 80 SSM heads).
"""
from repro.config import FAMILY_SSM, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family=FAMILY_SSM,
        num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family=FAMILY_SSM,
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=128,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=8))
