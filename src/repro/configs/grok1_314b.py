"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified tier).

64L, d_model=6144, 48 heads (GQA kv=8), expert d_ff=32768, vocab=131072,
8 experts top-2.
"""
from repro.config import FAMILY_MOE, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family=FAMILY_MOE,
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=32768, vocab_size=131072,
        moe=MoEConfig(num_experts=8, top_k=2))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok1-smoke", family=FAMILY_MOE,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0))
