"""zamba2-7b [hybrid] — Mamba2 + shared attention blocks, arXiv:2411.15242
(unverified tier).

81 layers (70 mamba2 + 11 shared-attn applications at every 7th position),
d_model=3584, 32 heads (MHA kv=32) in the shared block, d_ff=14336,
vocab=32000, ssm_state=64.
"""
from repro.config import FAMILY_HYBRID, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family=FAMILY_HYBRID,
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        head_dim=224, d_ff=14336, vocab_size=32000, hybrid_attn_every=6,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family=FAMILY_HYBRID,
        num_layers=7, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=128, vocab_size=128, hybrid_attn_every=2,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=8))
