"""yi-9b [dense] — llama-arch GQA, arXiv:2403.04652 (hf tier).

48L, d_model=4096, 32 heads (GQA kv=4), d_ff=11008, vocab=64000.
"""
from repro.config import FAMILY_DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", family=FAMILY_DENSE,
        num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000, rope_theta=5_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke", family=FAMILY_DENSE,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128)
