"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B (hf tier).

48L, d_model=2048, 32 heads (GQA kv=4, head_dim=128), expert d_ff=768,
vocab=151936, 128 experts top-8.
"""
from repro.config import FAMILY_MOE, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family=FAMILY_MOE,
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151936,
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=128, top_k=8))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family=FAMILY_MOE,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=128,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0))
