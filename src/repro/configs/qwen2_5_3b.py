"""qwen2.5-3b [dense] — GQA with QKV bias, hf:Qwen/Qwen2.5 family (hf tier).

36L, d_model=2048, 16 heads (GQA kv=2), d_ff=11008, vocab=151936, QKV bias,
tied embeddings.
"""
from repro.config import FAMILY_DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family=FAMILY_DENSE,
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        d_ff=11008, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke", family=FAMILY_DENSE,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128, qkv_bias=True, tie_embeddings=True)
