"""qwen2-vl-7b [vlm] — M-RoPE backbone, arXiv:2409.12191 (hf tier).

28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab=152064.  The
vision patch frontend is a STUB: input_specs provides M-RoPE position ids
(3, B, S); patch embeddings arrive as inputs_embeds when multimodal.
"""
from repro.config import FAMILY_VLM, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family=FAMILY_VLM,
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        d_ff=18944, vocab_size=152064, qkv_bias=True, mrope=True,
        mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
        frontend_stub=True, frontend_dim=3584)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family=FAMILY_VLM,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128, qkv_bias=True, mrope=True,
        mrope_sections=(4, 2, 2), frontend_stub=True, frontend_dim=64)
