"""whisper-large-v3 [audio/enc-dec] — arXiv:2212.04356 (unverified tier).

32 decoder + 32 encoder layers, d_model=1280, 20 heads (MHA), d_ff=5120,
vocab=51866.  Conv/mel frontend is a STUB: input_specs provides precomputed
(B, 1500, d_model) frame embeddings.  Whisper uses GELU MLPs, LayerNorm,
learned decoder positions, tied output embedding.
"""
from repro.config import FAMILY_ENCDEC, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family=FAMILY_ENCDEC,
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866, encoder_layers=32, encoder_ctx=1500,
        act="gelu", frontend_stub=True, frontend_dim=1280,
        tie_embeddings=True, max_seq_len=33024, scan_layers=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family=FAMILY_ENCDEC,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128, encoder_layers=2, encoder_ctx=16,
        act="gelu", frontend_stub=True, frontend_dim=64,
        tie_embeddings=True, max_seq_len=64)
