"""The assigned (architecture × input-shape) cell matrix — 40 cells.

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a seq_len
KV cache / SSM state); ``train_4k`` lowers ``train_step``; ``prefill_32k``
lowers the prefill.  ``long_500k`` requires sub-quadratic attention: it RUNS
for ssm/hybrid (mamba2-2.7b, zamba2-7b) and is a documented SKIP for the
eight pure-full-attention architectures (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional

from repro.config import ALL_SHAPES, ShapeConfig
from repro.configs import ARCH_IDS, get_config


class Cell(NamedTuple):
    arch: str
    shape: ShapeConfig
    skip: Optional[str]        # None = runs; else the documented reason


def cell_matrix() -> List[Cell]:
    cells: List[Cell] = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            skip = None
            if shape.name == "long_500k" and not cfg.is_subquadratic:
                skip = ("pure full attention: 500k-token context is "
                        "quadratic in prefill and impractical to serve; "
                        "runs only for ssm/hybrid archs")
            cells.append(Cell(arch, shape, skip))
    return cells


def runnable_cells() -> List[Cell]:
    return [c for c in cell_matrix() if c.skip is None]
