"""Gradient utilities: global-norm clipping and int8 compression with error
feedback (the distributed-optimization trick for cheap cross-pod gradient
all-reduce: 4× fewer ICI/DCN bytes; error feedback keeps convergence)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), n


def int8_compress(tree) -> Tuple:
    """Per-leaf symmetric int8 quantization. Returns (q_tree, scales)."""
    def one(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        return q, scale
    qs = jax.tree.map(one, tree)
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    scales = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    return q_tree, scales


def int8_decompress(q_tree, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales)


def compressed_psum(grads, axis_name: str, error=None):
    """int8-quantized all-reduce with error feedback.

    All shards agree on a COMMON per-leaf scale (one scalar pmax — cheap),
    quantize their (residual-corrected) grads against it, psum the int8
    payload (accumulated in int32 so sums cannot overflow), dequantize, and
    carry the local quantization residual to the next step.
    Returns (mean grads, new error tree).
    """
    n = jax.lax.psum(1, axis_name)
    if error is not None:
        grads = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, error)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    # common scale: without it, int8 payloads from different shards would be
    # in different units and their integer sum meaningless
    scales = jax.tree.map(
        lambda g: jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(g)), 1e-12), axis_name) / 127.0,
        grads)
    q = jax.tree.map(
        lambda g, s: jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8),
        grads, scales)
    summed = jax.tree.map(
        lambda t: jax.lax.psum(t.astype(jnp.int32), axis_name), q)
    mean = jax.tree.map(
        lambda si, sc: si.astype(jnp.float32) * sc / n, summed, scales)
    new_error = jax.tree.map(
        lambda g, qq, s: g - qq.astype(jnp.float32) * s, grads, q, scales)
    return mean, new_error
