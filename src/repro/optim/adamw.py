"""Optimizers: AdamW (dtype-configurable moments, ZeRO-1 friendly) and
Adafactor (factored second moments — lets 300B-class configs fit a pod).

Functional, pytree-based; optimizer state leaves inherit the parameter
sharding (GSPMD propagates it), which IS ZeRO-1 when params are FSDP-sharded.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.optim.schedule import warmup_cosine


def adamw_init(params, tcfg: TrainConfig) -> dict:
    mdt = jnp.dtype(tcfg.moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
    }


def adamw_update(grads, state: dict, params, tcfg: TrainConfig):
    step = state["step"] + 1
    lr = warmup_cosine(step, tcfg.learning_rate, tcfg.warmup_steps,
                       tcfg.total_steps)
    b1, b2, eps = tcfg.beta1, tcfg.beta2, tcfg.eps
    mdt = jnp.dtype(tcfg.moment_dtype)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / (1 - b1 ** step.astype(jnp.float32))
        vhat = vf / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + tcfg.weight_decay * p.astype(jnp.float32)
        return (-lr * delta).astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    updates = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    return updates, {"step": step, "m": m, "v": v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments)
# ---------------------------------------------------------------------------

def adafactor_init(params, tcfg: TrainConfig) -> dict:
    def rows(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2
                else jnp.zeros_like(p, dtype=jnp.float32))

    def cols(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if p.ndim >= 2 else jnp.zeros((1,), jnp.float32))

    return {
        "step": jnp.zeros((), jnp.int32),
        "vr": jax.tree.map(rows, params),
        "vc": jax.tree.map(cols, params),
    }


def adafactor_update(grads, state: dict, params, tcfg: TrainConfig):
    step = state["step"] + 1
    lr = warmup_cosine(step, tcfg.learning_rate, tcfg.warmup_steps,
                       tcfg.total_steps)
    b2 = 1.0 - (step.astype(jnp.float32) ** -0.8)
    eps = 1e-30

    def upd(g, vr, vc, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if p.ndim >= 2:
            nvr = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
            nvc = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
            r = nvr / jnp.maximum(
                jnp.mean(nvr, axis=-1, keepdims=True), eps)
            denom = jnp.sqrt(r[..., None] * nvc[..., None, :])
        else:
            nvr = b2 * vr + (1 - b2) * g2
            nvc = vc
            denom = jnp.sqrt(nvr)
        delta = gf / jnp.maximum(denom, 1e-12)
        # update clipping (Shazeer & Stern): RMS(delta) <= 1
        rms = jnp.sqrt(jnp.mean(delta * delta) + 1e-12)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + tcfg.weight_decay * p.astype(jnp.float32)
        return (-lr * delta).astype(p.dtype), nvr, nvc

    out = jax.tree.map(upd, grads, state["vr"], state["vc"], params)
    updates = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    vr = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    vc = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return updates, {"step": step, "vr": vr, "vc": vc}


def make_optimizer(tcfg: TrainConfig):
    if tcfg.optimizer == "adamw":
        return adamw_init, adamw_update
    if tcfg.optimizer == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(tcfg.optimizer)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
