from repro.optim.adamw import (adafactor_init, adafactor_update, adamw_init,  # noqa: F401
                               adamw_update, apply_updates, make_optimizer)
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.grad import (clip_by_global_norm, global_norm,  # noqa: F401
                              int8_compress, int8_decompress)
