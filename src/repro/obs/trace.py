"""Request-scoped tracing with Chrome-trace/Perfetto JSON export.

:class:`TraceRecorder` collects three kinds of events into a bounded
in-memory buffer:

* **Spans** — duration events on the calling thread, opened with the
  :meth:`TraceRecorder.span` context manager.  Nesting on one thread is
  expressed by containment (Chrome ``"X"`` complete events: ``ts`` +
  ``dur``), which is exactly how Perfetto reconstructs the stack.
* **Instant events** — point-in-time markers (``"i"``), either free-
  standing via :meth:`instant` or attached to an open span via
  :meth:`SpanHandle.event` (e.g. the coalescer's ``deadline_shed``).
* **Async events** — ``"b"``/``"n"``/``"e"`` pairs keyed by ``(cat, id)``
  for work that crosses threads, like one request's enqueue-on-client /
  dispatch-on-flusher lifetime.

Timestamps come from ``time.perf_counter()`` relative to the recorder's
construction, expressed in microseconds (the Chrome-trace unit).  Export
with :meth:`to_chrome_trace` / :meth:`write` and open the file in
`ui.perfetto.dev <https://ui.perfetto.dev>`__ or ``chrome://tracing``.

A disabled recorder (``TraceRecorder(enabled=False)``, or the shared
:data:`NULL_TRACER`) turns every call into a constant-time no-op — the
``span`` context manager returns a shared singleton and allocates
nothing — so instrumented hot paths pay nothing when tracing is off.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Dict, Iterator, Optional

__all__ = ["TraceRecorder", "SpanHandle", "NULL_TRACER"]


class SpanHandle:
    """Open span returned by :meth:`TraceRecorder.span`; lets the wrapped
    code attach args and instant events before the span closes."""

    __slots__ = ("_rec", "name", "cat", "_start_us", "_tid", "args")

    def __init__(self, rec: "TraceRecorder", name: str, cat: str,
                 start_us: float, tid: int, args: Optional[dict]):
        self._rec = rec
        self.name = name
        self.cat = cat
        self._start_us = start_us
        self._tid = tid
        self.args = dict(args) if args else {}

    def add_args(self, **kw) -> None:
        self.args.update(kw)

    def event(self, name: str, args: Optional[dict] = None) -> None:
        """Instant event stamped inside this span (same thread lane)."""
        self._rec._emit({
            "name": name, "ph": "i", "s": "t", "cat": self.cat,
            "ts": self._rec._now_us(), "pid": self._rec.pid,
            "tid": self._tid, "args": args or {},
        })

    def close(self) -> None:
        self._rec._emit({
            "name": self.name, "ph": "X", "cat": self.cat,
            "ts": self._start_us,
            "dur": self._rec._now_us() - self._start_us,
            "pid": self._rec.pid, "tid": self._tid, "args": self.args,
        })


class _NullSpan:
    """Shared no-op stand-in for :class:`SpanHandle` when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def add_args(self, **kw) -> None:
        pass

    def event(self, name: str, args: Optional[dict] = None) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context manager wrapping one live :class:`SpanHandle`."""

    __slots__ = ("_handle",)

    def __init__(self, handle: SpanHandle):
        self._handle = handle

    def __enter__(self) -> SpanHandle:
        return self._handle

    def __exit__(self, *exc) -> bool:
        self._handle.close()
        return False


class TraceRecorder:
    """Bounded, thread-safe trace-event buffer (see module docstring).

    ``max_events`` caps memory: the buffer is a ring, oldest events drop
    first (``dropped_events`` counts them).
    """

    def __init__(self, enabled: bool = True, max_events: int = 200_000):
        self.enabled = bool(enabled)
        self.pid = os.getpid()
        self._epoch = time.perf_counter()
        self._events: deque = deque(maxlen=max_events)
        self._n_emitted = 0
        self._lock = threading.Lock()
        self._thread_names: Dict[int, str] = {}

    # -- internals ---------------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)
            self._n_emitted += 1

    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)

    @property
    def dropped_events(self) -> int:
        with self._lock:
            return self._n_emitted - len(self._events)

    def name_thread(self, name: str, tid: Optional[int] = None) -> None:
        """Label the current (or given) thread's lane in the trace UI."""
        if not self.enabled:
            return
        self._thread_names[tid if tid is not None else
                           threading.get_ident()] = name

    # -- spans -------------------------------------------------------------

    def span(self, name: str, cat: str = "serve",
             args: Optional[dict] = None):
        """``with rec.span("dispatch") as sp: ...`` — duration event on the
        calling thread; nested calls nest by containment."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanCtx(SpanHandle(self, name, cat, self._now_us(),
                                   threading.get_ident(), args))

    def instant(self, name: str, cat: str = "serve",
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._emit({
            "name": name, "ph": "i", "s": "t", "cat": cat,
            "ts": self._now_us(), "pid": self.pid,
            "tid": threading.get_ident(), "args": args or {},
        })

    # -- async (cross-thread) events --------------------------------------

    def async_begin(self, name: str, id: int, cat: str = "request",
                    args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._emit({
            "name": name, "ph": "b", "cat": cat, "id": id,
            "ts": self._now_us(), "pid": self.pid,
            "tid": threading.get_ident(), "args": args or {},
        })

    def async_instant(self, name: str, id: int, cat: str = "request",
                      args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._emit({
            "name": name, "ph": "n", "cat": cat, "id": id,
            "ts": self._now_us(), "pid": self.pid,
            "tid": threading.get_ident(), "args": args or {},
        })

    def async_end(self, name: str, id: int, cat: str = "request",
                  args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._emit({
            "name": name, "ph": "e", "cat": cat, "id": id,
            "ts": self._now_us(), "pid": self.pid,
            "tid": threading.get_ident(), "args": args or {},
        })

    # -- export ------------------------------------------------------------

    def events(self) -> Iterator[dict]:
        with self._lock:
            return iter(list(self._events))

    def to_chrome_trace(self) -> dict:
        """Chrome-trace JSON object: ``{"traceEvents": [...], ...}``.
        Metadata (``"M"``) events name the process and any labelled
        threads so Perfetto lanes are readable."""
        with self._lock:
            events = list(self._events)
            names = dict(self._thread_names)
        meta = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": "repro.serve"},
        }]
        for tid, name in sorted(names.items()):
            meta.append({
                "name": "thread_name", "ph": "M", "pid": self.pid,
                "tid": tid, "args": {"name": name},
            })
        return {
            "traceEvents": meta + sorted(events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
        }

    def write(self, path: str, indent: Optional[int] = None) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=indent)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._n_emitted = 0


#: Shared disabled recorder — the default everywhere tracing is optional.
NULL_TRACER = TraceRecorder(enabled=False, max_events=1)
