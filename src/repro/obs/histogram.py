"""Streaming log-bucketed histogram: bounded memory, mergeable, accurate.

The serving stack needs latency *distributions* (p50/p95/p99), not totals —
but a Python list of floats grows without bound under sustained traffic and
cannot be merged across replicas.  :class:`LogHistogram` is the replacement:
a DDSketch-style sketch over geometrically-spaced buckets.

* **Relative-error guarantee.**  Bucket boundaries grow by a factor
  ``gamma = (1 + rel_err) / (1 - rel_err)``; a value ``v`` landing in bucket
  ``i`` is reported as the bucket's mid value ``2 * gamma**i / (gamma + 1)``,
  which is within ``rel_err`` of ``v``.  Quantile estimates therefore carry
  the same bound: ``|quantile(q) - exact| <= rel_err * exact`` (plus at most
  one rank of discreteness).  The default ``rel_err=0.01`` makes every
  reported percentile exact to within ±1%.
* **Bounded memory.**  Buckets are stored sparsely (index -> count) and
  capped at ``max_buckets``; on overflow the LOWEST buckets are collapsed
  into one (the standard DDSketch policy: tail percentiles — the ones that
  matter for latency — keep full resolution, only the far-low tail coarsens).
  At the default resolution 1024 buckets span more than eight decades, so
  collapse never triggers for realistic latency streams.
* **Mergeable.**  Two sketches with the same ``rel_err`` merge by adding
  bucket counts — exact, commutative, and associative (below the bucket
  cap), so per-replica histograms aggregate into fleet-wide percentiles
  without approximation beyond the per-sketch bound.

``count``/``sum``/``min``/``max`` (and therefore ``mean``) are tracked
exactly; only the quantiles are bucket-resolved.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, Optional

__all__ = ["LogHistogram"]

# values at or below this land in the dedicated zero bucket: they carry no
# meaningful relative precision and would need unbounded negative indices
_MIN_TRACKABLE = 1e-9


class LogHistogram:
    """Log-bucketed streaming histogram (see module docstring).

    Thread-safe: ``observe`` / ``merge`` / ``quantile`` take an internal
    lock (observation cost is one ``math.log`` + one dict update).
    """

    __slots__ = ("rel_err", "max_buckets", "_gamma", "_log_gamma", "_counts",
                 "count", "total", "zero_count", "_min", "_max", "_lock")

    def __init__(self, rel_err: float = 0.01, max_buckets: int = 1024):
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1), got {rel_err}")
        if max_buckets < 2:
            raise ValueError("max_buckets must be >= 2")
        self.rel_err = float(rel_err)
        self.max_buckets = int(max_buckets)
        self._gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._log_gamma = math.log(self._gamma)
        self._counts: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.zero_count = 0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------

    def _index(self, value: float) -> int:
        return math.ceil(math.log(value) / self._log_gamma)

    def _value(self, index: int) -> float:
        """Mid value of bucket ``index`` — within ``rel_err`` of every value
        the bucket holds."""
        return 2.0 * self._gamma ** index / (self._gamma + 1.0)

    def observe(self, value: float, n: int = 1) -> None:
        """Record ``value`` (``n`` times).  Non-finite values are dropped;
        values <= ~0 go to the exact zero bucket."""
        value = float(value)
        if not math.isfinite(value) or n <= 0:
            return
        with self._lock:
            self.count += n
            self.total += value * n
            self._min = value if self._min is None else min(self._min, value)
            self._max = value if self._max is None else max(self._max, value)
            if value <= _MIN_TRACKABLE:
                self.zero_count += n
            else:
                i = self._index(value)
                self._counts[i] = self._counts.get(i, 0) + n
                if len(self._counts) > self.max_buckets:
                    self._collapse_lowest()

    def observe_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.observe(v)

    def _collapse_lowest(self) -> None:
        """Fold the lowest bucket(s) into the next-lowest kept bucket —
        called under the lock when the sparse map exceeds ``max_buckets``."""
        keys = sorted(self._counts)
        spill = 0
        while len(keys) - (1 if spill else 0) >= self.max_buckets:
            spill += self._counts.pop(keys.pop(0))
        if spill:
            self._counts[keys[0]] = self._counts.get(keys[0], 0) + spill

    # -- merging -----------------------------------------------------------

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Fold ``other`` into this sketch (exact bucket-count addition;
        both sketches must share ``rel_err``).  Returns ``self``."""
        if abs(other.rel_err - self.rel_err) > 1e-12:
            raise ValueError(
                f"cannot merge histograms with different resolutions "
                f"({self.rel_err} vs {other.rel_err})")
        with other._lock:
            o_counts = dict(other._counts)
            o_count, o_total = other.count, other.total
            o_zero, o_min, o_max = other.zero_count, other._min, other._max
        with self._lock:
            for i, c in o_counts.items():
                self._counts[i] = self._counts.get(i, 0) + c
            self.count += o_count
            self.total += o_total
            self.zero_count += o_zero
            if o_min is not None:
                self._min = o_min if self._min is None \
                    else min(self._min, o_min)
            if o_max is not None:
                self._max = o_max if self._max is None \
                    else max(self._max, o_max)
            if len(self._counts) > self.max_buckets:
                self._collapse_lowest()
        return self

    # -- reading -----------------------------------------------------------

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def min(self) -> float:
        return self._min if self._min is not None else 0.0

    @property
    def max(self) -> float:
        return self._max if self._max is not None else 0.0

    def quantile(self, q: float) -> float:
        """The ``q`` in [0, 1] quantile, within ``rel_err`` relative error
        of the exact (nearest-rank) value.  Clamped to the exact observed
        [min, max] envelope, so ``quantile(0)``/``quantile(1)`` are exact."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        with self._lock:
            if not self.count:
                return 0.0
            if q == 0.0:
                return self._min if self._min is not None else 0.0
            if q == 1.0:
                return self._max if self._max is not None else 0.0
            rank = q * (self.count - 1)
            seen = self.zero_count
            if rank < seen:
                out = 0.0
            else:
                out = self._value(max(self._counts))   # fallback: top bucket
                for i in sorted(self._counts):
                    seen += self._counts[i]
                    if rank < seen:
                        out = self._value(i)
                        break
            lo = self._min if self._min is not None else out
            hi = self._max if self._max is not None else out
            return min(max(out, lo), hi)

    def percentile(self, p: float) -> float:
        """``quantile(p / 100)`` — the numpy-style spelling."""
        return self.quantile(p / 100.0)

    @property
    def n_buckets(self) -> int:
        """Distinct occupied buckets — bounded by ``max_buckets``."""
        return len(self._counts)

    def bucket_bounds(self):
        """Sorted ``(upper_bound, count)`` pairs of the occupied buckets
        (``gamma**i`` is bucket ``i``'s inclusive upper bound) — the
        Prometheus-exporter view.  The zero bucket is not included."""
        with self._lock:
            return [(self._gamma ** i, c)
                    for i, c in sorted(self._counts.items())]

    # -- (de)serialization -------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-safe full state — enough to reconstruct and merge on
        another host (bucket keys become strings; JSON objects only have
        string keys)."""
        with self._lock:
            return {
                "rel_err": self.rel_err,
                "max_buckets": self.max_buckets,
                "count": self.count,
                "sum": self.total,
                "zero_count": self.zero_count,
                "min": self._min,
                "max": self._max,
                "buckets": {str(i): c for i, c in
                            sorted(self._counts.items())},
            }

    @classmethod
    def from_dict(cls, d: dict) -> "LogHistogram":
        h = cls(rel_err=float(d["rel_err"]),
                max_buckets=int(d.get("max_buckets", 1024)))
        h.count = int(d["count"])
        h.total = float(d["sum"])
        h.zero_count = int(d.get("zero_count", 0))
        h._min = None if d.get("min") is None else float(d["min"])
        h._max = None if d.get("max") is None else float(d["max"])
        h._counts = {int(i): int(c) for i, c in d.get("buckets", {}).items()}
        return h

    def __repr__(self) -> str:
        return (f"LogHistogram(count={self.count}, mean={self.mean:.4g}, "
                f"p50={self.quantile(0.5):.4g}, p99={self.quantile(0.99):.4g}, "
                f"rel_err={self.rel_err})")
