"""Opt-in bridge from host spans to the JAX device profiler.

When a ``jax.profiler.trace()`` capture is running, host-side Python has
no natural representation on the device timeline.  ``TraceAnnotation``
fixes that: any code executed under one shows up as a named slice in the
profiler's host rows, letting you line up "the coalescer dispatched bucket
8 here" with the XLA ops it launched.

:func:`device_annotation` is the single entry point.  It is a no-op
(shared ``nullcontext``) unless explicitly enabled, so the serving hot
path never touches the profiler machinery by default:

    with device_annotation("ann_dispatch/bucket8", enabled=obs.profile):
        out = compiled(queries)

The import of ``jax.profiler`` is lazy and failure-tolerant — on a build
without profiler support the annotation degrades to the null context
instead of raising.
"""
from __future__ import annotations

from contextlib import nullcontext

__all__ = ["device_annotation", "have_profiler"]

_TraceAnnotation = None
_probed = False


def _resolve():
    global _TraceAnnotation, _probed
    if not _probed:
        _probed = True
        try:
            from jax.profiler import TraceAnnotation
            _TraceAnnotation = TraceAnnotation
        except Exception:
            _TraceAnnotation = None
    return _TraceAnnotation


def have_profiler() -> bool:
    """True if ``jax.profiler.TraceAnnotation`` is importable."""
    return _resolve() is not None


def device_annotation(name: str, enabled: bool = False):
    """Context manager: ``jax.profiler.TraceAnnotation(name)`` when
    ``enabled`` and the profiler is available, else a no-op."""
    if not enabled:
        return nullcontext()
    cls = _resolve()
    if cls is None:
        return nullcontext()
    return cls(name)
