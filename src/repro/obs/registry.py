"""Typed metrics registry: counters, gauges, histograms; JSON + Prometheus.

One :class:`MetricsRegistry` holds every metric a process emits.  Metrics
are keyed by ``(name, labels)`` where labels is a sorted tuple of
``(key, value)`` string pairs — the Prometheus data model.  Three types:

* :class:`Counter` — monotone float/int accumulator (``inc``).
* :class:`Gauge` — last-write-wins value (``set``, ``inc``/``dec``).
* :class:`Histogram` — a :class:`~repro.obs.histogram.LogHistogram` per
  label set (``observe``); quantiles carry the sketch's documented
  relative-error bound.

Registries are mergeable (:meth:`MetricsRegistry.merge`) and round-trip
through JSON (:meth:`to_json` / :meth:`from_json`), so per-replica
registries aggregate into fleet-wide views.  :meth:`to_prometheus` emits
the text exposition format (HELP/TYPE lines, label escaping, cumulative
``_bucket``/``_sum``/``_count`` series for histograms).
"""
from __future__ import annotations

import json
import threading
from typing import Dict, Iterable, Mapping, Optional, Tuple

from .histogram import LogHistogram

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

LabelPairs = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Mapping[str, str]]) -> LabelPairs:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label_value(v: str) -> str:
    """Prometheus text-format label-value escaping: backslash, double
    quote, and newline must be escaped (in that order)."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(pairs: LabelPairs, extra: Iterable[Tuple[str, str]] = ()) -> str:
    items = list(pairs) + list(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    # Prometheus wants plain decimal or scientific; repr of a float is fine,
    # but integral values read better without the trailing ".0"
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Metric:
    """Base: name, help text, per-label-set child values."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._children: Dict[LabelPairs, object] = {}
        self._lock = threading.Lock()

    def labels(self, **labels: str):
        """Child for a label set (created on first use)."""
        key = _label_key(labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._new_child()
                self._children[key] = child
            return child

    def _new_child(self):
        raise NotImplementedError

    def items(self):
        with self._lock:
            return list(self._children.items())


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return _CounterChild()

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        self.labels(**labels).inc(amount)


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return _GaugeChild()

    def set(self, value: float, **labels: str) -> None:
        self.labels(**labels).set(value)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "", rel_err: float = 0.01,
                 max_buckets: int = 1024):
        super().__init__(name, help)
        self.rel_err = rel_err
        self.max_buckets = max_buckets

    def _new_child(self):
        return LogHistogram(rel_err=self.rel_err, max_buckets=self.max_buckets)

    def observe(self, value: float, **labels: str) -> None:
        self.labels(**labels).observe(value)


class MetricsRegistry:
    """Process-wide metric index.  ``counter``/``gauge``/``histogram`` are
    get-or-create by name (re-registering an existing name with a different
    type raises)."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "", rel_err: float = 0.01,
                  max_buckets: int = 1024) -> Histogram:
        return self._get_or_create(Histogram, name, help,
                                   rel_err=rel_err, max_buckets=max_buckets)

    def metrics(self):
        with self._lock:
            return [self._metrics[n] for n in sorted(self._metrics)]

    # -- merging -----------------------------------------------------------

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry in: counters add, gauges take the other's
        value (last-write-wins), histograms merge sketches.  Returns self."""
        for m in other.metrics():
            if isinstance(m, Counter):
                mine = self.counter(m.name, m.help)
                for key, child in m.items():
                    mine.labels(**dict(key)).inc(child.value)
            elif isinstance(m, Gauge):
                mine = self.gauge(m.name, m.help)
                for key, child in m.items():
                    mine.labels(**dict(key)).set(child.value)
            elif isinstance(m, Histogram):
                mine = self.histogram(m.name, m.help, rel_err=m.rel_err,
                                      max_buckets=m.max_buckets)
                for key, child in m.items():
                    mine.labels(**dict(key)).merge(child)
        return self

    # -- JSON --------------------------------------------------------------

    def to_dict(self) -> dict:
        out = {}
        for m in self.metrics():
            series = []
            for key, child in sorted(m.items()):
                entry: dict = {"labels": dict(key)}
                if isinstance(m, Histogram):
                    h: LogHistogram = child  # type: ignore[assignment]
                    entry["histogram"] = h.to_dict()
                    entry["quantiles"] = {
                        "p50": h.quantile(0.50), "p95": h.quantile(0.95),
                        "p99": h.quantile(0.99)}
                else:
                    entry["value"] = child.value  # type: ignore[union-attr]
                series.append(entry)
            out[m.name] = {"type": m.kind, "help": m.help, "series": series}
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "MetricsRegistry":
        reg = cls()
        for name, meta in d.items():
            kind = meta.get("type", "gauge")
            for entry in meta.get("series", []):
                labels = entry.get("labels", {})
                if kind == "counter":
                    reg.counter(name, meta.get("help", "")) \
                        .labels(**labels).inc(float(entry["value"]))
                elif kind == "gauge":
                    reg.gauge(name, meta.get("help", "")) \
                        .labels(**labels).set(float(entry["value"]))
                elif kind == "histogram":
                    h = LogHistogram.from_dict(entry["histogram"])
                    m = reg.histogram(name, meta.get("help", ""),
                                      rel_err=h.rel_err,
                                      max_buckets=h.max_buckets)
                    m.labels(**labels).merge(h)
        return reg

    @classmethod
    def from_json(cls, s: str) -> "MetricsRegistry":
        return cls.from_dict(json.loads(s))

    # -- Prometheus text exposition ---------------------------------------

    def to_prometheus(self) -> str:
        """Text exposition format (version 0.0.4).  Histograms emit
        cumulative ``_bucket{le=...}`` series from the sketch's occupied
        bucket upper bounds, plus exact ``_sum`` and ``_count``."""
        lines = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key, child in sorted(m.items()):
                if isinstance(m, Histogram):
                    h: LogHistogram = child  # type: ignore[assignment]
                    cum = h.zero_count
                    for ub, c in h.bucket_bounds():
                        cum += c
                        lines.append(
                            f"{m.name}_bucket"
                            f"{_fmt_labels(key, [('le', f'{ub:.6g}')])}"
                            f" {cum}")
                    lines.append(
                        f"{m.name}_bucket{_fmt_labels(key, [('le', '+Inf')])}"
                        f" {h.count}")
                    lines.append(
                        f"{m.name}_sum{_fmt_labels(key)} {_fmt_value(h.total)}")
                    lines.append(f"{m.name}_count{_fmt_labels(key)} {h.count}")
                else:
                    v = child.value  # type: ignore[union-attr]
                    lines.append(f"{m.name}{_fmt_labels(key)} {_fmt_value(v)}")
        return "\n".join(lines) + "\n"
