"""repro.obs — observability for the serving stack.

Three pieces (see ``docs/observability.md``):

* :mod:`~repro.obs.histogram` — :class:`LogHistogram`, the bounded-memory
  mergeable sketch behind every latency/convergence distribution.
* :mod:`~repro.obs.registry` — :class:`MetricsRegistry` of typed counters,
  gauges, and histograms with JSON + Prometheus exporters.
* :mod:`~repro.obs.trace` — :class:`TraceRecorder`, request-scoped span
  trees exported as Chrome-trace/Perfetto JSON, plus the
  :func:`~repro.obs.jaxbridge.device_annotation` bridge to
  ``jax.profiler``.

:class:`Observability` bundles all three for threading through
``AnnIndex.serve(..., obs=...)`` / ``serve_async(..., obs=...)``.  The
shared :data:`NULL_OBS` singleton is the default: every probe point
degrades to a constant-time no-op, so an uninstrumented engine pays
nothing.
"""
from __future__ import annotations

from typing import Optional

from .histogram import LogHistogram
from .jaxbridge import device_annotation, have_profiler
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import NULL_TRACER, SpanHandle, TraceRecorder

__all__ = [
    "LogHistogram",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "TraceRecorder", "SpanHandle", "NULL_TRACER",
    "device_annotation", "have_profiler",
    "Observability", "NULL_OBS",
]


class Observability:
    """Tracer + metrics registry + profiler flag, as one handle.

    * ``tracing`` — record span trees (:class:`TraceRecorder`); off means
      the shared :data:`NULL_TRACER` (no-ops, no allocation).
    * ``metrics`` — write convergence/serving histograms into
      ``registry``.  The engines guard every registry write on this flag,
      which is what the zero-overhead test pins down.
    * ``profile`` — additionally wrap device dispatches in
      ``jax.profiler.TraceAnnotation`` so host spans line up with device
      timelines under ``jax.profiler.trace()``.
    """

    __slots__ = ("tracer", "registry", "metrics", "profile")

    def __init__(self, *, tracing: bool = True, metrics: bool = True,
                 profile: bool = False, max_trace_events: int = 200_000,
                 registry: Optional[MetricsRegistry] = None):
        self.tracer = (TraceRecorder(enabled=True,
                                     max_events=max_trace_events)
                       if tracing else NULL_TRACER)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.metrics = bool(metrics)
        self.profile = bool(profile)

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled or self.metrics

    def write_trace(self, path: str) -> None:
        """Dump the Chrome-trace JSON collected so far to ``path``."""
        self.tracer.write(path)


#: Shared all-off bundle — the default ``obs`` everywhere.
NULL_OBS = Observability(tracing=False, metrics=False, profile=False)
