"""Similarity-graph index structures.

The paper (§3.2, Fig. 2) stores the index as CSR topology + a separate
embedding matrix.  On TPU we use a *padded* CSR — a dense ``(N, R)`` int32
neighbor table (R = max out-degree, padding = sentinel ``N``) — because fixed
shapes are required under jit and similarity graphs are degree-truncated
anyway (NSG/HNSW cap out-degree to avoid the "out-degree explosion problem").

Neighbor grouping (§4.4, Fig. 11) is realized as a two-level layout:

* vertices are re-labelled by in-degree rank (degree-centric) or by measured
  access frequency (frequency-centric);
* the top ``n_top`` vertices additionally carry a *flattened* neighbor
  embedding tensor ``flat[(n_top, R, d)]`` so expanding a hot vertex is one
  contiguous ``dynamic_slice`` (an HBM burst) instead of R random gathers.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class PaddedCSR(NamedTuple):
    """Dense padded adjacency + vectors. ``nbrs[i, j] == n_nodes`` is padding."""
    nbrs: jax.Array        # (N, R) int32, padded with N
    vectors: jax.Array     # (N, d) float32/bfloat16 feature vectors
    medoid: jax.Array      # () int32, default entry point
    # two-level neighbor grouping (optional; zero-size when disabled)
    n_top: int             # static: number of top-level (flattened) vertices
    flat: jax.Array        # (n_top, R, d) flattened neighbor embeddings
    # quantized storage (repro.quant; None when the index is not quantized).
    # The quantized distance backends (ref_int8 | rowgather_int8 | ref_bf16)
    # gather from ``codes`` so the hot-path payload is 4x/2x smaller; the
    # f32 ``vectors`` stay the seeding + exact-re-ranking table.
    codes: Optional[jax.Array] = None    # (N, d) int8 | bfloat16
    scales: Optional[jax.Array] = None   # (N, 1) per-vector | (1, d) per-dim

    @property
    def n_nodes(self) -> int:
        return self.nbrs.shape[0]

    @property
    def degree(self) -> int:
        return self.nbrs.shape[1]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


def make_padded_csr(
    nbrs: np.ndarray,
    vectors: np.ndarray,
    medoid: Optional[int] = None,
    n_top: int = 0,
    metric: str = "l2",
) -> PaddedCSR:
    """Build a PaddedCSR from host arrays; optionally flatten top vertices.

    ``nbrs`` rows may be ragged-padded with any value >= N or < 0; they are
    normalized to the sentinel N.  ``metric`` only affects the default
    medoid choice when ``medoid`` is None.
    """
    n, _ = nbrs.shape
    nbrs = nbrs.astype(np.int32)
    nbrs = np.where((nbrs < 0) | (nbrs >= n), n, nbrs)
    vectors = np.asarray(vectors)
    if medoid is None:
        medoid = int(compute_medoid(vectors, metric=metric))
    flat = _flatten_top(nbrs, vectors, n_top)
    return PaddedCSR(
        nbrs=jnp.asarray(nbrs),
        vectors=jnp.asarray(vectors),
        medoid=jnp.asarray(medoid, jnp.int32),
        n_top=int(n_top),
        flat=jnp.asarray(flat),
    )


def _flatten_top(nbrs: np.ndarray, vectors: np.ndarray, n_top: int) -> np.ndarray:
    """Materialize neighbor embeddings of the ``n_top`` hottest vertices."""
    r = nbrs.shape[1]
    d = vectors.shape[1]
    if n_top <= 0:
        return np.zeros((0, r, d), dtype=vectors.dtype)
    ids = nbrs[:n_top]                       # (n_top, R)
    safe = np.minimum(ids, vectors.shape[0] - 1)
    flat = vectors[safe]                     # (n_top, R, d)
    flat = np.where((ids < vectors.shape[0])[..., None], flat, np.inf)
    return flat.astype(vectors.dtype)


def compute_medoid(vectors: np.ndarray, metric: str = "l2",
                   alive: Optional[np.ndarray] = None) -> int:
    """Vertex closest to the dataset centroid (NSG's navigating node).

    For "ip" the navigating node is the vertex with the largest inner
    product against the centroid (the MIPS analog of "closest"); "cosine"
    callers pass pre-normalized vectors, where l2 and ip orderings agree.

    ``alive`` (optional (N,) bool mask) restricts both the centroid and the
    argmin/argmax to live vertices — the incremental-delete path re-elects a
    navigating node among survivors when the medoid is tombstoned.
    """
    v = np.asarray(vectors, np.float32)
    if alive is not None:
        alive = np.asarray(alive, bool)
        if not alive.any():
            raise ValueError("compute_medoid: no live vertices")
        centroid = v[alive].mean(axis=0)
        if metric == "ip":
            score = np.where(alive, v @ centroid, -np.inf)
            return int(np.argmax(score))
        d = np.where(alive, np.linalg.norm(v - centroid, axis=1), np.inf)
        return int(np.argmin(d))
    centroid = v.mean(axis=0)
    if metric == "ip":
        return int(np.argmax(v @ centroid))
    d = np.linalg.norm(v - centroid, axis=1)
    return int(np.argmin(d))


def remap_sentinels(nbrs: np.ndarray, old_n: int, new_n: int) -> np.ndarray:
    """Rewrite padding entries when the node count changes (incremental add).

    The padded-CSR sentinel is the node count itself, so growing a graph from
    ``old_n`` to ``new_n`` rows invalidates every ``old_n`` padding entry —
    it would alias the first inserted point.  Must run BEFORE the neighbor
    table is grown.  Returns a new array; out-of-range ids (>= old_n or < 0)
    all normalize to the new sentinel.
    """
    nbrs = np.asarray(nbrs, np.int32)
    return np.where((nbrs < 0) | (nbrs >= old_n),
                    np.int32(new_n), nbrs)


# ---------------------------------------------------------------------------
# Neighbor grouping (§4.4): vertex re-labelling strategies
# ---------------------------------------------------------------------------

def indegree_rank(nbrs: np.ndarray) -> np.ndarray:
    """Degree-centric ranking: permutation old_id -> rank (0 = hottest)."""
    n = nbrs.shape[0]
    flat = nbrs[nbrs < n]
    indeg = np.bincount(flat, minlength=n)
    order = np.argsort(-indeg, kind="stable")       # old ids, hottest first
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)
    return rank


def frequency_rank(nbrs: np.ndarray, access_counts: np.ndarray) -> np.ndarray:
    """Frequency-centric ranking from measured query-time access counts."""
    n = nbrs.shape[0]
    order = np.argsort(-access_counts, kind="stable")
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)
    return rank


def relabel(nbrs: np.ndarray, vectors: np.ndarray, rank: np.ndarray):
    """Apply a vertex re-labelling: new_id = rank[old_id].

    Returns (new_nbrs, new_vectors, old_from_new) — ``old_from_new`` maps the
    search results back to original ids.
    """
    n = nbrs.shape[0]
    old_from_new = np.argsort(rank, kind="stable")
    new_vectors = vectors[old_from_new]
    remap = np.concatenate([rank.astype(np.int64), [n]])  # sentinel maps to n
    safe = np.where((nbrs >= 0) & (nbrs <= n), nbrs, n)
    new_nbrs = remap[safe][:]
    new_nbrs = new_nbrs[old_from_new]
    return new_nbrs.astype(np.int32), new_vectors, old_from_new


def group_by_indegree(
    nbrs: np.ndarray,
    vectors: np.ndarray,
    medoid: Optional[int] = None,
    top_fraction: float = 0.001,
):
    """Full degree-centric neighbor-grouping pipeline (paper's default).

    Returns (PaddedCSR with flattened top level, old_from_new permutation).
    """
    rank = indegree_rank(nbrs)
    new_nbrs, new_vectors, old_from_new = relabel(nbrs, vectors, rank)
    n_top = max(1, int(round(nbrs.shape[0] * top_fraction)))
    if medoid is not None:
        medoid = int(rank[medoid])
    csr = make_padded_csr(new_nbrs, new_vectors, medoid=medoid, n_top=n_top)
    return csr, old_from_new


# ---------------------------------------------------------------------------
# Device-side neighbor-vector fetch (two-level)
# ---------------------------------------------------------------------------

def gather_neighbor_ids(graph: PaddedCSR, active_ids: jax.Array) -> jax.Array:
    """(..., M) active vertex ids -> (..., M, R) neighbor ids.

    Leading-dims agnostic: the batch-major engine passes (B, M) ids and gets
    all queries' neighbor rows in one gather; per-query callers pass (M,).
    Invalid/sentinel actives yield fully padded rows.
    """
    safe = jnp.minimum(active_ids, graph.n_nodes - 1)
    nbrs = graph.nbrs[safe]
    return jnp.where((active_ids < graph.n_nodes)[..., None], nbrs,
                     graph.n_nodes)


def fetch_neighbor_vectors(
    graph: PaddedCSR, active_ids: jax.Array, nbr_ids: jax.Array
) -> jax.Array:
    """Fetch (..., M, R, d) neighbor embeddings via the two-level layout.

    Leading-dims agnostic like :func:`gather_neighbor_ids` — the batch-major
    ``ref`` backend fetches a whole (B, M, R, d) expansion in one gather.
    Hot vertices (< n_top) read their flattened block (contiguous HBM burst);
    cold vertices gather rows from the embedding table.  Padding rows return
    +inf so downstream distances are +inf.
    """
    n = graph.n_nodes
    safe_nbr = jnp.minimum(nbr_ids, n - 1)
    gathered = graph.vectors[safe_nbr]                        # (..., M, R, d)
    gathered = jnp.where(
        (nbr_ids < n)[..., None], gathered,
        jnp.asarray(jnp.inf, gathered.dtype))
    if graph.n_top == 0:
        return gathered
    hot = active_ids < graph.n_top                            # (..., M)
    safe_act = jnp.clip(active_ids, 0, graph.n_top - 1)
    flat = graph.flat[safe_act]                               # (..., M, R, d)
    return jnp.where(hot[..., None, None], flat, gathered)


def top_level_hit_fraction(graph: PaddedCSR, active_ids: jax.Array) -> jax.Array:
    """Fraction of expansions served by the flattened top level (profiling)."""
    valid = active_ids < graph.n_nodes
    hits = (active_ids < graph.n_top) & valid
    return jnp.sum(hits) / jnp.maximum(jnp.sum(valid), 1)
