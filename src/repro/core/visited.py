"""Visited-set structures (§4.4, "loosely synchronized visiting map").

Three modes, trading exactness for scale, all with the paper's correctness
model: a false-negative lookup merely causes a duplicate distance computation
(benign — the queue merge dedups); a false *positive* is never produced.

* ``bitmap`` — exact dense boolean array over the N graph vertices.  Per
  walker, per query.  The paper's shared CPU bitvector with benign races maps
  to *per-walker* maps that are OR-merged only at global syncs ("eventual
  consistency"); between syncs walkers may duplicate each other's work, which
  we measure (paper claims <5%).
* ``hash``  — fixed 2**bits open-addressed set with bounded linear probing.
  Scales to billion-node graphs (memory independent of N).  Probe losses and
  in-batch scatter races cause duplicate computations only (benign, and the
  direct TPU analog of the paper's fence-free racy updates).
* ``loose`` — no structure at all; dedup happens only against the frontier
  at insert time.  Maximum duplicates, zero memory; useful as an ablation
  (the paper's "no visiting map" extreme).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Visited:
    table: jax.Array    # bitmap: (N,) bool   | hash: (2**bits,) int32 keys
    mode_bitmap: bool = dataclasses.field(metadata=dict(static=True))
    mask: int = dataclasses.field(metadata=dict(static=True))  # hash: 2**b - 1

    def _replace(self, **kw) -> "Visited":
        return dataclasses.replace(self, **kw)


_EMPTY = jnp.int32(-1)
_PROBES = 8


def make_visited(mode: str, n_nodes: int, hash_bits: int = 14) -> Visited:
    if mode == "bitmap":
        return Visited(jnp.zeros((n_nodes,), bool), True, 0)
    if mode == "hash":
        size = 1 << hash_bits
        return Visited(jnp.full((size,), _EMPTY, jnp.int32), False, size - 1)
    if mode == "loose":
        return Visited(jnp.full((1,), _EMPTY, jnp.int32), False, 0)
    raise ValueError(f"unknown visited mode {mode!r}")


def _hash(ids: jax.Array, mask: int) -> jax.Array:
    # Knuth multiplicative hash on int32 ids.
    h = (ids.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h ^ ids.astype(jnp.uint32)).astype(jnp.int32) & mask


def check_and_insert(
    v: Visited, ids: jax.Array, valid: jax.Array
) -> Tuple[Visited, jax.Array]:
    """Batch test-and-set.  Returns (visited', fresh_mask).

    ``fresh_mask[i]`` is True when ids[i] was valid and *not* previously
    marked; those are the ids whose distances must be computed this step.
    """
    if v.mode_bitmap:
        n = v.table.shape[0]
        safe = jnp.clip(ids, 0, n - 1)
        already = v.table[safe] & valid
        fresh = valid & ~already
        # in-batch duplicates: keep first occurrence only (exact dedup)
        fresh = fresh & _first_occurrence(ids, fresh)
        # scatter-max (commutative OR): duplicate indices in the batch must
        # not be able to erase a concurrent True write (.set is order-
        # nondeterministic with duplicates)
        table = v.table.at[safe].max(fresh)
        return v._replace(table=table), fresh

    if v.mask == 0:  # loose mode: no memory; only in-batch dedup
        fresh = valid & _first_occurrence(ids, valid)
        return v, fresh

    # hash mode: bounded linear probing.
    table = v.table
    found = jnp.zeros(ids.shape, bool)
    inserted = jnp.zeros(ids.shape, bool)
    slot = _hash(ids, v.mask)
    for _ in range(_PROBES):
        cur = table[slot]
        # a lane that already claimed its slot must not read its own insert
        # back as a pre-existing hit
        hit = (cur == ids) & valid & ~inserted
        empty = (cur == _EMPTY) & valid & ~found & ~inserted
        # try to claim empty slots; duplicate-index scatter races are benign
        # (loser reads back a different key and retries next probe round)
        table = table.at[jnp.where(empty, slot, 0)].set(
            jnp.where(empty, ids, table[0]))
        claimed = empty & (table[slot] == ids)
        inserted = inserted | claimed
        found = found | hit
        done = found | inserted
        slot = jnp.where(done, slot, (slot + 1) & v.mask)
    # ids that neither hit nor found a slot are treated as fresh (duplicate
    # compute possible — benign)
    fresh = valid & ~found
    fresh = fresh & _first_occurrence(ids, fresh)
    return v._replace(table=table), fresh


def _first_occurrence(ids: jax.Array, valid: jax.Array) -> jax.Array:
    """Mask keeping only the first occurrence of each id among valid slots."""
    n = ids.shape[0]
    eq = ids[None, :] == ids[:, None]                 # (n, n)
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)  # j < i
    dup_of_earlier = jnp.any(eq & earlier & valid[None, :], axis=1)
    return valid & ~dup_of_earlier


# ---------------------------------------------------------------------------
# Batch-major operations — leading (B,) query axis on the table
# ---------------------------------------------------------------------------

def make_visited_batch(mode: str, n_nodes: int, batch: int,
                       hash_bits: int = 14) -> Visited:
    """A stacked visited map: one :func:`make_visited` table per query on a
    leading (B,) axis (the batch-major engine's per-query visited state).

    Walker-stacked maps compose by passing ``batch=(B, W)``-style products
    through repeated broadcasting at the call site; this helper only adds
    the query axis."""
    if mode == "bitmap":
        return Visited(jnp.zeros((batch, n_nodes), bool), True, 0)
    if mode == "hash":
        size = 1 << hash_bits
        return Visited(jnp.full((batch, size), _EMPTY, jnp.int32), False,
                       size - 1)
    if mode == "loose":
        return Visited(jnp.full((batch, 1), _EMPTY, jnp.int32), False, 0)
    raise ValueError(f"unknown visited mode {mode!r}")


def check_and_insert_batch(
    v: Visited, ids: jax.Array, valid: jax.Array
) -> Tuple[Visited, jax.Array]:
    """:func:`check_and_insert` vmapped over the leading query axis:
    (B, ...) tables × (B, C) ids — bit-identical to the per-query path."""
    return jax.vmap(check_and_insert)(v, ids, valid)


def popcount(v: Visited) -> jax.Array:
    """Number of marked vertices in walker 0's table.

    On an OR-merged stacked map this is the exact union size (bitmap mode) or
    table occupancy (hash mode; slot losses undercount — benign).  Used to
    measure cross-walker duplicate computations:
    ``dups = sum(per-walker comps) - (union_after - union_before)``.
    """
    t0 = v.table[0] if v.table.ndim > 1 else v.table
    if v.mode_bitmap:
        return jnp.sum(t0).astype(jnp.int32)
    if v.mask == 0:
        return jnp.int32(0)
    return jnp.sum(t0 != _EMPTY).astype(jnp.int32)


def merge_visited(vs: Visited) -> Visited:
    """OR-merge stacked walker visited maps (leading axis W) at a global sync.

    Bitmap: exact OR.  Hash: keep walker 0's table and re-insert others'
    non-empty keys (best effort; losses are benign).  Loose: no-op.
    """
    if vs.mode_bitmap:
        merged = jnp.any(vs.table, axis=0)
        w = vs.table.shape[0]
        return Visited(jnp.broadcast_to(merged, vs.table.shape), True, 0)
    if vs.mask == 0:
        return vs
    # hash: fold tables together; occupied slots from any walker win.
    def fold(acc, t):
        take = (acc == _EMPTY) & (t != _EMPTY)
        return jnp.where(take, t, acc), None
    merged, _ = jax.lax.scan(fold, vs.table[0], vs.table[1:])
    return Visited(jnp.broadcast_to(merged, vs.table.shape), False, vs.mask)
