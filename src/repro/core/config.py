"""Speed-ANN search configuration — the traversal layer's plumbing type.

:class:`SearchConfig` lives HERE, next to the algorithms it parameterizes
(``core.bfis`` / ``core.speedann`` / ``core.distributed``), not in the
model-config grab-bag ``repro.config`` (which re-exports it for backward
compatibility).  Public callers should prefer the :mod:`repro.ann` facade
(``IndexSpec`` + ``SearchParams``); ``SearchParams.to_search_config`` lowers
onto this type.

A frozen dataclass: hashable (usable as a jit static argument and as a
searcher-cache key), serializable, and diffable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class SearchConfig:
    """Speed-ANN search hyperparameters (Algorithm 3 + §4)."""
    k: int = 10                  # neighbors to return
    # distance metric of the index: "l2" (squared L2, minimized), "ip"
    # (negative inner product, minimized — MIPS), "cosine" (ip on unit-norm
    # vectors; the AnnIndex facade pre-normalizes base vectors and queries).
    metric: str = "l2"
    queue_len: int = 64          # L, bounded frontier capacity
    m_max: int = 8               # max expansion width M (paper: up to #threads)
    stage_every: int = 1         # t: double M every t global steps (paper: t=1)
    staged: bool = True          # staged search (§4.2); False = fixed M=m_max
    max_steps: int = 64          # step budget (safety bound; BFiS may need more)
    sync_ratio: float = 0.8      # R in Algorithm 2 (paper: 0.8/0.9 per dataset)
    local_steps: int = 4         # max local steps between sync checks
    num_walkers: int = 1         # W: private-queue workers (vmapped or devices)
    visited_mode: str = "bitmap"  # "bitmap" | "loose" | "hash"
    hash_bits: int = 14          # hash-set capacity = 2**hash_bits
    # distance backend for the neighbor-expansion hot path; resolved through
    # repro.kernels.registry:  "ref" (pure-jnp gather), "rowgather"
    # (scalar-prefetch Pallas row gather), "dma" (explicit-DMA tile gather +
    # MXU reduction).  Backends are BATCH-MAJOR: one kernel launch covers the
    # whole (B, M, R) expansion of a query batch per global step.  Pallas
    # backends run in interpret mode on CPU and lower through Mosaic on TPU
    # (see kernels/ops.INTERPRET).
    dist_backend: str = "ref"
    dma_group: int = 8           # G: rows per DMA tile ("dma" backend only)
    # distributed search: static outer (scatter/merge) round budget — bounded
    # rounds give deterministic worst-case latency (straggler mitigation)
    global_rounds: int = 12

    def with_(self, **kw) -> "SearchConfig":
        return dataclasses.replace(self, **kw)
