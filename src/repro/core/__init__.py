# Speed-ANN core: the paper's contribution as composable JAX modules.
from repro.core.config import SearchConfig  # noqa: F401
from repro.core.graph import (PaddedCSR, make_padded_csr, group_by_indegree,  # noqa: F401
                              compute_medoid, remap_sentinels)
from repro.core.build import (build_nsg, build_nsg_serial, build_hnsw,  # noqa: F401
                              exact_knn, insert_points, knn_graph,
                              normalize_rows, repair_deleted)
from repro.core.bfis import (bfis_search_batch, search_topm,  # noqa: F401
                             search_topm_batch, hnsw_search_batch, dist_l2,
                             dist_ip, make_ref_dist_fn, point_dist,
                             resolve_dist_fn)
from repro.core.speedann import (search_speedann, search_speedann_batch,  # noqa: F401
                                 variant)
from repro.core.metrics import recall_at_k, SearchStats  # noqa: F401
