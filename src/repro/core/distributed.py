"""Distributed Speed-ANN on a device mesh via ``shard_map``.

Two orthogonal distribution modes, composable on a ("data", "model") mesh:

* **walker sharding** (the paper's intra-query parallelism, cross-device):
  the query batch is sharded over ``data``; each device along ``model`` is
  one Speed-ANN *walker* holding a private frontier and visited map over a
  replicated graph.  Walker expansions use the per-query ``core.bfis.expand``
  (which lifts each call to a B=1 batch of the batch-major ``DistFn``);
  corpus shards run the full batch-major engine on their local query slice.  A global round = scatter (replicated global queue,
  owner = axis_index) → collective-free local segment → CheckMetrics (one
  scalar ``psum`` per local round — the lazy-synchronization trigger) →
  merge (``all_gather`` of local frontiers + dedup + top-L; visited maps
  OR-reduced).  Between merges there are NO collectives: the paper's
  "workers searching asynchronously without global queue contention".

* **corpus sharding** (billion-scale practicality, §5.5): the dataset is
  partitioned; each ``model`` device owns one partition with its own
  sub-index and searches it independently; final answers are the global
  top-K over an ``all_gather`` of per-shard top-K lists.  Walker and corpus
  sharding compose (walkers within a shard) for multi-pod meshes.

The distributed outer loop uses a STATIC round budget (``global_rounds``)
instead of a data-dependent while: bounded rounds ⇒ bounded, deterministic
tail latency (the serving-side straggler-mitigation policy; converged
queries no-op and counters stay exact).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# --- jax version compat: shard_map location + replication-check kwarg ------
try:                                      # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_vma": False}
except ImportError:                       # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}


def shard_map(f, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking off (stats
    leaves are reduced to uniform values manually)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **_SHARD_MAP_KW)


def make_search_mesh(shape, names=("data", "model")) -> Mesh:
    """Version-portable mesh construction for the search meshes: newer jax
    wants explicit ``axis_types``, 0.4.35+ has ``jax.make_mesh`` without
    that parameter, and older jax only has the raw ``Mesh`` constructor."""
    shape, names = tuple(shape), tuple(names)
    if hasattr(jax, "make_mesh"):
        try:
            axis_types = (jax.sharding.AxisType.Auto,) * len(names)
            return jax.make_mesh(shape, names, axis_types=axis_types)
        except (AttributeError, TypeError):
            return jax.make_mesh(shape, names)
    n = 1
    for s in shape:
        n *= s
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devices, names)

from repro.core.config import SearchConfig
from repro.core import queue as fq
from repro.core import visited as vs
from repro.core.bfis import (DistFn, expand, point_dist, resolve_dist_fn,
                             staged_m)
from repro.core.graph import PaddedCSR
from repro.core.metrics import SearchStats


# ---------------------------------------------------------------------------
# Walker-sharded Speed-ANN
# ---------------------------------------------------------------------------

def _scatter_share(f: fq.Frontier, walker: jax.Array, active: jax.Array
                   ) -> fq.Frontier:
    """This walker's share of the replicated global queue (Line 7).

    Equivalent to ``queue.scatter_round_robin(...)[walker]`` but computed
    locally from the replica — no communication.
    """
    unchecked = ~f.checked & (f.ids != fq.INVALID_ID)
    ranks = jnp.cumsum(unchecked.astype(jnp.int32)) - 1
    owner = jnp.where(unchecked, ranks % jnp.maximum(active, 1), -1)
    keep = (owner == walker) & (walker < active)
    shared = f.checked & (f.ids != fq.INVALID_ID)
    ids = jnp.where(keep | shared, f.ids, fq.INVALID_ID)
    dists = jnp.where(keep | shared, f.dists, fq.INF)
    checked = jnp.where(keep, False, True)
    dists, ids, checked8 = jax.lax.sort(
        (dists, ids, checked.astype(jnp.int32)), num_keys=2, is_stable=True)
    return fq.Frontier(ids=ids, dists=dists,
                       checked=(checked8 == 1) | (ids == fq.INVALID_ID))


def _merge_all_walkers(local: fq.Frontier, axis: str) -> fq.Frontier:
    """Line 23 across devices: all_gather local queues, dedup, top-L."""
    stacked = jax.tree.map(
        functools.partial(jax.lax.all_gather, axis_name=axis), local)
    merged, _ = fq.merge_frontiers(stacked)
    return merged


def _reduce_visited(v: vs.Visited, axis: str) -> vs.Visited:
    """§4.4 eventual consistency across devices at a sync point."""
    if v.mode_bitmap:
        table = jax.lax.pmax(v.table.astype(jnp.uint8), axis) > 0
        return v._replace(table=table)
    if v.mask == 0:
        return v
    tables = jax.lax.all_gather(v.table, axis)        # (W, size)

    def fold(acc, t):
        take = (acc == jnp.int32(-1)) & (t != jnp.int32(-1))
        return jnp.where(take, t, acc), None

    merged, _ = jax.lax.scan(fold, tables[0], tables[1:])
    return v._replace(table=merged)


def walker_sharded_search(
    graph: PaddedCSR,
    queries: jax.Array,
    cfg: SearchConfig,
    mesh: Mesh,
    data_axis: str = "data",
    walker_axis: str = "model",
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array, SearchStats]:
    """Speed-ANN with one walker per device along ``walker_axis``.

    queries: (B, d) global batch, B divisible by mesh.shape[data_axis].
    Returns (ids (B,k), dists (B,k), stats batched over B).
    """
    dist_fn = resolve_dist_fn(cfg, dist_fn)
    n_walkers = int(mesh.shape[walker_axis])
    n_top, n_nodes = graph.n_top, graph.n_nodes

    def per_query(nbrs, vectors, medoid, flat, q, walker):
        g = PaddedCSR(nbrs=nbrs, vectors=vectors, medoid=medoid,
                      n_top=n_top, flat=flat)
        cap = cfg.queue_len
        frontier = fq.make_frontier(cap)
        visited = vs.make_visited(cfg.visited_mode, n_nodes, cfg.hash_bits)
        visited, _ = vs.check_and_insert(
            visited, medoid[None], jnp.ones((1,), bool))
        v0 = vectors[medoid].astype(jnp.float32)
        d0 = point_dist(v0, q, cfg.metric)[None]
        frontier, _, _ = fq.insert(frontier, medoid[None], d0)
        frontier, visited, _, n0 = expand(g, q, frontier, visited, 1, 1,
                                          dist_fn)
        stats = SearchStats.zero()._replace(dist_comps=1 + n0)

        def round_(r, carry):
            frontier, visited, stats = carry
            live = fq.has_unchecked(frontier)
            m = jnp.minimum(staged_m(stats.steps, cfg), n_walkers)
            local = _scatter_share(frontier, walker, m)
            union_before = vs.popcount(visited)

            def lcond(c):
                fr, vis, up, ls, merge_flag, comps = c
                return (~merge_flag) & (ls < cfg.local_steps)

            def lbody(c):
                fr, vis, up, ls, merge_flag, comps = c
                had = fq.has_unchecked(fr) & (walker < m)
                fr2, vis2, u, nn = expand(g, q, fr, vis, 1, 1, dist_fn)
                u = jnp.where(had, u, cap).astype(jnp.int32)
                # CheckMetrics: ONE scalar all-reduce per local round — the
                # only communication between merges
                u_sum = jax.lax.psum(
                    jnp.where(walker < m, u, 0), walker_axis)
                u_bar = u_sum / jnp.maximum(m, 1)
                any_work = jax.lax.psum(
                    had.astype(jnp.int32), walker_axis) > 0
                merge_flag = (u_bar >= cap * cfg.sync_ratio) | ~any_work
                return (fr2, vis2, u, ls + 1, merge_flag,
                        comps + jnp.where(had, nn, 0))

            local, visited, _, rounds, _, comps = jax.lax.while_loop(
                lcond, lbody,
                (local, visited, jnp.int32(0), jnp.int32(0),
                 jnp.bool_(False), jnp.int32(0)))
            frontier = _merge_all_walkers(local, walker_axis)
            visited = _reduce_visited(visited, walker_axis)
            # all stats fields must be uniform along the walker axis (the
            # output spec replicates them), so reduce per-walker counters
            total_comps = jax.lax.psum(comps, walker_axis)
            n_dups = jnp.maximum(
                total_comps - (vs.popcount(visited) - union_before), 0)
            stats = stats._replace(
                steps=stats.steps + live.astype(jnp.int32),
                local_steps=stats.local_steps + rounds * m,  # uniform rounds
                dist_comps=stats.dist_comps + total_comps,
                dup_comps=stats.dup_comps + jnp.where(live, n_dups, 0),
                syncs=stats.syncs + live.astype(jnp.int32),
                crit_rounds=stats.crit_rounds + rounds)
            return frontier, visited, stats

        frontier, visited, stats = jax.lax.fori_loop(
            0, cfg.global_rounds, round_, (frontier, visited, stats))
        ids, dists = fq.results(frontier, cfg.k)
        return ids, dists, stats

    def shard_body(nbrs, vectors, medoid, flat, q_local):
        walker = jax.lax.axis_index(walker_axis).astype(jnp.int32)
        fn = functools.partial(per_query, nbrs, vectors, medoid, flat,
                               walker=walker)
        ids, dists, stats = jax.vmap(fn)(q_local)
        return ids, dists, stats

    rep = P()   # graph replicated on all devices
    fn = shard_map(
        shard_body, mesh=mesh,
        in_specs=(rep, rep, rep, rep, P(data_axis, None)),
        out_specs=(P(data_axis, None), P(data_axis, None),
                   jax.tree.map(lambda _: P(data_axis), SearchStats.zero())),
    )
    return fn(graph.nbrs, graph.vectors, graph.medoid, graph.flat, queries)


# ---------------------------------------------------------------------------
# Corpus-sharded search (billion-scale, §5.5)
# ---------------------------------------------------------------------------

class ShardedIndex(NamedTuple):
    """Per-shard sub-indices stacked on a leading shard axis."""
    nbrs: jax.Array        # (S, N_s, R) partition-local neighbor ids
    vectors: jax.Array     # (S, N_s, d)
    medoids: jax.Array     # (S,)
    offsets: jax.Array     # (S,) global id = offsets[s] + local id

    @property
    def num_shards(self) -> int:
        return self.nbrs.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[-1]


def build_partitioned(data: np.ndarray, num_shards: int, degree: int = 24,
                      **nsg_kw) -> ShardedIndex:
    """Partition the corpus contiguously and build one sub-index per shard.

    (Real deployments partition by clustering; contiguous split keeps the
    builder simple and the search path identical.)
    """
    from repro.core.build import build_nsg
    n = data.shape[0]
    per = n // num_shards
    nbrs, vecs, meds, offs = [], [], [], []
    for s in range(num_shards):
        lo, hi = s * per, (s + 1) * per if s < num_shards - 1 else n
        sub = np.asarray(data[lo:hi], np.float32)
        g = build_nsg(sub, degree=degree, **nsg_kw)
        nbrs.append(np.asarray(g.nbrs))
        vecs.append(np.asarray(g.vectors))
        meds.append(int(g.medoid))
        offs.append(lo)
    # pad shards to a common size
    max_n = max(x.shape[0] for x in vecs)
    d = vecs[0].shape[1]
    r = nbrs[0].shape[1]
    for s in range(num_shards):
        pad = max_n - vecs[s].shape[0]
        if pad:
            vecs[s] = np.concatenate(
                [vecs[s], np.full((pad, d), np.inf, np.float32)])
            nbrs[s] = np.concatenate(
                [np.where(nbrs[s] >= nbrs[s].shape[0], max_n, nbrs[s]),
                 np.full((pad, r), max_n, np.int32)]).astype(np.int32)
        else:
            nbrs[s] = nbrs[s].astype(np.int32)
    return ShardedIndex(
        nbrs=jnp.asarray(np.stack(nbrs)),
        vectors=jnp.asarray(np.stack(vecs)),
        medoids=jnp.asarray(np.asarray(meds, np.int32)),
        offsets=jnp.asarray(np.asarray(offs, np.int32)))


def corpus_sharded_search(
    index: ShardedIndex,
    queries: jax.Array,
    cfg: SearchConfig,
    mesh: Mesh,
    data_axis: str = "data",
    shard_axis: str = "model",
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Each ``shard_axis`` device searches its partition; global top-K merge.

    Returns (global ids (B,k), dists (B,k)).
    """
    from repro.core.bfis import search_topm_batch

    dist_fn = resolve_dist_fn(cfg, dist_fn)
    n_top = 0

    def shard_body(nbrs, vectors, medoid, offset, q_local):
        nbrs = nbrs[0]
        vectors = vectors[0]
        medoid = medoid[0]
        offset = offset[0]
        g = PaddedCSR(nbrs=nbrs, vectors=vectors, medoid=medoid, n_top=n_top,
                      flat=jnp.zeros((0, nbrs.shape[1], vectors.shape[1]),
                                     vectors.dtype))
        # batch-major engine inside the shard: the device's whole local
        # query batch advances through one while_loop / one distance launch
        # per step (bit-identical to the per-query vmap it replaces)
        ids, dists, _ = search_topm_batch(g, q_local, cfg, dist_fn=dist_fn)
        gids = jnp.where(ids == fq.INVALID_ID, fq.INVALID_ID, ids + offset)
        # gather per-shard top-k across the shard axis and reduce
        all_ids = jax.lax.all_gather(gids, shard_axis)     # (S, b, k)
        all_d = jax.lax.all_gather(dists, shard_axis)
        s, b, k = all_ids.shape
        flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(b, s * k)
        flat_d = jnp.moveaxis(all_d, 0, 1).reshape(b, s * k)
        flat_d, flat_i = jax.lax.sort((flat_d, flat_i), num_keys=2,
                                      is_stable=True, dimension=-1)
        return flat_i[:, :cfg.k], flat_d[:, :cfg.k]

    fn = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(shard_axis), P(shard_axis), P(shard_axis), P(shard_axis),
                  P(data_axis, None)),
        out_specs=(P(data_axis, None), P(data_axis, None)),
    )
    return fn(index.nbrs, index.vectors, index.medoids, index.offsets,
              queries)


# ---------------------------------------------------------------------------
# Engine-shaped entry points (facade types in, facade types out)
#
# The raw shard_map functions above take (PaddedCSR | ShardedIndex,
# SearchConfig) — the internal plumbing types.  The serving layer speaks
# AnnIndex + SearchParams, so these adapters let AnnEngine (and anything
# else engine-shaped) route dispatch through the distributed paths without
# re-wiring metric normalization, config lowering, or id remapping.
# ---------------------------------------------------------------------------

def walker_engine_search(index, queries, params, mesh: Optional[Mesh] = None):
    """Walker-sharded dispatch with facade types: ``AnnIndex`` +
    ``SearchParams`` in, ``SearchResult`` out.

    Delegates to ``index.searcher(algorithm="sharded")`` so the query
    normalization (cosine), grouping id remap, and searcher caching are the
    facade's own — one walker per device along the mesh's ``model`` axis,
    the query batch sharded over ``data``.  ``mesh=None`` uses the default
    (1, n_devices) search mesh.
    """
    return index.search(queries, params.with_(algorithm="sharded"),
                        mesh=mesh)


def build_partitioned_index(data, num_shards: int, spec=None) -> ShardedIndex:
    """Corpus partitioning driven by an :class:`repro.ann.IndexSpec`.

    Honors the spec's builder knobs (degree, knn_k, ef_construction, passes,
    seed, and the batched-construction ``build_batch``/``build_backend``
    tile — every per-shard build runs through the batch-insertion path) and
    its metric: for ``cosine`` the corpus is unit-normalized before
    partitioning (cosine == ip on the unit sphere), matching
    ``AnnIndex.build``.  Returns a :class:`ShardedIndex` for
    :func:`corpus_sharded_search` / :func:`corpus_engine_searcher`.
    """
    from repro.ann.spec import IndexSpec
    from repro.core.build import normalize_rows
    if spec is None:
        spec = IndexSpec()
    if spec.quant.enabled:
        raise ValueError("quantized storage is not wired into the "
                         "corpus-sharded path; use IndexSpec(quant='none')")
    data = np.asarray(data, np.float32)
    if spec.metric == "cosine":
        data = normalize_rows(data)
    build_metric = "l2" if spec.metric == "cosine" else spec.metric
    return build_partitioned(
        data, num_shards, degree=spec.degree, knn_k=spec.resolved_knn_k,
        alpha=spec.alpha, ef_construction=spec.resolved_ef,
        passes=spec.passes, seed=spec.seed, metric=build_metric,
        build_batch=spec.build_batch, build_backend=spec.build_backend)


def corpus_engine_searcher(index: ShardedIndex, params, mesh: Mesh,
                           metric: str = "l2"):
    """A batched callable ``fn(queries (B, d)) -> (ids, dists, stats)`` over
    a partitioned corpus — the corpus-sharded analogue of
    ``AnnIndex.searcher``, shaped for the serving engine.

    Each ``model`` device searches its own partition with a sequential
    best-first walker (top-M with M=1 — walker parallelism within a shard
    composes via a 3D mesh instead) and the global top-K is merged across
    shards.  Queries are unit-normalized here for ``metric="cosine"``.
    ``stats`` is a zero-filled :class:`SearchStats` batched over B: per-query
    counters do not cross the shard merge.
    """
    cfg = params.to_search_config(metric).with_(m_max=1, staged=False,
                                                num_walkers=1)
    normalize = metric == "cosine"

    @jax.jit
    def jitted(nbrs, vectors, medoids, offsets, q):
        idx = ShardedIndex(nbrs=nbrs, vectors=vectors, medoids=medoids,
                           offsets=offsets)
        q = q.astype(jnp.float32)
        if normalize:
            q = q / jnp.maximum(
                jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        ids, dists = corpus_sharded_search(idx, q, cfg, mesh)
        zero = jnp.zeros((q.shape[0],), jnp.int32)
        stats = jax.tree.map(lambda _: zero, SearchStats.zero())
        return ids, dists, stats

    def fn(queries):
        q = jnp.asarray(queries)
        if q.ndim != 2:
            raise ValueError(f"queries must be (B, d), got {q.shape}")
        return jitted(index.nbrs, index.vectors, index.medoids,
                      index.offsets, q)

    return fn
