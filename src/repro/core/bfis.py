"""Best-first search (Algorithm 1) and single-queue top-M relaxation (§4.1).

``search_topm`` is the bulk-synchronous form of Speed-ANN's parallel neighbor
expansion: each step selects the top-M unchecked candidates from ONE shared
frontier and expands them simultaneously.  ``M=1`` is exactly the paper's
BFiS (the NSG/HNSW search kernel); larger M exposes path-wise parallelism;
``staged=True`` doubles M every ``stage_every`` steps (§4.2).

The full Algorithm 3 (private walker queues + redundant-expansion-aware lazy
synchronization) lives in ``speedann.py``; this module is both the baseline
and the building block.

**Batch-major engine.**  ``search_topm_batch`` runs ONE ``lax.while_loop``
over batch-leading state: ``Frontier``/``Visited``/``SearchStats`` all carry
a leading ``(B,)`` query axis and every global step issues a SINGLE distance
launch over the whole ``(B, M, R)`` expansion (the workload the Pallas
kernels amortize).  Converged queries are masked no-ops — the loop body's
new state is selected per lane against the lane's own liveness predicate,
which is exactly ``jax.vmap``'s batching rule for ``while_loop``, so the
batch-major path is bit-identical (ids, dists, stats) to vmapping the
per-query search.  The per-query entry points (``search_topm``,
``search_speedann``) remain as thin ``B=1`` wrappers.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SearchConfig
from repro.core import queue as fq
from repro.core import visited as vs
from repro.core.graph import (PaddedCSR, fetch_neighbor_vectors,
                              gather_neighbor_ids)
from repro.core.metrics import SearchStats, batch_unique_counts

# dist_fn(graph, active_ids (B, M), nbr_ids (B, M, R), queries (B, d))
# -> (B, M, R) distances, float32, smaller = closer, +inf for padded ids.
# BATCH-MAJOR contract: one call covers every query's expansion for the
# step — backends launch ONE kernel over the flattened (B, M·R) candidate
# grid instead of per-lane gathers.  The queries are float32; WHICH stored
# table a backend reads (f32 ``graph.vectors``, int8 ``graph.codes`` +
# ``graph.scales``, bf16 codes) and in what precision it accumulates is the
# backend's own business — the search algorithms only see the f32 result,
# so quantized and exact backends are interchangeable here.
DistFn = Callable[[PaddedCSR, jax.Array, jax.Array, jax.Array], jax.Array]


def resolve_dist_fn(cfg: SearchConfig,
                    dist_fn: Optional[DistFn] = None) -> DistFn:
    """An explicit ``dist_fn`` wins; otherwise ``cfg.dist_backend`` resolves
    through the kernel registry (``"ref" | "rowgather" | "dma"``)."""
    if dist_fn is not None:
        return dist_fn
    # import here so ref-only users never touch the Pallas import path
    from repro.kernels.registry import resolve_backend
    return resolve_backend(cfg)


def dist_l2(graph: PaddedCSR, active_ids: jax.Array, nbr_ids: jax.Array,
            queries: jax.Array) -> jax.Array:
    """Reference squared-L2 distance via the two-level vector fetch.

    Leading-dims agnostic: (B, M, R) batch-major ids with (B, d) queries,
    or (M, R) with (d,) for per-query callers."""
    vecs = fetch_neighbor_vectors(graph, active_ids, nbr_ids)
    diff = vecs.astype(jnp.float32) \
        - queries.astype(jnp.float32)[..., None, None, :]
    return jnp.sum(diff * diff, axis=-1)


def dist_ip(graph: PaddedCSR, active_ids: jax.Array, nbr_ids: jax.Array,
            queries: jax.Array) -> jax.Array:
    """Reference negative-inner-product distance (MIPS; cosine when the
    index vectors and query are pre-normalized).

    Padding rows of the two-level fetch are +inf, so the dot product is
    masked explicitly by neighbor validity instead of relying on the inf
    arithmetic (inf * 0 -> nan)."""
    vecs = fetch_neighbor_vectors(graph, active_ids, nbr_ids)
    d = -jnp.sum(vecs.astype(jnp.float32)
                 * queries.astype(jnp.float32)[..., None, None, :], axis=-1)
    return jnp.where(nbr_ids < graph.n_nodes, d, jnp.inf)


def make_ref_dist_fn(metric: str = "l2") -> DistFn:
    """Metric tag -> pure-jnp two-level batch-major DistFn ("cosine" == ip:
    the facade pre-normalizes base vectors and queries)."""
    if metric in ("ip", "cosine"):
        return dist_ip
    if metric == "l2":
        return dist_l2
    raise ValueError(f"unknown metric {metric!r}")


def point_dist(v: jax.Array, q: jax.Array, metric: str = "l2") -> jax.Array:
    """Point-to-query distance used to seed the search frontier.

    Leading-dims agnostic: (d,) vectors give a scalar, (B, d) give (B,)."""
    v = v.astype(jnp.float32)
    q = q.astype(jnp.float32)
    if metric in ("ip", "cosine"):
        return -jnp.sum(v * q, axis=-1)
    return jnp.sum((v - q) ** 2, axis=-1)


def lane_select(alive: jax.Array, new, old):
    """Per-lane carry masking: where ``alive[b]`` take ``new``, else keep
    ``old`` — the ``jax.vmap`` while_loop batching rule, applied explicitly
    by the batch-major engine so converged queries are exact no-ops."""
    def sel(n, o):
        pred = alive.reshape(alive.shape + (1,) * (n.ndim - alive.ndim))
        return jnp.where(pred, n, o)
    return jax.tree.map(sel, new, old)


def expand_batch(
    graph: PaddedCSR,
    queries: jax.Array,
    frontier: fq.Frontier,
    visited: vs.Visited,
    m_max: int,
    m: jax.Array | int,
    dist_fn: DistFn = dist_l2,
    lane_mask: Optional[jax.Array] = None,
) -> Tuple[fq.Frontier, vs.Visited, jax.Array, jax.Array, jax.Array]:
    """One batch-major neighbor-expansion round (Algorithm 1 lines 6–13,
    width m, all B queries at once).

    ``frontier``/``visited`` carry a leading (B,) axis; ``m`` may be scalar
    or per-query (B,).  The ONLY cross-lane fusion is the distance call:
    one ``dist_fn`` launch covers the whole (B, m_max, R) candidate grid.
    Returns (frontier', visited', update_positions (B,), n_comps (B,),
    n_uniq (B,)) where ``n_uniq`` is the first-toucher count feeding
    ``SearchStats.uniq_comps`` — fresh candidates whose id no lower-index
    lane expands this round.  ``lane_mask`` (B,) bool excludes lanes whose
    state the caller will discard (converged/step-budget-dead lanes still
    ride in the batch as no-op work, but they must not claim first-toucher
    credit away from live lanes — the counters stay exact and front-slice
    invariant).
    """
    bsz = queries.shape[0]
    frontier, active_ids, active_valid = fq.select_unchecked_batch(
        frontier, m_max, m)
    nbrs = gather_neighbor_ids(graph, active_ids)          # (B, m_max, R)
    flat = nbrs.reshape(bsz, -1)
    valid = (flat < graph.n_nodes) \
        & jnp.repeat(active_valid, graph.degree, axis=-1)
    visited, fresh = vs.check_and_insert_batch(visited, flat, valid)
    # the frontier stores f32 keys; normalize here so a backend that reduces
    # in another precision (int32-accumulated int8, bf16) can't leak its
    # accumulator dtype into the queue
    dists = dist_fn(graph, active_ids, nbrs, queries).astype(
        jnp.float32).reshape(bsz, -1)
    dists = jnp.where(fresh, dists, jnp.inf)
    cand_ids = jnp.where(fresh, flat, fq.INVALID_ID)
    frontier, up_pos, _ = fq.insert_batch(frontier, cand_ids, dists)
    counted = fresh if lane_mask is None else fresh & lane_mask[:, None]
    n_uniq = batch_unique_counts(flat, counted)
    return frontier, visited, up_pos, \
        jnp.sum(fresh, axis=-1).astype(jnp.int32), n_uniq


def expand(
    graph: PaddedCSR,
    q: jax.Array,
    frontier: fq.Frontier,
    visited: vs.Visited,
    m_max: int,
    m: jax.Array | int,
    dist_fn: DistFn = dist_l2,
) -> Tuple[fq.Frontier, vs.Visited, jax.Array, jax.Array]:
    """Per-query expansion round (the ``core.distributed`` walker building
    block): lifts the query to a B=1 batch for the batch-major ``dist_fn``.

    Returns (frontier', visited', update_position, n_distance_comps).
    A single lane has no cross-lane overlap (uniq == comps), so no
    first-toucher count is returned here.
    """
    frontier, active_ids, active_valid = fq.select_unchecked(
        frontier, m_max, m)
    nbrs = gather_neighbor_ids(graph, active_ids)          # (m_max, R)
    flat = nbrs.reshape(-1)
    valid = (flat < graph.n_nodes) & jnp.repeat(active_valid, graph.degree)
    visited, fresh = vs.check_and_insert(visited, flat, valid)
    dists = dist_fn(graph, active_ids[None], nbrs[None], q[None])[0]
    dists = dists.astype(jnp.float32).reshape(-1)
    dists = jnp.where(fresh, dists, jnp.inf)
    cand_ids = jnp.where(fresh, flat, fq.INVALID_ID)
    frontier, up_pos, _ = fq.insert(frontier, cand_ids, dists)
    return frontier, visited, up_pos, jnp.sum(fresh).astype(jnp.int32)


class _TopMState(NamedTuple):
    frontier: fq.Frontier     # leaves (B, L)
    visited: vs.Visited       # table (B, ...)
    stats: SearchStats        # leaves (B,)


def _seed_ids(graph: PaddedCSR, start: Optional[jax.Array],
              batch: int) -> jax.Array:
    """(B,) int32 traversal entry points: the medoid (build-time entry
    policy, e.g. MIPS max-norm — see ``IndexSpec.entry_policy``) unless the
    caller provides per-query starts."""
    if start is None:
        return jnp.broadcast_to(
            jnp.asarray(graph.medoid, jnp.int32), (batch,))
    return jnp.broadcast_to(jnp.asarray(start, jnp.int32), (batch,))


def _init_state_batch(
    graph: PaddedCSR, queries: jax.Array, cfg: SearchConfig,
    start: Optional[jax.Array],
) -> _TopMState:
    """Batch-major initial state for (B, d) queries: frontier (B, L),
    visited (B, ...), stats leaves (B,), seeded at the entry point."""
    bsz = queries.shape[0]
    frontier = fq.make_frontier_batch(cfg.queue_len, bsz)
    visited = vs.make_visited_batch(cfg.visited_mode, graph.n_nodes, bsz,
                                    cfg.hash_bits)
    s = _seed_ids(graph, start, bsz)
    visited, _ = vs.check_and_insert_batch(
        visited, s[:, None], jnp.ones((bsz, 1), bool))
    v = graph.vectors[s].astype(jnp.float32)               # (B, d)
    d0 = point_dist(v, queries, cfg.metric)[:, None]
    frontier, _, _ = fq.insert_batch(frontier, s[:, None], d0)
    # the seed computation participates in first-toucher accounting too: a
    # shared entry point (the medoid) is the batch's first overlapping row
    seed_uniq = batch_unique_counts(s[:, None], jnp.ones((bsz, 1), bool))
    stats = SearchStats.zero_batch(bsz)._replace(
        dist_comps=jnp.ones((bsz,), jnp.int32),
        uniq_comps=seed_uniq,
        batch_dup_comps=jnp.int32(1) - seed_uniq)
    return _TopMState(frontier, visited, stats)


def staged_m(step: jax.Array, cfg: SearchConfig) -> jax.Array:
    """§4.2 staging function: M doubles every ``stage_every`` steps.

    Elementwise — a (B,) step vector yields per-query widths."""
    if not cfg.staged:
        return jnp.broadcast_to(jnp.int32(cfg.m_max), jnp.shape(step))
    expo = jnp.minimum(step // cfg.stage_every, 30).astype(jnp.int32)
    return jnp.minimum(jnp.left_shift(jnp.int32(1), expo),
                       jnp.int32(cfg.m_max))


def _run_topm_batch(
    graph: PaddedCSR,
    queries: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
) -> _TopMState:
    """Run the batch-major top-M loop to convergence; returns the final
    state (frontier + visited + stats), from which the public entry points
    slice their results."""
    dist_fn = resolve_dist_fn(cfg, dist_fn)
    st = _init_state_batch(graph, queries, cfg, start)

    def lanes_live(s: _TopMState) -> jax.Array:
        return fq.has_unchecked_batch(s.frontier) \
            & (s.stats.steps < cfg.max_steps)

    def cond(s: _TopMState):
        return jnp.any(lanes_live(s))

    def body(s: _TopMState):
        alive = lanes_live(s)
        live = fq.has_unchecked_batch(s.frontier).astype(jnp.int32)
        m = staged_m(s.stats.steps, cfg)
        frontier, visited, _, n, uniq = expand_batch(
            graph, queries, s.frontier, s.visited, cfg.m_max, m, dist_fn,
            lane_mask=alive)
        stats = s.stats._replace(
            steps=s.stats.steps + live,
            local_steps=s.stats.local_steps
            + jnp.minimum(m, jnp.int32(cfg.m_max)) * live,
            dist_comps=s.stats.dist_comps + n,
            uniq_comps=s.stats.uniq_comps + uniq,
            batch_dup_comps=s.stats.batch_dup_comps + (n - uniq),
            crit_rounds=s.stats.crit_rounds + live,
        )
        return lane_select(alive, _TopMState(frontier, visited, stats), s)

    return jax.lax.while_loop(cond, body, st)


def search_topm_batch(
    graph: PaddedCSR,
    queries: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array, SearchStats]:
    """Batch-major single-queue top-M search over a (B, d) query batch.

    One ``lax.while_loop`` advances every query per iteration (ONE distance
    launch per global step for the whole batch); converged lanes are masked
    no-ops, so per-query counters stay exact and results are bit-identical
    to vmapping :func:`search_topm`.  ``cfg.m_max == 1`` reproduces BFiS /
    Algorithm 1 exactly.  Returns (ids (B, k), dists (B, k), stats (B,)).
    """
    st = _run_topm_batch(graph, queries, cfg, start, dist_fn)
    ids, dists = fq.results_batch(st.frontier, cfg.k)
    return ids, dists, st.stats


def search_topm_batch_visited(
    graph: PaddedCSR,
    queries: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array, SearchStats, jax.Array]:
    """:func:`search_topm_batch` that ALSO returns the per-lane visited set
    as a (B, N) bool mask (requires ``cfg.visited_mode == "bitmap"``).

    The visited set — every vertex whose distance the traversal evaluated,
    not just the k survivors — is Vamana's robust-prune candidate pool V:
    it contains the far-out vertices along the entry→neighborhood descent
    path, whose pruned survivors become the graph's long-range edges.  The
    batched builder (``core.build``) is the consumer.  Per-lane content is
    batch-invariant like the results themselves.
    """
    if cfg.visited_mode != "bitmap":
        raise ValueError(
            "search_topm_batch_visited needs visited_mode='bitmap' (the "
            f"(B, N) mask IS the visited set); got {cfg.visited_mode!r}")
    st = _run_topm_batch(graph, queries, cfg, start, dist_fn)
    ids, dists = fq.results_batch(st.frontier, cfg.k)
    return ids, dists, st.stats, st.visited.table


def search_topm(
    graph: PaddedCSR,
    q: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array, SearchStats]:
    """Single-query top-M search — a thin B=1 wrapper over the batch-major
    engine.  Returns (ids (k,), dists (k,), stats).
    """
    start_b = None if start is None \
        else jnp.asarray(start, jnp.int32).reshape(1)
    ids, dists, stats = search_topm_batch(
        graph, q[None, :], cfg, start=start_b, dist_fn=dist_fn)
    return ids[0], dists[0], jax.tree.map(lambda t: t[0], stats)


def bfis_search_batch(graph, queries, cfg: SearchConfig, **kw):
    """Algorithm 1 (the NSG baseline): top-M search with M=1, no staging,
    batch-major over (B, d) queries -> (ids (B, k), dists (B, k),
    stats (B,))."""
    return search_topm_batch(
        graph, queries, cfg.with_(m_max=1, staged=False), **kw)


# ---------------------------------------------------------------------------
# HNSW-style hierarchical search (the paper's second baseline)
# ---------------------------------------------------------------------------

def greedy_descent(
    level_nbrs: jax.Array, vectors: jax.Array, entry: jax.Array,
    q: jax.Array, max_hops: int = 64, metric: str = "l2",
) -> jax.Array:
    """Greedy walk on one upper level: hop to the closest neighbor until a
    local minimum (HNSW's ef=1 upper-level search)."""
    n = vectors.shape[0]
    qf = q.astype(jnp.float32)

    def dist_of(i):
        v = vectors[jnp.minimum(i, n - 1)].astype(jnp.float32)
        return jnp.where(i < n, point_dist(v, qf, metric), jnp.inf)

    def cond(carry):
        cur, cur_d, moved, hops = carry
        return moved & (hops < max_hops)

    def body(carry):
        cur, cur_d, _, hops = carry
        nb = level_nbrs[cur]                        # (R_l,)
        vecs = vectors[jnp.minimum(nb, n - 1)].astype(jnp.float32)
        if metric in ("ip", "cosine"):
            d = -jnp.sum(vecs * qf[None, :], axis=-1)
        else:
            d = jnp.sum((vecs - qf[None, :]) ** 2, axis=-1)
        d = jnp.where(nb < n, d, jnp.inf)
        j = jnp.argmin(d)
        better = d[j] < cur_d
        return (jnp.where(better, nb[j], cur),
                jnp.where(better, d[j], cur_d),
                better, hops + 1)

    cur, _, _, _ = jax.lax.while_loop(
        cond, body, (entry, dist_of(entry), jnp.bool_(True), jnp.int32(0)))
    return cur


def hnsw_search_batch(index, queries: jax.Array, cfg: SearchConfig,
                      dist_fn: Optional[DistFn] = None):
    """HNSW baseline: greedy descent through upper levels, then the
    batch-major BFiS at level 0 (per-query entry points ride in as
    ``start``)."""
    base = index.base

    def one(q):
        cur = jnp.asarray(index.entry, jnp.int32)
        for lvl in range(len(index.level_nbrs) - 1, -1, -1):
            cur = greedy_descent(index.level_nbrs[lvl], base.vectors, cur, q,
                                 metric=cfg.metric)
        return cur

    starts = jax.vmap(one)(queries)
    return search_topm_batch(
        base, queries, cfg.with_(m_max=1, staged=False), start=starts,
        dist_fn=dist_fn)
