"""Speed-ANN intra-query parallel search — Algorithm 3 + §4.2/§4.3/§4.4.

Structure of one *global step* (outer loop iteration):

  1. scatter: the global queue's unchecked candidates are divided
     round-robin among the ``M`` active walkers (staged: M doubles every
     ``stage_every`` global steps up to ``num_walkers``);
  2. local search: every walker runs a private best-first search on its own
     bounded queue — no communication with other walkers (collective-free on
     TPU; lock-free on CPU in the paper);
  3. CheckMetrics (Algorithm 2): after each local round the mean *update
     position* ū over active walkers is compared against ``L·R``; when
     ū ≥ L·R (walkers inserting only near the queue tail ⇒ searching
     unpromising regions) a merge is triggered;
  4. merge: local queues collapse into the global queue (dedup, prefer
     checked); walker visited maps are OR-merged ("eventual consistency",
     §4.4); counters accumulate.

Walkers here are *vmapped lanes on one device*; ``core.distributed`` lifts
the same step functions onto a ``shard_map`` walker mesh axis where the merge
becomes an ``all_gather`` and CheckMetrics a scalar ``psum``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import SearchConfig
from repro.core import queue as fq
from repro.core import visited as vs
from repro.core.bfis import (DistFn, expand, point_dist, resolve_dist_fn,
                             staged_m)
from repro.core.metrics import SearchStats


class _LocalState(NamedTuple):
    locals_: fq.Frontier      # (W, L) private walker queues
    visited: vs.Visited       # (W, ...) private visited maps
    up_pos: jax.Array         # (W,) latest update positions
    lstep: jax.Array          # () local rounds taken this segment
    do_merge: jax.Array       # () bool — CheckMetrics flag
    comps: jax.Array          # () distance computations this segment


class _GlobalState(NamedTuple):
    frontier: fq.Frontier     # (L,) global queue S
    visited: vs.Visited       # (W, ...) walker visited maps (persist)
    stats: SearchStats


def check_metrics(up_pos: jax.Array, active: jax.Array, cfg: SearchConfig
                  ) -> jax.Array:
    """Algorithm 2: ū ≥ L·R over the ``active`` lowest-index walkers."""
    w = up_pos.shape[0]
    is_active = jnp.arange(w) < active
    u_bar = (jnp.sum(jnp.where(is_active, up_pos, 0))
             / jnp.maximum(jnp.sum(is_active), 1))
    return u_bar >= cfg.queue_len * cfg.sync_ratio


def _local_segment(
    graph, q, locals_: fq.Frontier, visited: vs.Visited,
    active: jax.Array, cfg: SearchConfig, dist_fn: DistFn,
) -> Tuple[fq.Frontier, vs.Visited, jax.Array, jax.Array]:
    """Lines 11–22: collective-free private best-first searches.

    Runs until CheckMetrics fires, every walker exhausts its queue, or the
    ``local_steps`` budget is hit.  Returns (locals', visited', rounds,
    comps)."""
    w = cfg.num_walkers
    cap = cfg.queue_len

    def cond(s: _LocalState):
        is_active = jnp.arange(w) < active
        any_work = jnp.any(
            jax.vmap(fq.has_unchecked)(s.locals_) & is_active)
        return (~s.do_merge) & any_work & (s.lstep < cfg.local_steps)

    def body(s: _LocalState):
        def one(fr, vis):
            return expand(graph, q, fr, vis, 1, 1, dist_fn)
        locals2, visited2, up, n = jax.vmap(one)(s.locals_, s.visited)
        is_active = (jnp.arange(w) < active)
        had_work = jax.vmap(fq.has_unchecked)(s.locals_) & is_active
        # walkers with no unchecked candidates saturate at L (stuck)
        up = jnp.where(had_work, up, cap).astype(jnp.int32)
        do_merge = check_metrics(up, active, cfg)
        return _LocalState(
            locals_=locals2, visited=visited2, up_pos=up,
            lstep=s.lstep + 1, do_merge=do_merge,
            comps=s.comps + jnp.sum(jnp.where(had_work, n, 0)))

    init = _LocalState(
        locals_=locals_, visited=visited,
        up_pos=jnp.zeros((w,), jnp.int32), lstep=jnp.int32(0),
        do_merge=jnp.bool_(False), comps=jnp.int32(0))
    out = jax.lax.while_loop(cond, body, init)
    return out.locals_, out.visited, out.lstep, out.comps


def search_speedann(
    graph,
    q: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array, SearchStats]:
    """Full Speed-ANN search for one query (Algorithm 3)."""
    dist_fn = resolve_dist_fn(cfg, dist_fn)
    w, cap = cfg.num_walkers, cfg.queue_len

    frontier = fq.make_frontier(cap)
    visited0 = vs.make_visited(cfg.visited_mode, graph.n_nodes, cfg.hash_bits)
    s0 = graph.medoid if start is None else start.astype(jnp.int32)
    visited0, _ = vs.check_and_insert(visited0, s0[None], jnp.ones((1,), bool))
    v0 = graph.vectors[s0].astype(jnp.float32)
    d0 = point_dist(v0, q, cfg.metric)[None]
    frontier, _, _ = fq.insert(frontier, s0[None], d0)
    # Expand the starting point once before dividing work, so the first
    # scatter has a full frontier to distribute (paper Fig. 4: the search
    # fans out from P's neighbors; without this, NoSync would degenerate to
    # a single busy walker).
    frontier, visited0, _, n0 = expand(
        graph, q, frontier, visited0, 1, 1, dist_fn)
    # replicate the seed visited map to all walkers (consistent at t=0)
    visited = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (w,) + t.shape), visited0)

    init = _GlobalState(
        frontier=frontier, visited=visited,
        stats=SearchStats.zero()._replace(dist_comps=jnp.int32(1) + n0))

    def cond(s: _GlobalState):
        return fq.has_unchecked(s.frontier) & (s.stats.steps < cfg.max_steps)

    def body(s: _GlobalState):
        # invariant: s.visited is OR-merged (all walkers agree) on entry
        live = fq.has_unchecked(s.frontier)
        m = staged_m(s.stats.steps, cfg).astype(jnp.int32)
        m = jnp.minimum(m, w)
        union_before = vs.popcount(s.visited)
        # Line 7: divide unchecked candidates among active walkers.
        locals_ = fq.scatter_round_robin(s.frontier, w, active=m)
        # Lines 11–22: collective-free local searches + CheckMetrics.
        locals_, visited, rounds, comps = _local_segment(
            graph, q, locals_, s.visited, m, cfg, dist_fn)
        # Line 23: merge local queues into the global queue; §4.4: visited
        # maps reach eventual consistency here.
        merged, _ = fq.merge_frontiers(locals_)
        visited = vs.merge_visited(visited)
        # cross-walker duplicate computations = work minus union growth
        n_dups = comps - (vs.popcount(visited) - union_before)
        stats = s.stats._replace(
            steps=s.stats.steps + live.astype(jnp.int32),
            local_steps=s.stats.local_steps + rounds * m,
            dist_comps=s.stats.dist_comps + comps,
            dup_comps=s.stats.dup_comps + jnp.maximum(n_dups, 0),
            syncs=s.stats.syncs + live.astype(jnp.int32),
            crit_rounds=s.stats.crit_rounds + rounds,
        )
        return _GlobalState(frontier=merged, visited=visited, stats=stats)

    out = jax.lax.while_loop(cond, body, init)
    ids, dists = fq.results(out.frontier, cfg.k)
    return ids, dists, out.stats


def search_speedann_batch(
    graph,
    queries: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
):
    """vmapped Speed-ANN over a (B, d) query batch."""
    fn = functools.partial(search_speedann, graph, cfg=cfg,
                           dist_fn=resolve_dist_fn(cfg, dist_fn))
    if start is None:
        return jax.vmap(lambda qq: fn(qq))(queries)
    return jax.vmap(lambda qq, ss: fn(qq, start=ss))(queries, start)


# Named ablation variants (§5.3) ------------------------------------------

def variant(cfg: SearchConfig, name: str) -> SearchConfig:
    """The paper's §5.3 configurations."""
    if name == "bfis":               # NSG baseline
        return cfg.with_(m_max=1, num_walkers=1, staged=False)
    if name == "edge_parallel":      # NSG-32T: one global candidate per
        # step (M=1), but its edge expansion is spread across ALL walkers —
        # unlike "bfis" the walker pool is kept, so the §5.3 ablation
        # separates edge parallelism from path parallelism.
        return cfg.with_(m_max=1, staged=False)
    if name == "nostaged":           # Speed-ANN-NoStaged: fixed M=W
        return cfg.with_(staged=False)
    if name == "nosync":             # Speed-ANN-NoSync: all workers start at
        # once, search independently, merge only at the end (§5.3 (iii))
        return cfg.with_(staged=False, sync_ratio=2.0,
                         local_steps=cfg.max_steps)
    if name == "adaptive":           # Speed-ANN-Adaptive (the paper's method)
        return cfg
    raise ValueError(name)
