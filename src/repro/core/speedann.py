"""Speed-ANN intra-query parallel search — Algorithm 3 + §4.2/§4.3/§4.4.

Structure of one *global step* (outer loop iteration):

  1. scatter: the global queue's unchecked candidates are divided
     round-robin among the ``M`` active walkers (staged: M doubles every
     ``stage_every`` global steps up to ``num_walkers``);
  2. local search: every walker runs a private best-first search on its own
     bounded queue — no communication with other walkers (collective-free on
     TPU; lock-free on CPU in the paper);
  3. CheckMetrics (Algorithm 2): after each local round the mean *update
     position* ū over active walkers is compared against ``L·R``; when
     ū ≥ L·R (walkers inserting only near the queue tail ⇒ searching
     unpromising regions) a merge is triggered;
  4. merge: local queues collapse into the global queue (dedup, prefer
     checked); walker visited maps are OR-merged ("eventual consistency",
     §4.4); counters accumulate.

**Batch-major engine.**  ``search_speedann_batch`` runs the whole (B, d)
query batch through ONE outer ``lax.while_loop``: frontiers are (B, L),
walker queues (B, W, L), visited maps (B, W, ...), stats (B,).  Each local
round flattens the (B, W) walker lanes into the batch axis of the distance
backend, so ALL queries' walker expansions are ONE kernel launch.  Converged
queries are masked no-ops (per-lane carry select — exactly ``jax.vmap``'s
while_loop rule), so the batch-major path is bit-identical to vmapping the
per-query search and per-query counters stay exact.  ``search_speedann``
remains as a thin B=1 wrapper.

Walkers here are *vmapped lanes on one device*; ``core.distributed`` lifts
the same step functions onto a ``shard_map`` walker mesh axis where the merge
becomes an ``all_gather`` and CheckMetrics a scalar ``psum``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SearchConfig
from repro.core import queue as fq
from repro.core import visited as vs
from repro.core.bfis import (DistFn, _seed_ids, expand_batch, lane_select,
                             point_dist, resolve_dist_fn, staged_m)
from repro.core.metrics import SearchStats, batch_unique_counts


class _LocalState(NamedTuple):
    locals_: fq.Frontier      # (B, W, L) private walker queues
    visited: vs.Visited       # (B, W, ...) private visited maps
    up_pos: jax.Array         # (B, W) latest update positions
    lstep: jax.Array          # (B,) local rounds taken this segment
    do_merge: jax.Array       # (B,) bool — CheckMetrics flag
    comps: jax.Array          # (B,) distance computations this segment
    uniq: jax.Array           # (B,) first-toucher comps this segment (over
    #                           the whole flattened B·W walker grid — the
    #                           rows a batch-dedup backend would gather)


class _GlobalState(NamedTuple):
    frontier: fq.Frontier     # (B, L) global queue S
    visited: vs.Visited       # (B, W, ...) walker visited maps (persist)
    stats: SearchStats        # leaves (B,)


def check_metrics(up_pos: jax.Array, active: jax.Array, cfg: SearchConfig
                  ) -> jax.Array:
    """Algorithm 2: ū ≥ L·R over the ``active`` lowest-index walkers."""
    w = up_pos.shape[0]
    is_active = jnp.arange(w) < active
    u_bar = (jnp.sum(jnp.where(is_active, up_pos, 0))
             / jnp.maximum(jnp.sum(is_active), 1))
    return u_bar >= cfg.queue_len * cfg.sync_ratio


def _local_segment_batch(
    graph, queries: jax.Array, locals_: fq.Frontier, visited: vs.Visited,
    active: jax.Array, cfg: SearchConfig, dist_fn: DistFn,
    query_mask: Optional[jax.Array] = None,
) -> Tuple[fq.Frontier, vs.Visited, jax.Array, jax.Array, jax.Array]:
    """Lines 11–22 batch-major: collective-free private best-first searches
    for every query's walker pool at once.

    Each local round flattens the (B, W) walker lanes into one (B·W,)
    batch-major expansion — ONE distance launch for the whole batch's
    walkers.  Per query, the segment runs until CheckMetrics fires, every
    walker exhausts its queue, or the ``local_steps`` budget is hit;
    finished queries are masked no-ops.  ``query_mask`` (B,) excludes
    queries whose state the caller discards from first-toucher accounting
    (see ``expand_batch``).  Returns (locals', visited', rounds (B,),
    comps (B,), uniq (B,))."""
    w = cfg.num_walkers
    cap = cfg.queue_len
    bsz = queries.shape[0]
    q_rep = jnp.repeat(queries, w, axis=0)                 # (B·W, d)

    def flatten_bw(t):
        return t.reshape((bsz * w,) + t.shape[2:])

    def unflatten_bw(t):
        return t.reshape((bsz, w) + t.shape[1:])

    def is_active_mask():
        return jnp.arange(w)[None, :] < active[:, None]    # (B, W)

    def lanes_live(s: _LocalState) -> jax.Array:
        any_work = jnp.any(
            fq.has_unchecked_batch(s.locals_) & is_active_mask(), axis=-1)
        return (~s.do_merge) & any_work & (s.lstep < cfg.local_steps)

    def cond(s: _LocalState):
        return jnp.any(lanes_live(s))

    def body(s: _LocalState):
        alive = lanes_live(s)
        counted_q = alive if query_mask is None else alive & query_mask
        had_work = fq.has_unchecked_batch(s.locals_) & is_active_mask()
        # ONE batch-major expansion over all B·W walker lanes (M=1 each)
        fr = jax.tree.map(flatten_bw, s.locals_)
        vis = jax.tree.map(flatten_bw, s.visited)
        fr, vis, up, n, uniq = expand_batch(
            graph, q_rep, fr, vis, 1, 1, dist_fn,
            lane_mask=jnp.repeat(counted_q, w))
        locals2 = jax.tree.map(unflatten_bw, fr)
        visited2 = jax.tree.map(unflatten_bw, vis)
        up = up.reshape(bsz, w)
        n = n.reshape(bsz, w)
        uniq = uniq.reshape(bsz, w)
        # walkers with no unchecked candidates saturate at L (stuck)
        up = jnp.where(had_work, up, cap).astype(jnp.int32)
        do_merge = jax.vmap(
            lambda u, a: check_metrics(u, a, cfg))(up, active)
        new = _LocalState(
            locals_=locals2, visited=visited2, up_pos=up,
            lstep=s.lstep + 1, do_merge=do_merge,
            comps=s.comps + jnp.sum(jnp.where(had_work, n, 0), axis=-1),
            uniq=s.uniq + jnp.sum(jnp.where(had_work, uniq, 0), axis=-1))
        return lane_select(alive, new, s)

    init = _LocalState(
        locals_=locals_, visited=visited,
        up_pos=jnp.zeros((bsz, w), jnp.int32),
        lstep=jnp.zeros((bsz,), jnp.int32),
        do_merge=jnp.zeros((bsz,), bool),
        comps=jnp.zeros((bsz,), jnp.int32),
        uniq=jnp.zeros((bsz,), jnp.int32))
    out = jax.lax.while_loop(cond, body, init)
    return out.locals_, out.visited, out.lstep, out.comps, out.uniq


def search_speedann_batch(
    graph,
    queries: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array, SearchStats]:
    """Batch-major Speed-ANN (Algorithm 3) over a (B, d) query batch.

    Returns (ids (B, k), dists (B, k), stats (B,)); bit-identical to
    vmapping :func:`search_speedann` over the batch.
    """
    dist_fn = resolve_dist_fn(cfg, dist_fn)
    w, cap = cfg.num_walkers, cfg.queue_len
    bsz = queries.shape[0]

    frontier = fq.make_frontier_batch(cap, bsz)
    visited0 = vs.make_visited_batch(cfg.visited_mode, graph.n_nodes, bsz,
                                     cfg.hash_bits)
    s0 = _seed_ids(graph, start, bsz)
    visited0, _ = vs.check_and_insert_batch(
        visited0, s0[:, None], jnp.ones((bsz, 1), bool))
    v0 = graph.vectors[s0].astype(jnp.float32)
    d0 = point_dist(v0, queries, cfg.metric)[:, None]
    frontier, _, _ = fq.insert_batch(frontier, s0[:, None], d0)
    # Expand the starting point once before dividing work, so the first
    # scatter has a full frontier to distribute (paper Fig. 4: the search
    # fans out from P's neighbors; without this, NoSync would degenerate to
    # a single busy walker).
    frontier, visited0, _, n0, uniq0 = expand_batch(
        graph, queries, frontier, visited0, 1, 1, dist_fn)
    # replicate the seed visited map to all walkers (consistent at t=0)
    visited = jax.tree.map(
        lambda t: jnp.broadcast_to(t[:, None], (bsz, w) + t.shape[1:]),
        visited0)

    seed_uniq = batch_unique_counts(s0[:, None], jnp.ones((bsz, 1), bool))
    init = _GlobalState(
        frontier=frontier, visited=visited,
        stats=SearchStats.zero_batch(bsz)._replace(
            dist_comps=jnp.int32(1) + n0,
            uniq_comps=seed_uniq + uniq0,
            batch_dup_comps=(jnp.int32(1) - seed_uniq) + (n0 - uniq0)))

    def lanes_live(s: _GlobalState) -> jax.Array:
        return fq.has_unchecked_batch(s.frontier) \
            & (s.stats.steps < cfg.max_steps)

    def cond(s: _GlobalState):
        return jnp.any(lanes_live(s))

    def body(s: _GlobalState):
        # invariant: s.visited is OR-merged (all walkers agree) on entry
        alive = lanes_live(s)
        live = fq.has_unchecked_batch(s.frontier).astype(jnp.int32)
        m = jnp.minimum(staged_m(s.stats.steps, cfg).astype(jnp.int32), w)
        union_before = jax.vmap(vs.popcount)(s.visited)
        # Line 7: divide unchecked candidates among active walkers.
        locals_ = jax.vmap(
            lambda f, a: fq.scatter_round_robin(f, w, a))(s.frontier, m)
        # Lines 11–22: collective-free local searches + CheckMetrics.
        locals_, visited, rounds, comps, uniq = _local_segment_batch(
            graph, queries, locals_, s.visited, m, cfg, dist_fn,
            query_mask=alive)
        # Line 23: merge local queues into the global queue; §4.4: visited
        # maps reach eventual consistency here.
        merged, _ = jax.vmap(fq.merge_frontiers)(locals_)
        visited = jax.vmap(vs.merge_visited)(visited)
        # cross-walker duplicate computations = work minus union growth
        n_dups = comps - (jax.vmap(vs.popcount)(visited) - union_before)
        stats = s.stats._replace(
            steps=s.stats.steps + live,
            local_steps=s.stats.local_steps + rounds * m,
            dist_comps=s.stats.dist_comps + comps,
            dup_comps=s.stats.dup_comps + jnp.maximum(n_dups, 0),
            syncs=s.stats.syncs + live,
            crit_rounds=s.stats.crit_rounds + rounds,
            uniq_comps=s.stats.uniq_comps + uniq,
            batch_dup_comps=s.stats.batch_dup_comps + (comps - uniq),
        )
        return lane_select(
            alive, _GlobalState(frontier=merged, visited=visited,
                                stats=stats), s)

    out = jax.lax.while_loop(cond, body, init)
    ids, dists = fq.results_batch(out.frontier, cfg.k)
    return ids, dists, out.stats


def search_speedann(
    graph,
    q: jax.Array,
    cfg: SearchConfig,
    start: Optional[jax.Array] = None,
    dist_fn: Optional[DistFn] = None,
) -> Tuple[jax.Array, jax.Array, SearchStats]:
    """Full Speed-ANN search for one query — a thin B=1 wrapper over the
    batch-major engine."""
    start_b = None if start is None \
        else jnp.asarray(start, jnp.int32).reshape(1)
    ids, dists, stats = search_speedann_batch(
        graph, q[None, :], cfg, start=start_b, dist_fn=dist_fn)
    return ids[0], dists[0], jax.tree.map(lambda t: t[0], stats)


# Named ablation variants (§5.3) ------------------------------------------

def variant(cfg: SearchConfig, name: str) -> SearchConfig:
    """The paper's §5.3 configurations."""
    if name == "bfis":               # NSG baseline
        return cfg.with_(m_max=1, num_walkers=1, staged=False)
    if name == "edge_parallel":      # NSG-32T: one global candidate per
        # step (M=1), but its edge expansion is spread across ALL walkers —
        # unlike "bfis" the walker pool is kept, so the §5.3 ablation
        # separates edge parallelism from path parallelism.
        return cfg.with_(m_max=1, staged=False)
    if name == "nostaged":           # Speed-ANN-NoStaged: fixed M=W
        return cfg.with_(staged=False)
    if name == "nosync":             # Speed-ANN-NoSync: all workers start at
        # once, search independently, merge only at the end (§5.3 (iii))
        return cfg.with_(staged=False, sync_ratio=2.0,
                         local_steps=cfg.max_steps)
    if name == "adaptive":           # Speed-ANN-Adaptive (the paper's method)
        return cfg
    raise ValueError(name)
