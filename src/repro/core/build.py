"""Similarity-graph index construction.

The paper builds on NSG's construction ("not the focus of this work", §2.2).
The seed repo used a serial per-node Python loop with a host-side heap
search; this module replaces it with ParlayANN-style **batch insertion**:

* points are inserted in prefix-doubling rounds (1, 1, 2, 4, 8, ...); within
  a round every point runs its candidate search against the SAME frozen
  snapshot of the graph-so-far, so results cannot depend on intra-round
  ordering;
* all searches of a round go through the jit-compiled batch-major engine
  (``search_topm_batch`` — the exact hot path queries use at serve time,
  any registered distance backend), chunked into ``build_batch``-sized
  device calls.  ``build_batch`` is ONLY a compute tile: the final graph is
  bit-identical for every batch size and every within-batch permutation;
* the α-prune runs as a vectorized matrix form of :func:`_robust_prune`
  over the whole round (:func:`robust_prune_batch`), and reverse edges are
  applied from a (u, p)-lexsorted pair list with a fixed lowest-id-first
  conflict rule — deterministic, batch-invariant, bit-reproducible.

:func:`build_nsg_serial` is the per-point reference implementation (same
round schedule, scalar prune loops); ``build_nsg(build_batch=1)`` must match
it bit for bit — the parity gate ``tests/test_build_batch.py`` pins.

Incremental maintenance rides the same machinery: :func:`insert_points`
inserts new points into a live padded adjacency (``AnnIndex.add``), and
:func:`repair_deleted` re-prunes the in-neighborhood of tombstoned vertices
(``AnnIndex.delete``) so the graph stays navigable without a rebuild.

Construction remains offline-ish; host numpy orchestrates and the device
does the distance-heavy candidate searches.  Search-time code never calls
into this module.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SearchConfig
from repro.core.graph import PaddedCSR, compute_medoid, make_padded_csr


# ---------------------------------------------------------------------------
# Exact kNN (blocked brute force) — ground truth + upper-level seeds
# ---------------------------------------------------------------------------

def normalize_rows(x: np.ndarray) -> np.ndarray:
    """Unit-normalize rows (cosine = inner product on normalized vectors)."""
    x = np.asarray(x, np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)


@functools.partial(jax.jit, static_argnames=("metric",))
def _dist_block(q: jax.Array, x: jax.Array, metric: str = "l2") -> jax.Array:
    """(B, N) distances between query block and data block; smaller =
    closer for every metric ("ip" = negative inner product)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if metric == "ip":
        return -(q @ x.T)
    q2 = jnp.sum(q * q, axis=1, keepdims=True)
    x2 = jnp.sum(x * x, axis=1)
    return q2 + x2[None, :] - 2.0 * (q @ x.T)


def exact_knn(
    data: np.ndarray, queries: np.ndarray, k: int, block: int = 2048,
    metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact k nearest neighbors of ``queries`` within ``data``.

    ``metric`` is "l2" (squared L2), "ip" (negative inner product), or
    "cosine" (ip after normalizing BOTH sides here).  Returns
    (ids (Q, k) int32, dists (Q, k) float32) sorted ascending.
    """
    if metric == "cosine":
        data, queries = normalize_rows(data), normalize_rows(queries)
        metric = "ip"
    data_j = jnp.asarray(data)
    out_ids, out_d = [], []
    for s in range(0, queries.shape[0], block):
        q = jnp.asarray(queries[s:s + block])
        d = _dist_block(q, data_j, metric=metric)     # (b, N)
        d_top, i_top = jax.lax.top_k(-d, k)
        out_ids.append(np.asarray(i_top, np.int32))
        out_d.append(np.asarray(-d_top, np.float32))
    return np.concatenate(out_ids), np.concatenate(out_d)


def knn_graph(data: np.ndarray, k: int, block: int = 2048,
              metric: str = "l2") -> np.ndarray:
    """(N, k) kNN graph excluding self-edges, padded with the sentinel N.

    One numpy pass: a stable argsort on the self-edge mask compacts each
    row's non-self entries to the front (preserving distance order), then
    slots past the per-row valid count become the sentinel.
    """
    ids, _ = exact_knn(data, data, k + 1, block, metric=metric)
    n = data.shape[0]
    valid = ids != np.arange(n, dtype=ids.dtype)[:, None]     # (N, k+1)
    order = np.argsort(~valid, axis=1, kind="stable")
    rows = np.take_along_axis(ids, order, axis=1)[:, :k]
    cnt = np.minimum(valid.sum(axis=1), k)
    rows = np.where(np.arange(k)[None, :] < cnt[:, None], rows, n)
    return rows.astype(np.int32)


# ---------------------------------------------------------------------------
# α-prune: scalar reference + vectorized batch form
# ---------------------------------------------------------------------------

def prune_dists(vecs: np.ndarray, point: np.ndarray,
                metric: str) -> np.ndarray:
    """Candidate-to-point distances on the builder's pruning scale.

    ``vecs`` is (..., C, d), ``point`` broadcasts as (..., d); returns
    (..., C).  Actual L2 for "l2" (NOT squared — the α-occlusion rule is
    stated on metric distances), negative inner product for "ip".  Both the
    scalar and the batch prune call THIS function, with einsum contractions
    whose elementwise accumulation order is identical for 2-D and 3-D
    inputs — that shared arithmetic is what makes ``build_batch=1``
    bit-identical to the serial reference.
    """
    if metric == "ip":
        return -np.einsum("...cd,...d->...c", vecs, point)
    diff = vecs - point[..., None, :]
    return np.sqrt(np.maximum(
        np.einsum("...cd,...cd->...c", diff, diff), 0.0))


def _prune_dists(data: np.ndarray, ids: np.ndarray, point: np.ndarray,
                 metric: str) -> np.ndarray:
    """Distances of data[ids] to ``point`` (scalar-path convenience)."""
    return prune_dists(data[ids], point, metric)


def _robust_prune(
    data: np.ndarray, node: int, cand_ids: np.ndarray, cand_d: np.ndarray,
    degree: int, alpha: float, metric: str = "l2",
) -> np.ndarray:
    """Monotonic-RNG α-prune: greedily keep the closest candidate c, then
    drop every remaining candidate c' with α·d(c, c') ≤ d(node, c').

    For "ip" the same occlusion rule runs on negative-inner-product
    distances (the ip-NSW heuristic) with α forced to 1: scaling negative
    distances would invert the α>1 "keep more" semantics."""
    order = np.argsort(cand_d, kind="stable")
    cand_ids = cand_ids[order]
    cand_d = cand_d[order]
    eff_alpha = 1.0 if metric == "ip" else alpha
    keep: List[int] = []
    alive = np.ones(cand_ids.shape[0], bool)
    alive &= cand_ids != node
    for i in range(cand_ids.shape[0]):
        if not alive[i]:
            continue
        c = int(cand_ids[i])
        keep.append(c)
        if len(keep) >= degree:
            break
        # occlusion rule: drop c' when c is much closer to c' than node is
        d_cc = _prune_dists(data, cand_ids, data[c], metric)
        alive = alive & ~(eff_alpha * d_cc <= cand_d)
        alive[i] = False
    return np.asarray(keep, np.int32)


def robust_prune_batch(
    data: np.ndarray, node_ids: np.ndarray, cand_ids: np.ndarray,
    degree: int, alpha: float, metric: str = "l2",
) -> np.ndarray:
    """Vectorized :func:`_robust_prune` over a whole batch of nodes.

    ``node_ids`` is (B,), ``cand_ids`` (B, C) int32 padded with the
    sentinel ``len(data)`` (rows need not be sorted; padding and self
    entries are masked).  Returns (B, degree) int32 kept neighbors, padded
    with the sentinel — row b bit-identical to
    ``_robust_prune(data, node_ids[b], ...)`` over the same candidates.

    The greedy loop runs over OUTPUT SLOTS (``degree`` iterations) instead
    of candidates: each iteration picks every row's first still-alive
    candidate at once and applies the α-occlusion mask as one (B, C)
    matrix update.
    """
    n = data.shape[0]
    bsz, _ = cand_ids.shape
    valid = cand_ids < n
    cvecs = data[np.minimum(cand_ids, n - 1)]             # (B, C, d)
    cand_d = prune_dists(cvecs, data[node_ids], metric)   # (B, C)
    cand_d = np.where(valid, cand_d, np.inf)
    order = np.argsort(cand_d, axis=1, kind="stable")
    cand_ids = np.take_along_axis(cand_ids, order, axis=1)
    cand_d = np.take_along_axis(cand_d, order, axis=1)
    cvecs = np.take_along_axis(cvecs, order[:, :, None], axis=1)
    eff_alpha = 1.0 if metric == "ip" else alpha
    alive = (cand_ids < n) & (cand_ids != node_ids[:, None])
    rows = np.arange(bsz)
    out = np.full((bsz, degree), n, np.int32)
    for slot in range(degree):
        has = alive.any(axis=1)
        if not has.any():
            break
        idx = np.argmax(alive, axis=1)                    # first alive
        out[:, slot] = np.where(has, cand_ids[rows, idx], n)
        if slot == degree - 1:
            break
        d_cc = prune_dists(cvecs, cvecs[rows, idx], metric)   # (B, C)
        alive = alive & ~(eff_alpha * d_cc <= cand_d)
        alive[rows, idx] = False
    return out


# ---------------------------------------------------------------------------
# Candidate search: the batch-major engine over the graph-so-far
# ---------------------------------------------------------------------------

def _build_search_config(ef: int, metric: str, backend: str) -> SearchConfig:
    """The builder's candidate-search beam: top-M staged traversal with an
    ``ef``-deep frontier, through any registered distance backend."""
    return SearchConfig(
        k=ef, metric=metric, queue_len=ef, m_max=4, staged=True,
        stage_every=1, max_steps=4 * ef, dist_backend=backend,
        visited_mode="bitmap")   # the (B, N) mask IS the prune pool


@functools.partial(jax.jit, static_argnames=("cfg", "pool"))
def _candidate_search_batch(nbrs: jax.Array, vectors: jax.Array,
                            entry: jax.Array, queries: jax.Array,
                            cfg: SearchConfig, pool: str) -> jax.Array:
    """Candidate beam search for a B-leading query batch over a graph
    snapshot.  ``queries`` is (B, d).  ``pool`` picks the candidate set:

    * ``"visited"`` — the (B, N) bool visited mask, Vamana's prune pool V
      (every vertex the traversal scored, including the far-out descent
      path whose pruned survivors become the long-range edges).  The
      INSERTION pool.
    * ``"results"`` — the (B, ef) top results only (the seed builder's
      refinement pool): a deliberately NARROW, local pool, so a
      refinement prune polishes the close neighborhood while the current
      row's long-range edges keep their slots.

    The snapshot is the full-shape (N, R) adjacency, so every round of a
    build reuses ONE trace per pool kind."""
    from repro.core.bfis import (search_topm_batch,
                                 search_topm_batch_visited)

    graph = PaddedCSR(
        nbrs=nbrs, vectors=vectors, medoid=entry, n_top=0,
        flat=jnp.zeros((0, nbrs.shape[1], vectors.shape[1]),
                       vectors.dtype))
    if pool == "visited":
        _, _, _, visited = search_topm_batch_visited(graph, queries, cfg)
        return visited
    if pool == "results":
        ids, _, _ = search_topm_batch(graph, queries, cfg)
        return ids
    raise ValueError(f"unknown candidate pool {pool!r}")


def _visited_to_rows(vis: np.ndarray, n: int) -> np.ndarray:
    """(b, N) bool visited masks -> (b, C) int32 ascending visited ids,
    sentinel-padded, with C = the chunk's max visited count.  Converting
    per chunk keeps host memory at O(b · C) — the round never materializes
    a (round, N) mask."""
    counts = vis.sum(axis=1)
    width = max(int(counts.max()), 1)
    rows_idx, ids = np.nonzero(vis)
    pos = np.arange(ids.shape[0]) \
        - np.repeat(np.cumsum(counts) - counts, counts)
    out = np.full((vis.shape[0], width), n, np.int32)
    out[rows_idx, pos] = ids
    return out


def _search_candidates(
    nbrs_dev: jax.Array, vectors_dev: jax.Array, entry_dev: jax.Array,
    queries: np.ndarray, cfg: SearchConfig, build_batch: int,
    batch_perm: Optional[int] = None, pool: str = "visited",
) -> np.ndarray:
    """Run all candidate searches for a round, ``build_batch`` at a time;
    returns per-point candidate pools as (B, C) sentinel-padded id rows
    (``pool`` as in :func:`_candidate_search_batch`).

    The last chunk is padded (repeating its first row) so every device call
    has the same (build_batch, d) shape — one jit trace per build.  With
    ``batch_perm`` set, each chunk is permuted before the device call and
    un-permuted after: a determinism audit knob proving lane results don't
    depend on batch position (the engine's per-lane independence contract).
    """
    n = int(nbrs_dev.shape[0])
    out = []
    total = queries.shape[0]
    for s in range(0, total, build_batch):
        q = queries[s:s + build_batch]
        b = q.shape[0]
        if b < build_batch:
            q = np.concatenate(
                [q, np.repeat(q[:1], build_batch - b, axis=0)])
        if batch_perm is not None:
            perm = np.random.RandomState(batch_perm + s).permutation(
                build_batch)
            res = np.asarray(_candidate_search_batch(
                nbrs_dev, vectors_dev, entry_dev, jnp.asarray(q[perm]),
                cfg, pool))
            unperm = np.empty_like(res)
            unperm[perm] = res
            res = unperm
        else:
            res = np.asarray(_candidate_search_batch(
                nbrs_dev, vectors_dev, entry_dev, jnp.asarray(q), cfg,
                pool))
        out.append(_visited_to_rows(res[:b], n)
                   if pool == "visited" else res[:b].astype(np.int32))
    width = max(c.shape[1] for c in out)
    out = [np.pad(c, ((0, 0), (0, width - c.shape[1])),
                  constant_values=n) if c.shape[1] < width else c
           for c in out]
    return np.concatenate(out, axis=0)


def _canonical_candidates(ids: np.ndarray, cur: np.ndarray,
                          node_ids: np.ndarray, n: int) -> np.ndarray:
    """Merge search results with current neighbors into the canonical
    candidate form: per row ascending unique ids, self and invalid entries
    mapped to the sentinel ``n``, sentinel-padded to fixed width.

    Canonicalization is what buys batch invariance: however the search
    chunks delivered the ids, every row enters the prune as the same
    ascending set — matching the ``np.unique`` ordering of the serial
    reference.
    """
    allc = np.concatenate([ids, cur], axis=1).astype(np.int64)
    allc = np.where((allc < 0) | (allc >= n), n, allc)
    allc = np.where(allc == node_ids[:, None], n, allc)
    allc = np.sort(allc, axis=1)
    dup = np.zeros(allc.shape, bool)
    dup[:, 1:] = allc[:, 1:] == allc[:, :-1]
    allc = np.sort(np.where(dup, n, allc), axis=1)
    return allc.astype(np.int32)


# ---------------------------------------------------------------------------
# Round application: forward prune + deterministic reverse edges
# ---------------------------------------------------------------------------

_PRUNE_CHUNK = 2048   # rows per robust_prune_batch call (bounds B·C·d memory)


def _prune_round(data: np.ndarray, node_ids: np.ndarray, cand: np.ndarray,
                 degree: int, alpha: float, metric: str,
                 serial: bool) -> np.ndarray:
    """α-prune every row of a round; returns (B, degree) sentinel-padded."""
    n = data.shape[0]
    if serial:
        out = np.full((node_ids.shape[0], degree), n, np.int32)
        for i, node in enumerate(node_ids):
            c = cand[i][cand[i] < n]
            d = _prune_dists(data, c, data[node], metric)
            kept = _robust_prune(data, int(node), c, d, degree, alpha,
                                 metric=metric)
            out[i, :kept.shape[0]] = kept
        return out
    chunks = [robust_prune_batch(data, node_ids[s:s + _PRUNE_CHUNK],
                                 cand[s:s + _PRUNE_CHUNK], degree, alpha,
                                 metric=metric)
              for s in range(0, node_ids.shape[0], _PRUNE_CHUNK)]
    return np.concatenate(chunks, axis=0)


def _apply_reverse(nbrs: np.ndarray, data: np.ndarray,
                   round_ids: np.ndarray, pruned: np.ndarray,
                   degree: int, alpha: float, metric: str,
                   serial: bool) -> None:
    """Apply a round's reverse edges p -> u for every forward edge u in
    pruned[p], mutating ``nbrs`` rows of the targets u in place.

    Determinism rule: collect ALL (u, p) pairs of the round, lexsort by
    (u, p), then per target u (ascending) append the fresh in-neighbors in
    ascending-p order; on overflow past ``degree`` the row is re-pruned
    ONCE over the ascending unique union — lowest-id-first at every tie, so
    the result is independent of how the round was batched.
    """
    n = data.shape[0]
    valid = pruned < n
    if not valid.any():
        return
    u_arr = pruned[valid]
    p_arr = np.repeat(round_ids, valid.sum(axis=1))
    order = np.lexsort((p_arr, u_arr))
    u_arr, p_arr = u_arr[order], p_arr[order]
    targets, starts = np.unique(u_arr, return_index=True)
    bounds = np.append(starts, u_arr.shape[0])

    over_nodes: List[int] = []
    over_cands: List[np.ndarray] = []
    for t, u in enumerate(targets):
        u = int(u)
        incoming = p_arr[bounds[t]:bounds[t + 1]]
        cur = nbrs[u][nbrs[u] < n]
        fresh = np.setdiff1d(incoming, cur)       # sorted unique, asc p
        fresh = fresh[fresh != u]
        if fresh.shape[0] == 0:
            continue
        if cur.shape[0] + fresh.shape[0] <= degree:
            row = np.concatenate([cur, fresh])
            nbrs[u, :row.shape[0]] = row
            nbrs[u, row.shape[0]:] = n
            continue
        cand = np.unique(np.concatenate([cur, fresh]))
        cand = cand[cand != u]
        if serial:
            d = _prune_dists(data, cand, data[u], metric)
            kept = _robust_prune(data, u, cand, d, degree, alpha,
                                 metric=metric)
            nbrs[u, :kept.shape[0]] = kept
            nbrs[u, kept.shape[0]:] = n
        else:
            over_nodes.append(u)
            over_cands.append(cand)
    if over_nodes:
        width = max(c.shape[0] for c in over_cands)
        cmat = np.full((len(over_nodes), width), n, np.int32)
        for i, c in enumerate(over_cands):
            cmat[i, :c.shape[0]] = c
        node_arr = np.asarray(over_nodes, np.int64)
        for s in range(0, node_arr.shape[0], _PRUNE_CHUNK):
            kept = robust_prune_batch(
                data, node_arr[s:s + _PRUNE_CHUNK],
                cmat[s:s + _PRUNE_CHUNK], degree, alpha, metric=metric)
            nbrs[node_arr[s:s + _PRUNE_CHUNK]] = kept


# ---------------------------------------------------------------------------
# Batch insertion (ParlayANN-style) + refinement
# ---------------------------------------------------------------------------

def insert_points(
    nbrs: np.ndarray,
    data: np.ndarray,
    entry: int,
    new_ids: np.ndarray,
    n_base: int,
    *,
    degree: int,
    alpha: float,
    ef: int,
    metric: str,
    build_batch: int = 32,
    build_backend: str = "ref",
    serial: bool = False,
    batch_perm: Optional[int] = None,
) -> None:
    """Insert ``new_ids`` (in order) into the live padded adjacency
    ``nbrs`` (mutated in place) by prefix-doubling batch insertion.

    ``nbrs`` is the full (N, degree) int32 table, sentinel-padded;
    not-yet-inserted rows must be fully sentinel.  ``n_base`` is how many
    points are already live (0 for a fresh build — the first new id then
    bootstraps the graph bare).  Every round: ONE batch-major candidate
    search per ``build_batch`` chunk against the frozen snapshot, a
    vectorized α-prune of the whole round, then the deterministic reverse
    pass.  Round sizes double from the live count, so the schedule — and
    therefore the graph — depends only on the insertion order, never on
    ``build_batch``.
    """
    new_ids = np.asarray(new_ids, np.int64)
    n = data.shape[0]
    cfg = _build_search_config(ef, metric, build_backend)
    vectors_dev = jnp.asarray(data)
    entry_dev = jnp.asarray(entry, jnp.int32)

    pos = 0
    inserted = n_base
    if inserted == 0 and new_ids.shape[0] > 0:
        nbrs[new_ids[0]] = n          # bootstrap: first point, no edges
        pos, inserted = 1, 1
    while pos < new_ids.shape[0]:
        take = min(inserted, new_ids.shape[0] - pos)
        _process_round(nbrs, data, vectors_dev, entry_dev,
                       new_ids[pos:pos + take], cfg, degree, alpha, metric,
                       build_batch, serial, batch_perm)
        pos += take
        inserted += take


def _process_round(
    nbrs: np.ndarray, data: np.ndarray, vectors_dev: jax.Array,
    entry_dev: jax.Array, round_ids: np.ndarray, cfg: SearchConfig,
    degree: int, alpha: float, metric: str, build_batch: int,
    serial: bool, batch_perm: Optional[int], pool: str = "visited",
) -> None:
    """One build round: search the frozen snapshot for every round point,
    α-prune each over its candidate pool ∪ current row, write the forward
    rows, then run the deterministic reverse pass."""
    n = data.shape[0]
    vis = _search_candidates(
        jnp.asarray(nbrs), vectors_dev, entry_dev, data[round_ids], cfg,
        build_batch, batch_perm, pool)
    cand = _canonical_candidates(vis, nbrs[round_ids], round_ids, n)
    pruned = _prune_round(data, round_ids, cand, degree, alpha, metric,
                          serial)
    nbrs[round_ids] = pruned
    _apply_reverse(nbrs, data, round_ids, pruned, degree, alpha, metric,
                   serial)


def _refine_pass(
    nbrs: np.ndarray, data: np.ndarray, entry: int, order: np.ndarray, *,
    degree: int, alpha: float, ef: int, metric: str,
    build_batch: int, build_backend: str, serial: bool,
    batch_perm: Optional[int],
) -> None:
    """One refinement pass: every vertex is re-processed in the SAME
    doubling round partition as insertion (1, 1, 2, 4, ...), each round
    searching the graph as left by the previous rounds.  Gauss-Seidel at
    round granularity: later rounds see earlier rounds' refined rows —
    replacing all rows against one frozen snapshot (Jacobi) measurably
    degrades navigability, because simultaneous replacement severs the
    in/out-edge interdependencies the insertion pass built up.  The round
    partition is fixed by ``order`` alone, so the pass stays deterministic
    and ``build_batch``-invariant.

    Refinement prunes over the NARROW ``"results"`` pool (top-ef results ∪
    current row — the seed builder's pass semantics), not the visited set:
    on a fully built graph the visited pool is so rich in near candidates
    that a degree-capped re-prune fills every slot locally and evicts the
    long-range descent edges the insertion pass created (measured: the
    entry point's longest edge shrinks ~3x and beam recall collapses).
    The narrow pool polishes local structure while incumbent long edges
    keep their slots."""
    cfg = _build_search_config(ef, metric, build_backend)
    vectors_dev = jnp.asarray(data)
    entry_dev = jnp.asarray(entry, jnp.int32)
    pos, step = 0, 1
    while pos < order.shape[0]:
        take = min(step, order.shape[0] - pos)
        _process_round(nbrs, data, vectors_dev, entry_dev,
                       order[pos:pos + take], cfg, degree, alpha, metric,
                       build_batch, serial, batch_perm, pool="results")
        pos += take
        step *= 2


def build_nsg(
    data: np.ndarray,
    degree: int = 32,
    knn_k: int = 32,
    alpha: float = 1.2,
    ef_construction: int = 64,
    seed: int = 0,
    passes: int = 2,
    metric: str = "l2",
    build_batch: int = 32,
    build_backend: str = "ref",
    batch_perm: Optional[int] = None,
    serial: bool = False,
) -> PaddedCSR:
    """Vamana/NSG-style construction by batched prefix-doubling insertion
    (medoid-first random order) plus ``passes - 1`` synchronous α-pruned
    refinement passes.  The insertion pass prunes with α=1 when refinement
    follows (the seed builder's schedule); a single-pass build prunes with
    ``alpha`` directly.

    ``metric``: "l2" (default), "ip" (MIPS graph — ip-NSW-style pruning on
    negative-inner-product distances), or "cosine" (the base vectors are
    unit-normalized HERE and the graph built with l2, which orders
    identically to cosine on the unit sphere — the returned index stores
    the normalized vectors).

    ``knn_k`` is accepted for signature compatibility with the seed
    builder; batch insertion needs no kNN seed graph, so it is ignored.
    ``build_batch`` tiles the device-side candidate searches and
    ``build_backend`` picks their distance kernel — neither changes a
    single output bit (``tests/test_build_batch.py``).  ``batch_perm``
    shuffles each search chunk (and unshuffles results): the determinism
    audit knob.  ``serial`` switches to the scalar per-point reference
    kernels (see :func:`build_nsg_serial`).
    """
    del knn_k
    n = data.shape[0]
    data = np.asarray(data, np.float32)
    if metric == "cosine":
        data = normalize_rows(data)
        metric = "l2"
    elif metric not in ("l2", "ip"):
        raise ValueError(f"unknown metric {metric!r}")
    medoid = compute_medoid(data, metric=metric)
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    order = np.concatenate([[medoid], perm[perm != medoid]])
    nbrs = np.full((n, degree), n, np.int32)
    kw = dict(degree=degree, ef=ef_construction, metric=metric,
              build_batch=build_batch, build_backend=build_backend,
              serial=serial, batch_perm=batch_perm)
    a_ins = alpha if passes <= 1 else 1.0
    insert_points(nbrs, data, medoid, order, 0, alpha=a_ins, **kw)
    for _ in range(max(passes - 1, 0)):
        _refine_pass(nbrs, data, medoid, order, alpha=alpha, **kw)
    return make_padded_csr(nbrs, data, medoid=medoid)


def build_nsg_serial(
    data: np.ndarray,
    degree: int = 32,
    knn_k: int = 32,
    alpha: float = 1.2,
    ef_construction: int = 64,
    seed: int = 0,
    passes: int = 2,
    metric: str = "l2",
) -> PaddedCSR:
    """Per-point reference builder: identical round schedule and candidate
    searches to :func:`build_nsg`, but every prune runs the scalar
    :func:`_robust_prune` loop and reverse edges apply one target at a
    time.  ``build_nsg(..., build_batch=1)`` must reproduce its output bit
    for bit — the batched path's correctness oracle."""
    return build_nsg(
        data, degree=degree, knn_k=knn_k, alpha=alpha,
        ef_construction=ef_construction, seed=seed, passes=passes,
        metric=metric, build_batch=1, serial=True)


# ---------------------------------------------------------------------------
# Incremental maintenance: tombstone-delete repair
# ---------------------------------------------------------------------------

def repair_deleted(
    nbrs: np.ndarray,
    data: np.ndarray,
    tombstone: np.ndarray,
    *,
    degree: int,
    alpha: float,
    metric: str,
    serial: bool = False,
) -> int:
    """Repair the neighborhood of tombstoned vertices (FreshDiskANN-style).

    Every live in-neighbor u of a deleted vertex d re-prunes over
    ``(nbrs[u] \\ deleted) ∪ (nbrs[d] \\ deleted \\ {u})`` — u inherits its
    dead neighbors' out-edges so paths THROUGH d stay representable, then
    the α-prune restores the degree bound.  Deleted rows keep their
    out-edges (they remain navigable waypoints; search masks them from
    results).  All affected rows are collected against the pre-repair
    snapshot and pruned in one vectorized call — deterministic, order-free.
    Returns the number of repaired rows.
    """
    n = data.shape[0]
    tombstone = np.asarray(tombstone, bool)
    deleted = np.where(tombstone)[0]
    if deleted.shape[0] == 0:
        return 0
    snapshot = nbrs.copy()
    dead_edge = (snapshot < n) & tombstone[np.minimum(snapshot, n - 1)]
    affected = np.where(dead_edge.any(axis=1) & ~tombstone)[0]
    if affected.shape[0] == 0:
        return 0

    cands: List[np.ndarray] = []
    for u in affected:
        row = snapshot[u][snapshot[u] < n]
        keepers = row[~tombstone[row]]
        inherited = snapshot[row[tombstone[row]]].ravel()
        inherited = inherited[inherited < n]
        inherited = inherited[~tombstone[inherited]]
        cand = np.unique(np.concatenate([keepers, inherited]))
        cand = cand[cand != u]
        cands.append(cand)
    width = max(max(c.shape[0] for c in cands), 1)
    cmat = np.full((affected.shape[0], width), n, np.int32)
    for i, c in enumerate(cands):
        cmat[i, :c.shape[0]] = c
    if serial:
        for i, u in enumerate(affected):
            c = cmat[i][cmat[i] < n]
            d = _prune_dists(data, c, data[u], metric)
            kept = _robust_prune(data, int(u), c, d, degree, alpha,
                                 metric=metric)
            nbrs[u, :kept.shape[0]] = kept
            nbrs[u, kept.shape[0]:] = n
    else:
        for s in range(0, affected.shape[0], _PRUNE_CHUNK):
            kept = robust_prune_batch(
                data, affected[s:s + _PRUNE_CHUNK].astype(np.int64),
                cmat[s:s + _PRUNE_CHUNK], degree, alpha, metric=metric)
            nbrs[affected[s:s + _PRUNE_CHUNK]] = kept
    return int(affected.shape[0])


# ---------------------------------------------------------------------------
# HNSW-style hierarchical index (the paper's second baseline)
# ---------------------------------------------------------------------------

class HNSWIndex(NamedTuple):
    base: PaddedCSR                 # level-0 graph (searched with BFiS)
    level_nbrs: Tuple[jax.Array, ...]   # per upper level: (N, R_l) int32
    level_nodes: Tuple[jax.Array, ...]  # per upper level: member node ids
    entry: int


def _upper_level_ids(sub_knn: np.ndarray, members: np.ndarray,
                     n: int) -> np.ndarray:
    """Map a sub-index kNN table onto global ids via a lookup table whose
    last entry IS the global sentinel: sub-sentinel rows (value ==
    len(members), from duplicate members) land on ``n`` and can never
    alias a real member id."""
    lut = np.concatenate(
        [members.astype(np.int64), np.asarray([n], np.int64)])
    return lut[np.minimum(sub_knn, members.shape[0])].astype(np.int32)


def build_hnsw(
    data: np.ndarray,
    degree: int = 32,
    upper_degree: int = 16,
    ml: float = 0.36,                # 1/ln(M) with M=16
    seed: int = 0,
    alpha: float = 1.2,
    metric: str = "l2",
    build_batch: int = 32,
    build_backend: str = "ref",
) -> HNSWIndex:
    """Simplified HNSW: geometric level sampling; each upper level is an
    α-pruned kNN graph over its members; level 0 reuses the (batched) NSG
    builder.  ``metric`` as in :func:`build_nsg` (cosine normalizes here)."""
    n = data.shape[0]
    data = np.asarray(data, np.float32)
    if metric == "cosine":
        data = normalize_rows(data)
        metric = "l2"
    rng = np.random.RandomState(seed)
    levels = np.minimum(
        (-np.log(np.maximum(rng.uniform(size=n), 1e-12)) * ml).astype(int), 6)
    base = build_nsg(data, degree=degree, alpha=alpha, seed=seed, passes=2,
                     metric=metric, build_batch=build_batch,
                     build_backend=build_backend)
    level_nbrs, level_nodes = [], []
    max_level = int(levels.max())
    entry = int(np.argmax(levels))
    for lvl in range(1, max_level + 1):
        members = np.where(levels >= lvl)[0].astype(np.int32)
        if members.shape[0] < 2:
            break
        sub = data[members]
        k = min(upper_degree, members.shape[0] - 1)
        sub_knn = knn_graph(sub, k, metric=metric)
        g = _upper_level_ids(sub_knn, members, n)
        full = np.full((n, upper_degree), n, np.int32)
        full[members, :k] = g
        level_nbrs.append(jnp.asarray(full))
        level_nodes.append(jnp.asarray(members))
    return HNSWIndex(base=base, level_nbrs=tuple(level_nbrs),
                     level_nodes=tuple(level_nodes), entry=entry)
