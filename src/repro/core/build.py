"""Similarity-graph index construction.

The paper builds on NSG's construction ("not the focus of this work", §2.2) —
we therefore provide faithful-but-compact builders so the system is complete:

* blocked exact kNN (JAX matmul-based; also used for ground truth),
* NSG/Vamana-style α-pruned graph (monotonic-RNG heuristic, two passes from
  the medoid, reverse-edge augmentation) — the "NSG" index,
* a hierarchical (HNSW-style) index: geometric level assignment, per-level
  pruned graphs, greedy upper-level descent — the "HNSW" baseline index.

Construction is offline; numpy is acceptable here (the paper's own builders
are offline C++).  Search-time code never calls into this module.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import PaddedCSR, compute_medoid, make_padded_csr


# ---------------------------------------------------------------------------
# Exact kNN (blocked brute force) — ground truth + kNN-graph seed
# ---------------------------------------------------------------------------

def normalize_rows(x: np.ndarray) -> np.ndarray:
    """Unit-normalize rows (cosine = inner product on normalized vectors)."""
    x = np.asarray(x, np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)


@functools.partial(jax.jit, static_argnames=("metric",))
def _dist_block(q: jax.Array, x: jax.Array, metric: str = "l2") -> jax.Array:
    """(B, N) distances between query block and data block; smaller =
    closer for every metric ("ip" = negative inner product)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if metric == "ip":
        return -(q @ x.T)
    q2 = jnp.sum(q * q, axis=1, keepdims=True)
    x2 = jnp.sum(x * x, axis=1)
    return q2 + x2[None, :] - 2.0 * (q @ x.T)


def exact_knn(
    data: np.ndarray, queries: np.ndarray, k: int, block: int = 2048,
    metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact k nearest neighbors of ``queries`` within ``data``.

    ``metric`` is "l2" (squared L2), "ip" (negative inner product), or
    "cosine" (ip after normalizing BOTH sides here).  Returns
    (ids (Q, k) int32, dists (Q, k) float32) sorted ascending.
    """
    if metric == "cosine":
        data, queries = normalize_rows(data), normalize_rows(queries)
        metric = "ip"
    data_j = jnp.asarray(data)
    out_ids, out_d = [], []
    for s in range(0, queries.shape[0], block):
        q = jnp.asarray(queries[s:s + block])
        d = _dist_block(q, data_j, metric=metric)     # (b, N)
        d_top, i_top = jax.lax.top_k(-d, k)
        out_ids.append(np.asarray(i_top, np.int32))
        out_d.append(np.asarray(-d_top, np.float32))
    return np.concatenate(out_ids), np.concatenate(out_d)


def knn_graph(data: np.ndarray, k: int, block: int = 2048,
              metric: str = "l2") -> np.ndarray:
    """(N, k) kNN graph excluding self-edges."""
    ids, _ = exact_knn(data, data, k + 1, block, metric=metric)
    n = data.shape[0]
    rows = []
    for i in range(n):
        row = ids[i][ids[i] != i][:k]
        if row.shape[0] < k:  # duplicate points: pad with sentinel
            row = np.concatenate([row, np.full(k - row.shape[0], n, np.int32)])
        rows.append(row)
    return np.stack(rows).astype(np.int32)


# ---------------------------------------------------------------------------
# NSG/Vamana-style α-pruned graph
# ---------------------------------------------------------------------------

def _prune_dists(data: np.ndarray, ids: np.ndarray, point: np.ndarray,
                 metric: str) -> np.ndarray:
    """Distances of data[ids] to ``point`` on the builder's pruning scale
    (actual L2 for "l2", negative inner product for "ip")."""
    if metric == "ip":
        return -(data[ids] @ point)
    diff = data[ids] - point
    return np.sqrt(np.maximum(np.einsum("ij,ij->i", diff, diff), 0.0))


def _robust_prune(
    data: np.ndarray, node: int, cand_ids: np.ndarray, cand_d: np.ndarray,
    degree: int, alpha: float, metric: str = "l2",
) -> np.ndarray:
    """Monotonic-RNG α-prune: greedily keep the closest candidate c, then
    drop every remaining candidate c' with α·d(c, c') ≤ d(node, c').

    For "ip" the same occlusion rule runs on negative-inner-product
    distances (the ip-NSW heuristic) with α forced to 1: scaling negative
    distances would invert the α>1 "keep more" semantics."""
    order = np.argsort(cand_d, kind="stable")
    cand_ids = cand_ids[order]
    cand_d = cand_d[order]
    eff_alpha = 1.0 if metric == "ip" else alpha
    keep: List[int] = []
    alive = np.ones(cand_ids.shape[0], bool)
    alive &= cand_ids != node
    for i in range(cand_ids.shape[0]):
        if not alive[i]:
            continue
        c = int(cand_ids[i])
        keep.append(c)
        if len(keep) >= degree:
            break
        # occlusion rule: drop c' when c is much closer to c' than node is
        d_cc = _prune_dists(data, cand_ids, data[c], metric)
        alive = alive & ~(eff_alpha * d_cc <= cand_d)
        alive[i] = False
    return np.asarray(keep, np.int32)


def _greedy_search_np(
    data: np.ndarray, nbrs: List[np.ndarray], start: int, q: np.ndarray,
    ef: int, metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side best-first search used during construction (Vamana pass)."""
    import heapq

    if metric == "ip":
        def pd(u):
            return -float(data[u] @ q)
    else:
        def pd(u):
            return float(np.sum((data[u] - q) ** 2))

    d0 = pd(start)
    cand = [(d0, start)]
    visited = {start}
    best: List[Tuple[float, int]] = [(-d0, start)]
    while cand:
        d, v = heapq.heappop(cand)
        if -best[0][0] < d and len(best) >= ef:
            break
        for u in nbrs[v]:
            u = int(u)
            if u in visited or u >= data.shape[0]:
                continue
            visited.add(u)
            du = pd(u)
            if len(best) < ef or du < -best[0][0]:
                heapq.heappush(cand, (du, u))
                heapq.heappush(best, (-du, u))
                if len(best) > ef:
                    heapq.heappop(best)
    out = sorted([(-negd, u) for negd, u in best])
    ids = np.asarray([u for _, u in out], np.int32)
    ds = np.asarray([d for d, _ in out], np.float32)
    return ids, ds


def build_nsg(
    data: np.ndarray,
    degree: int = 32,
    knn_k: int = 32,
    alpha: float = 1.2,
    ef_construction: int = 64,
    seed: int = 0,
    passes: int = 2,
    metric: str = "l2",
) -> PaddedCSR:
    """Vamana/NSG-style construction: kNN seed + α-pruned refinement passes
    from the medoid + reverse-edge augmentation with re-pruning.

    ``metric``: "l2" (default), "ip" (MIPS graph — ip-NSW-style pruning on
    negative-inner-product distances), or "cosine" (the base vectors are
    unit-normalized HERE and the graph built with l2, which orders
    identically to cosine on the unit sphere — the returned index stores
    the normalized vectors).
    """
    n = data.shape[0]
    data = np.asarray(data, np.float32)
    if metric == "cosine":
        data = normalize_rows(data)
        metric = "l2"
    elif metric not in ("l2", "ip"):
        raise ValueError(f"unknown metric {metric!r}")
    knn = knn_graph(data, knn_k, metric=metric)
    nbrs: List[np.ndarray] = [knn[i][knn[i] < n] for i in range(n)]
    medoid = compute_medoid(data, metric=metric)
    rng = np.random.RandomState(seed)

    for p in range(passes):
        a = 1.0 if p == 0 else alpha
        order = rng.permutation(n)
        for node in order:
            cand_ids, cand_d = _greedy_search_np(
                data, nbrs, medoid, data[node], ef_construction,
                metric=metric)
            # include current neighbors as candidates
            cur = nbrs[node]
            allc = np.unique(np.concatenate([cand_ids, cur]))
            allc = allc[allc != node]
            d = _prune_dists(data, allc, data[node], metric)
            pruned = _robust_prune(data, node, allc, d, degree, a,
                                   metric=metric)
            nbrs[node] = pruned
            # reverse edges with degree cap + re-prune
            for u in pruned:
                u = int(u)
                if node in nbrs[u]:
                    continue
                lst = np.concatenate([nbrs[u], [node]])
                if lst.shape[0] > degree:
                    d_u = _prune_dists(data, lst, data[u], metric)
                    lst = _robust_prune(data, u, lst, d_u, degree, a,
                                        metric=metric)
                nbrs[u] = lst.astype(np.int32)

    padded = np.full((n, degree), n, np.int32)
    for i in range(n):
        m = min(len(nbrs[i]), degree)
        padded[i, :m] = nbrs[i][:m]
    return make_padded_csr(padded, data, medoid=medoid)


# ---------------------------------------------------------------------------
# HNSW-style hierarchical index (the paper's second baseline)
# ---------------------------------------------------------------------------

class HNSWIndex(NamedTuple):
    base: PaddedCSR                 # level-0 graph (searched with BFiS)
    level_nbrs: Tuple[jax.Array, ...]   # per upper level: (N, R_l) int32
    level_nodes: Tuple[jax.Array, ...]  # per upper level: member node ids
    entry: int


def build_hnsw(
    data: np.ndarray,
    degree: int = 32,
    upper_degree: int = 16,
    ml: float = 0.36,                # 1/ln(M) with M=16
    seed: int = 0,
    alpha: float = 1.2,
    metric: str = "l2",
) -> HNSWIndex:
    """Simplified HNSW: geometric level sampling; each upper level is an
    α-pruned kNN graph over its members; level 0 reuses the NSG builder.
    ``metric`` as in :func:`build_nsg` (cosine normalizes here)."""
    n = data.shape[0]
    data = np.asarray(data, np.float32)
    if metric == "cosine":
        data = normalize_rows(data)
        metric = "l2"
    rng = np.random.RandomState(seed)
    levels = np.minimum(
        (-np.log(np.maximum(rng.uniform(size=n), 1e-12)) * ml).astype(int), 6)
    base = build_nsg(data, degree=degree, alpha=alpha, seed=seed, passes=2,
                     metric=metric)
    level_nbrs, level_nodes = [], []
    max_level = int(levels.max())
    entry = int(np.argmax(levels))
    for lvl in range(1, max_level + 1):
        members = np.where(levels >= lvl)[0].astype(np.int32)
        if members.shape[0] < 2:
            break
        sub = data[members]
        k = min(upper_degree, members.shape[0] - 1)
        sub_knn = knn_graph(sub, k, metric=metric)
        # map back to global ids, pad with n
        g = np.where(sub_knn < members.shape[0], members[np.minimum(
            sub_knn, members.shape[0] - 1)], n).astype(np.int32)
        full = np.full((n, upper_degree), n, np.int32)
        full[members, :k] = g
        level_nbrs.append(jnp.asarray(full))
        level_nodes.append(jnp.asarray(members))
    return HNSWIndex(base=base, level_nbrs=tuple(level_nbrs),
                     level_nodes=tuple(level_nodes), entry=entry)
