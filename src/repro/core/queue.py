"""Bounded sorted frontier ("priority queue S" of Algorithm 1/3).

The paper's queue is a capacity-L array kept sorted by distance, supporting:
  * insert a batch of candidates, dedup by id, truncate to L   (Line 13/19)
  * select + mark the first M unchecked entries                (Line 6/12)
  * report the *update position* of an insertion               (§4.3)

All ops are fixed-shape and jit/vmap-friendly.  Sort order is (dist, id)
ascending; empty slots carry dist=+inf / id=INT32_MAX so they sort last.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INVALID_ID = jnp.int32(2**31 - 1)
INF = jnp.float32(jnp.inf)


class Frontier(NamedTuple):
    ids: jax.Array      # (L,) int32, INVALID_ID for empty slots
    dists: jax.Array    # (L,) float32, +inf for empty slots
    checked: jax.Array  # (L,) bool, True for empty slots (never selectable)


def make_frontier(capacity: int) -> Frontier:
    return Frontier(
        ids=jnp.full((capacity,), INVALID_ID, jnp.int32),
        dists=jnp.full((capacity,), INF, jnp.float32),
        checked=jnp.ones((capacity,), bool),
    )


def frontier_valid(f: Frontier) -> jax.Array:
    return f.ids != INVALID_ID


def _sort_by(keys1, keys2, *payload):
    """Stable co-sort by (keys1, keys2) ascending."""
    out = jax.lax.sort((keys1, keys2) + tuple(payload), num_keys=2,
                       is_stable=True)
    return out


def insert(
    f: Frontier, new_ids: jax.Array, new_dists: jax.Array
) -> Tuple[Frontier, jax.Array, jax.Array]:
    """Merge candidates into the frontier.

    Candidates with id >= INVALID_ID or dist == +inf are ignored.  Duplicate
    ids collapse to a single entry, preferring an existing (possibly checked)
    queue entry over a fresh one, so a vertex is never re-expanded after a
    merge (the paper's eventual-consistency guarantee, §4.4).

    Returns ``(frontier', update_position, n_inserted)`` where
    ``update_position`` is the best (lowest) rank among surviving *new*
    entries, saturating at L when nothing improved — the §4.3 sync metric.
    """
    cap = f.ids.shape[0]
    new_ids = new_ids.astype(jnp.int32)
    new_dists = new_dists.astype(jnp.float32)
    bad = (new_ids < 0) | (new_ids == INVALID_ID) | ~jnp.isfinite(new_dists)
    new_ids = jnp.where(bad, INVALID_ID, new_ids)
    new_dists = jnp.where(bad, INF, new_dists)

    ids = jnp.concatenate([f.ids, new_ids])
    dists = jnp.concatenate([f.dists, new_dists])
    checked = jnp.concatenate(
        [f.checked, jnp.zeros(new_ids.shape, bool)])
    is_new = jnp.concatenate(
        [jnp.zeros(f.ids.shape, jnp.int32), jnp.ones(new_ids.shape, jnp.int32)])

    # Pass 1: group by id (old entries first within a group), drop duplicates.
    ids, is_new, dists, checked8 = _sort_by(
        ids, is_new, dists, checked.astype(jnp.int32))
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (ids[1:] == ids[:-1]) & (ids[1:] != INVALID_ID)])
    ids = jnp.where(dup, INVALID_ID, ids)
    dists = jnp.where(dup, INF, dists)

    # Pass 2: re-sort by (dist, id); truncate to capacity.
    dists, ids, checked8, is_new = _sort_by(dists, ids, checked8, is_new)
    kept = Frontier(ids=ids[:cap], dists=dists[:cap],
                    checked=(checked8[:cap] == 1) | (ids[:cap] == INVALID_ID))

    rank = jnp.arange(ids.shape[0], dtype=jnp.int32)
    surviving_new = (is_new == 1) & (ids != INVALID_ID) & (rank < cap)
    update_pos = jnp.min(jnp.where(surviving_new, rank, cap))
    n_inserted = jnp.sum(surviving_new).astype(jnp.int32)
    return kept, update_pos.astype(jnp.int32), n_inserted


def select_unchecked(
    f: Frontier, m_max: int, m: jax.Array | int | None = None
) -> Tuple[Frontier, jax.Array, jax.Array]:
    """Select and mark-checked the first ``m`` unchecked entries (Line 6/12).

    ``m_max`` is the static slot count; ``m`` (traced, <= m_max) masks the
    dynamic expansion width for staged search.  Returns
    ``(frontier', active_ids (m_max,), active_valid (m_max,) bool)``;
    inactive slots carry INVALID_ID.
    """
    if m is None:
        m = m_max
    unchecked = ~f.checked & (f.ids != INVALID_ID)
    # Stable argsort puts unchecked slots first, preserving dist order.
    order = jnp.argsort(~unchecked, stable=True)
    sel_pos = order[:m_max]                                  # (m_max,)
    in_budget = jnp.arange(m_max) < m
    active_valid = unchecked[sel_pos] & in_budget
    active_ids = jnp.where(active_valid, f.ids[sel_pos], INVALID_ID)
    new_checked = f.checked.at[sel_pos].set(
        f.checked[sel_pos] | active_valid)
    return f._replace(checked=new_checked), active_ids, active_valid


def has_unchecked(f: Frontier) -> jax.Array:
    return jnp.any(~f.checked & (f.ids != INVALID_ID))


def top_k_stable(f: Frontier, k: int) -> jax.Array:
    """First K entries are all checked — Algorithm 1's convergence test."""
    idx = jnp.arange(f.ids.shape[0]) < k
    return ~jnp.any(idx & ~f.checked & (f.ids != INVALID_ID))


def results(f: Frontier, k: int) -> Tuple[jax.Array, jax.Array]:
    """The first K (id, dist) pairs — Algorithm 1 Line 14."""
    return f.ids[:k], f.dists[:k]


# ---------------------------------------------------------------------------
# Multi-queue (walker) operations — Algorithm 3 Lines 7 and 23
# ---------------------------------------------------------------------------

def scatter_round_robin(
    f: Frontier, num_walkers: int, active: jax.Array | int | None = None,
) -> Frontier:
    """Divide unchecked candidates among walkers (Line 7).

    Walker w receives the unchecked entries whose *unchecked-rank* ≡ w
    (mod ``active``) — the paper's even division — plus every checked entry
    (read-only context so each walker sees current best results).  ``active``
    (traced, <= num_walkers) is the staged worker count M; walkers >= active
    receive no work.  Returned frontier is stacked: (W, L).
    """
    if active is None:
        active = num_walkers
    active = jnp.maximum(jnp.asarray(active, jnp.int32), 1)
    unchecked = ~f.checked & (f.ids != INVALID_ID)
    # rank among unchecked, by queue (distance) order
    ranks = jnp.cumsum(unchecked.astype(jnp.int32)) - 1
    owner = jnp.where(unchecked, ranks % active, -1)

    def one(w):
        keep = owner == w
        # checked entries are shared (read-only) context; unchecked entries go
        # to their owner only
        shared = f.checked & (f.ids != INVALID_ID)
        ids = jnp.where(keep | shared, f.ids, INVALID_ID)
        dists = jnp.where(keep | shared, f.dists, INF)
        checked = jnp.where(keep, False, True)
        # re-sort so each local queue is contiguous / ordered
        dists, ids, checked8 = _sort_by(dists, ids, checked.astype(jnp.int32))
        return Frontier(ids=ids, dists=dists,
                        checked=(checked8 == 1) | (ids == INVALID_ID))

    return jax.vmap(one)(jnp.arange(num_walkers))


# ---------------------------------------------------------------------------
# Batch-major operations — leading (B,) query axis on every leaf
# ---------------------------------------------------------------------------
#
# The batch-major traversal engine (core.bfis / core.speedann) keeps ONE
# frontier per query stacked on a leading batch axis and advances the whole
# batch per global step.  These wrappers are ``jax.vmap`` of the single-query
# ops above — bit-identical to the per-query path by construction (vmap of a
# sort/gather is the batched sort/gather), while XLA fuses the batch into
# single wide ops.

def make_frontier_batch(capacity: int, batch: int) -> Frontier:
    """A stacked (B, L) frontier; every row is ``make_frontier(capacity)``."""
    return Frontier(
        ids=jnp.full((batch, capacity), INVALID_ID, jnp.int32),
        dists=jnp.full((batch, capacity), INF, jnp.float32),
        checked=jnp.ones((batch, capacity), bool),
    )


def insert_batch(f: Frontier, new_ids: jax.Array, new_dists: jax.Array
                 ) -> Tuple[Frontier, jax.Array, jax.Array]:
    """:func:`insert` over a (B, L) frontier and (B, C) candidates."""
    return jax.vmap(insert)(f, new_ids, new_dists)


def select_unchecked_batch(
    f: Frontier, m_max: int, m: jax.Array | int | None = None
) -> Tuple[Frontier, jax.Array, jax.Array]:
    """:func:`select_unchecked` over (B, L); ``m`` may be per-query (B,)."""
    if m is None:
        m = m_max
    m = jnp.broadcast_to(jnp.asarray(m, jnp.int32), (f.ids.shape[0],))
    return jax.vmap(lambda fr, mm: select_unchecked(fr, m_max, mm))(f, m)


def has_unchecked_batch(f: Frontier) -> jax.Array:
    """(B,) bool: per-query :func:`has_unchecked` on a stacked frontier."""
    return jnp.any(~f.checked & (f.ids != INVALID_ID), axis=-1)


def results_batch(f: Frontier, k: int) -> Tuple[jax.Array, jax.Array]:
    """The first K (id, dist) pairs per query: (B, k) each."""
    return f.ids[:, :k], f.dists[:, :k]


def merge_frontiers(fs: Frontier) -> Tuple[Frontier, jax.Array]:
    """Merge stacked walker frontiers (W, L) into a global queue (Line 23).

    Duplicate ids collapse preferring checked entries, so work done by any
    walker is never repeated globally.  Also returns the number of duplicate
    entries dropped — a lower bound on cross-walker redundant expansion
    (the loose-visiting-map cost the paper bounds at <5%, §4.4).
    """
    w, cap = fs.ids.shape
    ids = fs.ids.reshape(-1)
    dists = fs.dists.reshape(-1)
    checked = fs.checked.reshape(-1)
    # group by id; prefer checked (sort key ~checked within id group)
    not_checked = (~checked).astype(jnp.int32)
    ids, not_checked, dists = _sort_by(ids, not_checked, dists)
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (ids[1:] == ids[:-1]) & (ids[1:] != INVALID_ID)])
    n_dups = jnp.sum(dup).astype(jnp.int32)
    ids = jnp.where(dup, INVALID_ID, ids)
    dists = jnp.where(dup, INF, dists)
    dists, ids, not_checked = _sort_by(dists, ids, not_checked)
    out = Frontier(ids=ids[:cap], dists=dists[:cap],
                   checked=(not_checked[:cap] == 0) | (ids[:cap] == INVALID_ID))
    return out, n_dups
