"""Search-quality and search-work metrics (paper §2.1 Eq. 1, §5 profiling)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def recall_at_k(found_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    """Recall@K (Eq. 1): |found ∩ true| / K, averaged over queries."""
    found = np.asarray(found_ids)[:, :k]
    gt = np.asarray(gt_ids)[:, :k]
    hits = 0
    for f, g in zip(found, gt):
        hits += len(set(int(x) for x in f) & set(int(x) for x in g))
    return hits / (found.shape[0] * k)


class SearchStats(NamedTuple):
    """Per-query work counters (paper Figures 5–9, 16, 18)."""
    steps: jax.Array          # global (convergence) steps taken
    local_steps: jax.Array    # walker-local steps summed over walkers
    dist_comps: jax.Array     # distance computations (incl. duplicates)
    dup_comps: jax.Array      # duplicates across walkers (loose-map cost)
    syncs: jax.Array          # global synchronizations (queue merges)
    # critical-path expansions: sequential rounds (walkers run in parallel
    # within a round) — the latency model for W-core/W-device hardware
    crit_rounds: jax.Array
    # cross-lane frontier-overlap counters (batch-major engine).  Every
    # distance computation of a step is attributed to exactly one bucket by
    # FIRST-TOUCHER order over the step's flattened expansion lanes:
    #   uniq_comps      — the lane was the first (lowest-index) lane to
    #                     compute this candidate id this step; under a
    #                     batch-deduplicating backend this lane pays the row
    #                     gather.
    #   batch_dup_comps — an earlier lane already computed the id this step;
    #                     the row gather is redundant — the reuse the
    #                     "dedup_gather" backend converts into VMEM hits.
    # Invariant: uniq_comps + batch_dup_comps == dist_comps per lane, always
    # (the traversal seed counts too).  A lane's counters depend only on
    # EARLIER lanes, so they are invariant under front-slicing the batch.
    # At B=1 every top-M computation is unique; Speed-ANN walker lanes share
    # the flattened expansion grid, so cross-WALKER duplicates within one
    # query still count as batch_dup_comps (a dedup backend gathers across
    # walkers too).  Unlike the other fields they are defined RELATIVE TO
    # THE BATCH, so vmapping the per-query search yields the B=1 values,
    # not the cross-query ones.
    uniq_comps: jax.Array
    batch_dup_comps: jax.Array

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.int32)
        return SearchStats(z, z, z, z, z, z, z, z)

    @staticmethod
    def zero_batch(batch: int):
        """Per-query counters stacked on a leading (B,) axis — the
        batch-major engine's stats carry (lanes stay exact under the
        active-query masking)."""
        z = jnp.zeros((batch,), jnp.int32)
        return SearchStats(z, z, z, z, z, z, z, z)

    # fields whose values are defined relative to the whole batch (see
    # above); parity harnesses that compare the batch-major engine against
    # vmapped per-query searches must treat these separately
    BATCH_RELATIVE = ("uniq_comps", "batch_dup_comps")

    def summary(self) -> dict:
        return {k: float(np.mean(np.asarray(v)))
                for k, v in self._asdict().items()}


# the fields the serving stack surfaces as per-lane DISTRIBUTIONS (registry
# histograms, docs/observability.md): convergence depth, critical-path
# rounds, and the total/unique/duplicate distance-computation split that
# prices a batch-dedup backend
TELEMETRY = ("steps", "crit_rounds", "dist_comps", "uniq_comps",
             "batch_dup_comps")


def telemetry_per_lane(stats: "SearchStats") -> dict:
    """Host-side view of the TELEMETRY leaves: field -> (B,) float64 array
    (scalar leaves become shape-(1,)).  One transfer per leaf — callers
    gate on their metrics flag so the untraced hot path never pays it."""
    return {field: np.asarray(getattr(stats, field),
                              np.float64).reshape(-1)
            for field in TELEMETRY}


# sentinel for masked-out candidate slots in first-toucher counting; real
# graph ids are always < n_nodes < 2**31 - 1
_UNIQ_SENTINEL = jnp.int32(2**31 - 1)


def batch_unique_counts(ids: jax.Array, counted: jax.Array) -> jax.Array:
    """First-toucher attribution of one step's expansion across lanes.

    ``ids`` (B, C) candidate ids, ``counted`` (B, C) bool — the candidates
    that actually cost a distance computation this step (fresh AND on a live
    lane).  Returns (B,) int32: per lane, how many of its counted candidates
    were NOT counted by any lower-index lane — the number of row gathers a
    batch-deduplicating backend would charge this lane.  Exact: a stable
    sort by id keeps the flattened row-major (= lane) order inside every id
    group, so the group's first element belongs to the first touching lane.

    Per-lane ``counted`` candidates are assumed id-distinct (the visited
    structures dedup in-lane before any distance is counted), so
    ``sum(out) == |{distinct ids}|`` and ``out <= sum(counted, axis=-1)``
    elementwise with equality iff no id is shared across lanes.
    """
    b, c = ids.shape
    # jaxlint: ignore[JL402] -- cross-lane flatten is the point: first-
    # toucher attribution sorts the whole batch's ids in one (B*C,) stream
    flat = jnp.where(counted, ids, _UNIQ_SENTINEL).reshape(-1)
    lane = jnp.repeat(jnp.arange(b, dtype=jnp.int32), c)
    sorted_ids, sorted_lane = jax.lax.sort((flat, lane), num_keys=1,
                                           is_stable=True)
    prev = jnp.concatenate([_UNIQ_SENTINEL[None] - 1, sorted_ids[:-1]])
    first = (sorted_ids != _UNIQ_SENTINEL) & (sorted_ids != prev)
    return jnp.zeros((b,), jnp.int32).at[sorted_lane].add(
        first.astype(jnp.int32))
