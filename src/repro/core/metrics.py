"""Search-quality and search-work metrics (paper §2.1 Eq. 1, §5 profiling)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def recall_at_k(found_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    """Recall@K (Eq. 1): |found ∩ true| / K, averaged over queries."""
    found = np.asarray(found_ids)[:, :k]
    gt = np.asarray(gt_ids)[:, :k]
    hits = 0
    for f, g in zip(found, gt):
        hits += len(set(int(x) for x in f) & set(int(x) for x in g))
    return hits / (found.shape[0] * k)


class SearchStats(NamedTuple):
    """Per-query work counters (paper Figures 5–9, 16, 18)."""
    steps: jax.Array          # global (convergence) steps taken
    local_steps: jax.Array    # walker-local steps summed over walkers
    dist_comps: jax.Array     # distance computations (incl. duplicates)
    dup_comps: jax.Array      # duplicates across walkers (loose-map cost)
    syncs: jax.Array          # global synchronizations (queue merges)
    # critical-path expansions: sequential rounds (walkers run in parallel
    # within a round) — the latency model for W-core/W-device hardware
    crit_rounds: jax.Array

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.int32)
        return SearchStats(z, z, z, z, z, z)

    @staticmethod
    def zero_batch(batch: int):
        """Per-query counters stacked on a leading (B,) axis — the
        batch-major engine's stats carry (lanes stay exact under the
        active-query masking)."""
        z = jnp.zeros((batch,), jnp.int32)
        return SearchStats(z, z, z, z, z, z)

    def summary(self) -> dict:
        return {k: float(np.mean(np.asarray(v)))
                for k, v in self._asdict().items()}
