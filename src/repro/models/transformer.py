"""Decoder-only transformer: dense GQA (llama/yi/qwen/mistral), MoE
(qwen3-moe/grok-1), and M-RoPE VLM backbone (qwen2-vl).

Layers are homogeneous and scanned (``jax.lax.scan`` over stacked params) so
the HLO stays O(1) in depth — essential for 88-layer configs on 512-device
meshes.  KV caches are stacked per layer with a leading ``layers`` axis.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import FAMILY_MOE, ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import moe_a2a
from repro.models.common import (cross_entropy, dtype_of, maybe_scan,
                                 mrope_angles, normal_init, pdtype_of,
                                 rmsnorm, rmsnorm_init, rope_angles)
from repro.sharding import shard


class DecodeState(NamedTuple):
    caches: attn.KVCache       # stacked (L, B, S, kv, hd)
    pos: jax.Array             # (B,) next position to write


class CausalLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ---------------------------------------------------------------
    def _layer_init(self, key) -> dict:
        cfg = self.cfg
        pdt = pdtype_of(cfg)
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "attn_norm": rmsnorm_init(cfg.d_model, pdt),
            "attn": attn.attn_init(k1, cfg, dtype=pdt),
            "ffn_norm": rmsnorm_init(cfg.d_model, pdt),
        }
        if cfg.family == FAMILY_MOE:
            p["moe"] = moe_mod.moe_init(k2, cfg, pdt)
        else:
            p["mlp"] = mlp_mod.swiglu_init(k2, cfg, pdt)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        pdt = pdtype_of(cfg)
        kE, kL, kH = jax.random.split(key, 3)
        layer_keys = jax.random.split(kL, cfg.num_layers)
        layers = jax.vmap(self._layer_init)(layer_keys)
        params = {
            "embedding": normal_init(
                kE, (cfg.vocab_size, cfg.d_model), 0.02, pdt),
            "layers": layers,
            "final_norm": rmsnorm_init(cfg.d_model, pdt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = normal_init(
                kH, (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5, pdt)
        return params

    # -- shared pieces -------------------------------------------------------
    def _rope(self, positions: jax.Array):
        cfg = self.cfg
        if cfg.mrope:
            if positions.ndim == 2:          # (B,S) -> same stream 3x
                positions = jnp.broadcast_to(
                    positions[None], (3,) + positions.shape)
            return mrope_angles(positions, cfg.resolved_head_dim,
                                cfg.rope_theta, cfg.mrope_sections)
        return rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embedding"][tokens].astype(dtype_of(cfg))
        return shard(x, "batch", "seq", "embed")

    def _logits(self, params, x):
        cfg = self.cfg
        head = (params["embedding"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        return shard(logits, "batch", "seq", "vocab")

    def _layer_apply(self, p, x, rope, mode, cache, pos):
        cfg = self.cfg
        h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
        a, new_cache = attn.attend(p["attn"], h, cfg, rope=rope, mode=mode,
                                   cache=cache, pos=pos)
        x = x + a
        h = rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
        if cfg.family == FAMILY_MOE:
            if moe_a2a.moe_impl() == "a2a":
                f, aux = moe_a2a.moe_ffn_sharded(p["moe"], h, cfg)
            else:
                f, aux = moe_mod.moe_ffn(p["moe"], h, cfg)
        else:
            f, aux = mlp_mod.swiglu(p["mlp"], h), jnp.float32(0.0)
        return x + f, new_cache, aux

    # -- train / full forward -----------------------------------------------
    def forward(self, params, tokens, positions=None, remat: bool = True,
                inputs_embeds=None) -> Tuple[jax.Array, jax.Array]:
        """Full causal forward. Returns (logits (B,S,V), aux_loss ())."""
        cfg = self.cfg
        b, s = tokens.shape
        x = inputs_embeds if inputs_embeds is not None else self._embed(
            params, tokens)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        rope = self._rope(positions)

        def body(carry, lp):
            x, aux = carry
            x2, _, a = self._layer_apply(lp, x, rope, "train", None, None)
            return (x2, aux + a), None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = maybe_scan(body, (x, jnp.float32(0.0)),
                                 params["layers"], cfg.scan_layers)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self._logits(params, x), aux

    def loss(self, params, batch, remat: bool = True) -> jax.Array:
        logits, aux = self.forward(params, batch["tokens"],
                                   positions=batch.get("positions"),
                                   remat=remat,
                                   inputs_embeds=batch.get("inputs_embeds"))
        return cross_entropy(logits, batch["targets"], batch["mask"]) + aux

    # -- serving -------------------------------------------------------------
    def init_decode_state(self, batch: int, s_max: int) -> DecodeState:
        cfg = self.cfg
        one = attn.init_cache(cfg, batch, s_max, cfg.num_kv_heads,
                              dtype_of(cfg))
        caches = jax.tree.map(
            lambda t: jnp.zeros((cfg.num_layers,) + t.shape, t.dtype), one)
        return DecodeState(caches=caches, pos=jnp.zeros((batch,), jnp.int32))

    def prefill(self, params, tokens, s_max: int, positions=None,
                inputs_embeds=None) -> Tuple[jax.Array, DecodeState]:
        """Run the prompt, fill caches. Returns (last-token logits, state)."""
        cfg = self.cfg
        b, s = tokens.shape
        x = inputs_embeds if inputs_embeds is not None else self._embed(
            params, tokens)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        rope = self._rope(positions)
        empty = attn.init_cache(cfg, b, s_max, cfg.num_kv_heads,
                                dtype_of(cfg))

        def body(x, lp):
            x2, cache, _ = self._layer_apply(lp, x, rope, "prefill", empty,
                                             None)
            return x2, cache

        x, caches = maybe_scan(body, x, params["layers"], cfg.scan_layers)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x[:, -1:, :])
        return logits, DecodeState(
            caches=caches, pos=jnp.full((b,), s, jnp.int32))

    def decode_step(self, params, state: DecodeState, token: jax.Array,
                    ) -> Tuple[jax.Array, DecodeState]:
        """One greedy decode step. token (B, 1) -> (logits (B,1,V), state)."""
        cfg = self.cfg
        b = token.shape[0]
        x = self._embed(params, token)
        rope = self._rope(state.pos[:, None])

        def body(x, lp_cache):
            lp, cache = lp_cache
            x2, new_cache, _ = self._layer_apply(
                lp, x, rope, "decode", cache, state.pos)
            return x2, new_cache

        x, caches = maybe_scan(body, x, (params["layers"], state.caches),
                               cfg.scan_layers)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, DecodeState(caches=caches, pos=state.pos + 1)
