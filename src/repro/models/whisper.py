"""Whisper-style encoder-decoder backbone (whisper-large-v3).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` delivers
precomputed frame embeddings (B, enc_ctx, d_model).  Encoder: bidirectional
self-attention + GELU MLP, sinusoidal positions.  Decoder: causal
self-attention + cross-attention + GELU MLP, learned positions.  Serving
precomputes the per-layer cross-attention K/V from the encoder output once.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (cross_entropy, dtype_of, layernorm,
                                 layernorm_init, maybe_scan, normal_init,
                                 pdtype_of, sinusoidal_positions)
from repro.sharding import shard


class WhisperDecodeState(NamedTuple):
    self_caches: attn.KVCache   # (L, B, S_max, kv, hd)
    cross_k: jax.Array          # (L, B, enc_ctx, kv, hd)
    cross_v: jax.Array
    pos: jax.Array              # (B,)


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _enc_layer_init(self, key):
        cfg, pdt = self.cfg, pdtype_of(self.cfg)
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": layernorm_init(cfg.d_model, pdt),
            "attn": attn.attn_init(k1, cfg, dtype=pdt),
            "ffn_norm": layernorm_init(cfg.d_model, pdt),
            "mlp": mlp_mod.gelu_mlp_init(k2, cfg, dtype=pdt),
        }

    def _dec_layer_init(self, key):
        cfg, pdt = self.cfg, pdtype_of(self.cfg)
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "attn_norm": layernorm_init(cfg.d_model, pdt),
            "attn": attn.attn_init(k1, cfg, dtype=pdt),
            "cross_norm": layernorm_init(cfg.d_model, pdt),
            "cross": attn.attn_init(k2, cfg, dtype=pdt),
            "ffn_norm": layernorm_init(cfg.d_model, pdt),
            "mlp": mlp_mod.gelu_mlp_init(k3, cfg, dtype=pdt),
        }

    def init(self, key) -> dict:
        cfg, pdt = self.cfg, pdtype_of(self.cfg)
        kE, kEnc, kDec, kP = jax.random.split(key, 4)
        enc_keys = jax.random.split(kEnc, cfg.encoder_layers)
        dec_keys = jax.random.split(kDec, cfg.num_layers)
        return {
            "embedding": normal_init(
                kE, (cfg.vocab_size, cfg.d_model), 0.02, pdt),
            "pos_embedding": normal_init(
                kP, (cfg.max_seq_len, cfg.d_model), 0.01, pdt),
            "enc_layers": jax.vmap(self._enc_layer_init)(enc_keys),
            "dec_layers": jax.vmap(self._dec_layer_init)(dec_keys),
            "enc_norm": layernorm_init(cfg.d_model, pdt),
            "dec_norm": layernorm_init(cfg.d_model, pdt),
        }

    # -- encoder --------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, enc_ctx, d_model) stub embeddings -> encoder states."""
        cfg = self.cfg
        x = frames.astype(dtype_of(cfg))
        x = x + sinusoidal_positions(
            x.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = shard(x, "batch", "frames", "embed")

        def body(x, lp):
            h = layernorm(lp["attn_norm"], x, cfg.norm_eps)
            a, _ = attn.attend(lp["attn"], h, cfg, rope=None, mode="train",
                               causal=False)
            x = x + a
            h = layernorm(lp["ffn_norm"], x, cfg.norm_eps)
            return x + mlp_mod.gelu_mlp(lp["mlp"], h), None

        x, _ = maybe_scan(body, x, params["enc_layers"], cfg.scan_layers)
        return layernorm(params["enc_norm"], x, cfg.norm_eps)

    # -- decoder --------------------------------------------------------------
    def _dec_layer(self, lp, x, enc, mode, cache, pos):
        cfg = self.cfg
        h = layernorm(lp["attn_norm"], x, cfg.norm_eps)
        a, new_cache = attn.attend(lp["attn"], h, cfg, rope=None, mode=mode,
                                   cache=cache, pos=pos)
        x = x + a
        h = layernorm(lp["cross_norm"], x, cfg.norm_eps)
        c, _ = attn.attend(lp["cross"], h, cfg, rope=None, kv_x=enc)
        x = x + c
        h = layernorm(lp["ffn_norm"], x, cfg.norm_eps)
        return x + mlp_mod.gelu_mlp(lp["mlp"], h), new_cache

    def forward(self, params, frames, tokens, remat: bool = True
                ) -> jax.Array:
        """Teacher-forced decoder logits (B, S, V)."""
        cfg = self.cfg
        enc = self.encode(params, frames)
        b, s = tokens.shape
        x = params["embedding"][tokens].astype(dtype_of(cfg))
        x = x + params["pos_embedding"][:s].astype(x.dtype)[None]
        x = shard(x, "batch", "seq", "embed")

        def body(x, lp):
            x2, _ = self._dec_layer(lp, x, enc, "train", None, None)
            return x2, None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = maybe_scan(body, x, params["dec_layers"], cfg.scan_layers)
        x = layernorm(params["dec_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embedding"].astype(x.dtype))
        return shard(logits, "batch", "seq", "vocab")

    def loss(self, params, batch, remat: bool = True) -> jax.Array:
        logits = self.forward(params, batch["frames"], batch["tokens"],
                              remat=remat)
        return cross_entropy(logits, batch["targets"], batch["mask"])

    # -- serving ---------------------------------------------------------------
    def prefill(self, params, frames, tokens, s_max: int
                ) -> Tuple[jax.Array, WhisperDecodeState]:
        cfg = self.cfg
        enc = self.encode(params, frames)
        b, s = tokens.shape
        x = params["embedding"][tokens].astype(dtype_of(cfg))
        x = x + params["pos_embedding"][:s].astype(x.dtype)[None]
        empty = attn.init_cache(cfg, b, s_max, cfg.num_kv_heads,
                                dtype_of(cfg))

        def body(x, lp):
            x2, cache = self._dec_layer(lp, x, enc, "prefill", empty, None)
            # cross-attention K/V precomputed once per layer
            _, ck, cv = attn._proj_qkv(lp["cross"], enc, cfg)
            return x2, (cache, ck.astype(dtype_of(cfg)),
                        cv.astype(dtype_of(cfg)))

        x, (caches, cks, cvs) = maybe_scan(body, x, params["dec_layers"],
                                           cfg.scan_layers)
        x = layernorm(params["dec_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x[:, -1:],
                            params["embedding"].astype(x.dtype))
        return logits, WhisperDecodeState(
            self_caches=caches, cross_k=cks, cross_v=cvs,
            pos=jnp.full((b,), s, jnp.int32))

    def init_decode_state(self, batch: int, s_max: int) -> WhisperDecodeState:
        cfg = self.cfg
        h = cfg.resolved_head_dim
        one = attn.init_cache(cfg, batch, s_max, cfg.num_kv_heads,
                              dtype_of(cfg))
        caches = jax.tree.map(
            lambda t: jnp.zeros((cfg.num_layers,) + t.shape, t.dtype), one)
        cross = jnp.zeros(
            (cfg.num_layers, batch, cfg.encoder_ctx, cfg.num_kv_heads, h),
            dtype_of(cfg))
        return WhisperDecodeState(
            self_caches=caches, cross_k=cross, cross_v=cross,
            pos=jnp.zeros((batch,), jnp.int32))

    def decode_step(self, params, state: WhisperDecodeState,
                    token: jax.Array) -> Tuple[jax.Array, WhisperDecodeState]:
        cfg = self.cfg
        b = token.shape[0]
        x = params["embedding"][token].astype(dtype_of(cfg))
        pos_emb = params["pos_embedding"][state.pos[0]]
        x = x + pos_emb.astype(x.dtype)[None, None]

        def body(x, lp_cache):
            lp, cache, ck, cv = lp_cache
            h = layernorm(lp["attn_norm"], x, cfg.norm_eps)
            a, new_cache = attn.attend(lp["attn"], h, cfg, rope=None,
                                       mode="decode", cache=cache,
                                       pos=state.pos)
            x = x + a
            h = layernorm(lp["cross_norm"], x, cfg.norm_eps)
            q, _, _ = attn._proj_qkv(lp["cross"], h, cfg)
            mask = jnp.ones((1, 1, 1, ck.shape[1]), bool)
            c = attn._sdpa(q, ck, cv, mask, cfg)
            x = x + attn._wo(lp["cross"], c, cfg)
            h = layernorm(lp["ffn_norm"], x, cfg.norm_eps)
            return x + mlp_mod.gelu_mlp(lp["mlp"], h), new_cache

        x, caches = maybe_scan(
            body, x, (params["dec_layers"], state.self_caches,
                      state.cross_k, state.cross_v), cfg.scan_layers)
        x = layernorm(params["dec_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embedding"].astype(x.dtype))
        return logits, state._replace(self_caches=caches, pos=state.pos + 1)
