"""Feed-forward blocks: SwiGLU (llama family) and GELU MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import normal_init
from repro.sharding import shard


def swiglu_init(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(ks[0], (d, f), d ** -0.5, dtype),
        "w_up": normal_init(ks[1], (d, f), d ** -0.5, dtype),
        "w_down": normal_init(ks[2], (f, d), f ** -0.5, dtype),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    h = shard(jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u,
              "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    return shard(y, "batch", "seq", "embed")


def gelu_mlp_init(key, cfg: ModelConfig, d_in=None, dtype=None) -> dict:
    d = d_in or cfg.d_model
    f = cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "fc1": normal_init(ks[0], (d, f), d ** -0.5, dtype),
        "fc1_b": jnp.zeros((f,), dtype),
        "fc2": normal_init(ks[1], (f, cfg.d_model), f ** -0.5, dtype),
        "fc2_b": jnp.zeros((cfg.d_model,), dtype),
    }


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["fc1"].astype(dt)) + p["fc1_b"].astype(dt)
    h = shard(jax.nn.gelu(h.astype(jnp.float32)).astype(dt),
              "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["fc2"].astype(dt)) + p["fc2_b"].astype(dt)
    return shard(y, "batch", "seq", "embed")
