"""Uniform model interface over all families.

``build_model(cfg)`` returns an object exposing:
    init(key) -> params
    loss(params, batch, remat=...) -> scalar        (train step core)
    prefill(params, ...) -> (logits, decode_state)
    decode_step(params, state, token) -> (logits, decode_state)
    init_decode_state(batch, s_max) -> decode_state

Batch dict keys by family (see launch.dryrun.input_specs):
    dense/moe:  tokens, targets, mask
    vlm:        + positions (3, B, S) M-RoPE position ids (stubbed)
    encdec:     + frames (B, enc_ctx, d_model) stub frame embeddings
    ssm/hybrid: tokens, targets, mask
"""
from __future__ import annotations

from repro.config import (FAMILY_DENSE, FAMILY_ENCDEC, FAMILY_HYBRID,
                          FAMILY_MOE, FAMILY_SSM, FAMILY_VLM, ModelConfig)
from repro.models.mamba_lm import MambaLM
from repro.models.transformer import CausalLM
from repro.models.whisper import WhisperModel
from repro.models.zamba2 import Zamba2Model


def build_model(cfg: ModelConfig):
    if cfg.family in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        return CausalLM(cfg)
    if cfg.family == FAMILY_ENCDEC:
        return WhisperModel(cfg)
    if cfg.family == FAMILY_SSM:
        return MambaLM(cfg)
    if cfg.family == FAMILY_HYBRID:
        return Zamba2Model(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
