"""Shared model components: norms, embeddings, rotary embeddings (incl.
M-RoPE), initializers.  Functional style — params are nested dicts of
jnp arrays; every layer is (init, apply) pair."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def normal_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return y.astype(x.dtype)


def gated_rmsnorm(scale: jax.Array, x: jax.Array, z: jax.Array,
                  eps: float) -> jax.Array:
    """Mamba2's RMSNorm(x * silu(z)) output gate."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float
                ) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) -> cos/sin (..., S, head_dim/2) in f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: Tuple[int, int, int]
                 ) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — temporal / height / width position ids.  The
    head_dim/2 frequency slots are split into three contiguous sections,
    each driven by its own position stream.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    sec_id = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    # select per-frequency position stream: (B, S, half)
    pos_sel = positions.astype(jnp.float32)[sec_id, :, :]    # (half, B, S)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)                   # (B, S, half)
    ang = pos_sel * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (n, d) f32."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def maybe_scan(body, carry, xs, use_scan: bool):
    """``jax.lax.scan`` or a Python unroll over the stacked leading axis.

    The unrolled path exists for the dry-run's roofline accounting: XLA's
    ``cost_analysis`` counts a while-loop body ONCE (trip count ignored), so
    per-layer FLOPs/bytes/collective costs are measured from small unrolled
    lowerings and extrapolated to full depth (see launch/dryrun.py).
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda t, i=i: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def cross_entropy(logits: jax.Array, targets: jax.Array, mask: jax.Array
                  ) -> jax.Array:
    """Mean masked token cross-entropy, f32 accumulation."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
