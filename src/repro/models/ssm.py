"""Mamba2 block — SSD (state-space duality) chunked algorithm.

The SSD form computes the selective-SSM recurrence

    h_t = exp(dt_t·A) h_{t-1} + dt_t · B_t ⊗ x_t        (per head, state N)
    y_t = C_t · h_t + D · x_t

as chunked matmuls (MXU-friendly): within a chunk the lower-triangular decay
kernel L = exp(segsum(dt·A)) turns the recurrence into attention-like
einsums; across chunks a short scan carries the (H, P, N) state.  This is
the TPU-native realization — chunk length is a config knob that the §Perf
loop tunes (trade intra-chunk O(Q²) FLOPs vs scan length T/Q).

``ssd_scan_ref`` is the naive sequential oracle used by property tests;
``step`` is the O(1) decode update sharing the same parameters.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import gated_rmsnorm, normal_init
from repro.sharding import shard


class SSMState(NamedTuple):
    conv: jax.Array     # (B, W-1, conv_channels) rolling conv window
    ssm: jax.Array      # (B, H, P, N) recurrent state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.ngroups * s.state_dim
    return s, d_in, nheads, conv_ch


def mamba2_init(key, cfg: ModelConfig, dtype) -> dict:
    s, d_in, nheads, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    # in_proj emits [z (d_in), xBC (conv_ch), dt (nheads)]
    out_dim = d_in + conv_ch + nheads
    return {
        "in_proj": normal_init(ks[0], (d, out_dim), d ** -0.5, dtype),
        "conv_w": normal_init(ks[1], (s.conv_width, conv_ch),
                              s.conv_width ** -0.5, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.linspace(1e-3, 1e-1, nheads), 1e-4, None))
        ).astype(jnp.float32),
        "ssm_norm": jnp.ones((d_in,), dtype),
        "out_proj": normal_init(ks[3], (d_in, d), d_in ** -0.5, dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L) decay exponents.

    seg[i, j] = sum_{t=j+1..i} x_t for j < i (the decay an input at j suffers
    before being read at i), 0 on the diagonal, -inf above (causality)."""
    seqlen = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((seqlen, seqlen), bool), k=-1)
    diag = jnp.eye(seqlen, dtype=bool)
    return jnp.where(mask, seg, jnp.where(diag, 0.0, -jnp.inf))


def ssd_chunked(
    xdt: jax.Array,    # (B, T, H, P)  — x already scaled by dt
    a_dt: jax.Array,   # (B, T, H)     — dt * A  (negative)
    bmat: jax.Array,   # (B, T, G, N)
    cmat: jax.Array,   # (B, T, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,   # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,T,H,P), final state (B,H,P,N))."""
    b, t, h, p = xdt.shape
    g, n = bmat.shape[2], bmat.shape[3]
    assert t % chunk == 0, (t, chunk)
    c = t // chunk
    rep = h // g
    # expand groups to heads
    bh = jnp.repeat(bmat, rep, axis=2)            # (B, T, H, N)
    ch = jnp.repeat(cmat, rep, axis=2)

    def r(x_, shape):
        return x_.reshape(shape)

    x_ = r(xdt, (b, c, chunk, h, p))
    a_ = jnp.moveaxis(r(a_dt, (b, c, chunk, h)), -1, 2)   # (B, C, H, L)
    b_ = r(bh, (b, c, chunk, h, n))
    c_ = r(ch, (b, c, chunk, h, n))

    a_cs = jnp.cumsum(a_, axis=-1)                        # (B, C, H, L)
    # 1. intra-chunk (diagonal) term
    lmat = jnp.exp(_segsum(a_))                           # (B, C, H, L, L)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                        c_, b_, lmat, x_)
    # 2. per-chunk final states
    decay = jnp.exp(a_cs[..., -1:] - a_cs)                # (B, C, H, L)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", b_, decay, x_)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1])                  # (B, C, H)

    def scan_f(prev, inp):
        st, dec = inp
        new = prev * dec[..., None, None] + st
        return new, prev

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_f, h0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B, C, H, P, N)
    # 4. state -> output within each chunk
    state_decay = jnp.exp(a_cs)                           # (B, C, H, L)
    y_off = jnp.einsum("bclhn,bchl,bchpn->bclhp",
                       c_, state_decay, prev_states)
    y = (y_diag + y_off).reshape(b, t, h, p)
    return y, final


def ssd_scan_ref(xdt, a_dt, bmat, cmat, h0=None):
    """Naive sequential oracle for property tests."""
    b, t, h, p = xdt.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(cmat, rep, axis=2).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def f(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = (state * jnp.exp(a_t)[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", x_t, b_t))
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    xs = (jnp.moveaxis(xdt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(a_dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(bh, 1, 0), jnp.moveaxis(ch, 1, 0))
    final, ys = jax.lax.scan(f, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), final


def _split_proj(p, x, cfg):
    s, d_in, nheads, conv_ch = _dims(cfg)
    z_xbc_dt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z = z_xbc_dt[..., :d_in]
    xbc = z_xbc_dt[..., d_in:d_in + conv_ch]
    dt = z_xbc_dt[..., d_in + conv_ch:]
    return z, xbc, dt


def _conv_full(p, xbc):
    """Depthwise causal conv over (B, T, C) with static width."""
    w = p["conv_w"].astype(jnp.float32)                   # (W, C)
    width = w.shape[0]
    x = xbc.astype(jnp.float32)
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + p["conv_b"].astype(jnp.float32)
                       ).astype(xbc.dtype)


def mamba2_forward(
    p: dict, x: jax.Array, cfg: ModelConfig,
    state: Optional[SSMState] = None, return_state: bool = False,
) -> Tuple[jax.Array, Optional[SSMState]]:
    """Full-sequence Mamba2 block. x (B, T, d) -> (B, T, d)."""
    import math as _math
    s, d_in, nheads, conv_ch = _dims(cfg)
    b, t, _ = x.shape
    z, xbc_raw, dt = _split_proj(p, x, cfg)
    xbc = _conv_full(p, xbc_raw)
    xs = xbc[..., :d_in]
    bmat = xbc[..., d_in:d_in + s.ngroups * s.state_dim].reshape(
        b, t, s.ngroups, s.state_dim)
    cmat = xbc[..., d_in + s.ngroups * s.state_dim:].reshape(
        b, t, s.ngroups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,T,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    xh = xs.reshape(b, t, nheads, s.head_dim)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    a_dt = dt * a[None, None, :]
    chunk = _math.gcd(t, s.chunk)   # largest config chunk dividing T
    h0 = state.ssm if state is not None else None
    y, hfinal = ssd_chunked(xdt, a_dt, bmat, cmat, chunk, h0=h0)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = gated_rmsnorm(p["ssm_norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))
    out = shard(out, "batch", "seq", "embed")
    if not return_state:
        return out, None
    # keep last W-1 raw (pre-conv) xbc inputs for decode continuation
    conv_tail = jnp.zeros((b, s.conv_width - 1, conv_ch), x.dtype)
    take = min(s.conv_width - 1, t)
    conv_tail = conv_tail.at[:, -take:].set(
        xbc_raw[:, t - take:].astype(x.dtype))
    return out, SSMState(conv=conv_tail, ssm=hfinal.astype(jnp.float32))


def mamba2_step(
    p: dict, x: jax.Array, cfg: ModelConfig, state: SSMState,
) -> Tuple[jax.Array, SSMState]:
    """O(1) decode step. x (B, 1, d) -> (B, 1, d)."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    b = x.shape[0]
    z, xbc, dt = _split_proj(p, x, cfg)                   # (B,1,·)
    window = jnp.concatenate([state.conv, xbc.astype(state.conv.dtype)],
                             axis=1)                      # (B, W, C)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)
    xbc1 = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    xs = xbc1[:, :d_in]
    bvec = xbc1[:, d_in:d_in + s.ngroups * s.state_dim].reshape(
        b, s.ngroups, s.state_dim)
    cvec = xbc1[:, d_in + s.ngroups * s.state_dim:].reshape(
        b, s.ngroups, s.state_dim)
    rep = nheads // s.ngroups
    bh = jnp.repeat(bvec, rep, axis=1)                    # (B, H, N)
    ch = jnp.repeat(cvec, rep, axis=1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))   # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, nheads, s.head_dim).astype(jnp.float32)
    da = jnp.exp(dt1 * a[None, :])                        # (B,H)
    h_new = (state.ssm * da[..., None, None]
             + jnp.einsum("bhp,bhn->bhpn", xh * dt1[..., None], bh))
    y = jnp.einsum("bhpn,bhn->bhp", h_new, ch)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = gated_rmsnorm(p["ssm_norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))
    new_conv = window[:, 1:]
    return out, SSMState(conv=new_conv, ssm=h_new)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                   ) -> SSMState:
    s, d_in, nheads, conv_ch = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, nheads, s.head_dim, s.state_dim), jnp.float32))
