"""Mixture-of-experts SwiGLU FFN with top-k routing and capacity buffers.

Expert-parallel layout: expert tensors carry a leading ``expert`` logical
axis (sharded over the ``model`` mesh axis when divisible — qwen3's 128
experts shard 16-way; grok-1's 8 experts fall back to tensor-parallel
``mlp`` sharding inside each expert).  Dispatch/combine are dense einsums
over one-hot capacity assignments, the standard GSPMD MoE formulation (XLA
turns the dispatch einsum into an all-to-all under expert sharding).

Routing: softmax over expert logits (f32), top-k per token, probabilities
renormalized over the selected k (qwen3/grok convention), tokens beyond an
expert's capacity are dropped (contribute zero; residual passes through) —
the load-balancing auxiliary loss keeps drops rare.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import normal_init
from repro.sharding import shard


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": normal_init(ks[0], (d, e), d ** -0.5, jnp.float32),
        "moe_gate": normal_init(ks[1], (e, d, f), d ** -0.5, dtype),
        "moe_up": normal_init(ks[2], (e, d, f), d ** -0.5, dtype),
        "moe_down": normal_init(ks[3], (e, f, d), f ** -0.5, dtype),
    }


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * tokens * m.top_k / m.num_experts)
    return max(8, min(tokens, (c + 3) // 4 * 4))


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss ())."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    cap = _capacity(cfg, t)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (T, k)
    top_p = top_p / jnp.maximum(
        jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)            # renorm

    # load-balancing aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux = m.aux_loss_weight * e * jnp.sum(me * ce)

    # position of each (token, slot) within its expert's capacity buffer
    flat_e = top_e.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)          # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot               # exclusive
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                    # (T*k,)
    keep = pos < cap
    pos = jnp.where(keep, pos, 0)

    # dispatch: (E, cap, d) buffers
    tok_idx = jnp.repeat(jnp.arange(t), k)
    disp = jnp.zeros((e, cap, d), x.dtype)
    disp = disp.at[flat_e, pos].add(
        jnp.where(keep[:, None], xt[tok_idx], 0).astype(x.dtype))
    disp = shard(disp, "expert", "capacity", "embed")

    # expert computation (SwiGLU per expert)
    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", disp, p["moe_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", disp, p["moe_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    h = shard(h, "expert", "capacity", "mlp")
    y_e = jnp.einsum("ecf,efd->ecd", h, p["moe_down"].astype(dt))
    y_e = shard(y_e, "expert", "capacity", "embed")

    # combine: weighted gather back to tokens
    gathered = y_e[flat_e, pos]                                  # (T*k, d)
    w = (top_p.reshape(-1) * keep).astype(jnp.float32)
    yt = jnp.zeros((t, d), jnp.float32)
    yt = yt.at[tok_idx].add(gathered.astype(jnp.float32) * w[:, None])
    y = shard(yt.reshape(b, s, d).astype(x.dtype), "batch", "seq", "embed")
    return y, aux
