"""Zamba2-style hybrid: Mamba2 trunk + ONE shared attention block applied
every ``hybrid_attn_every`` Mamba layers.

Layer layout for num_layers=81, attn_every=6:
    [6×mamba, shared-attn] × 11 groups  +  4 trailing mamba layers
(81 "layers" counts each shared-attn application).  The shared block is a
full transformer block over ``concat(hidden, initial_embedding)`` (2·d wide
— Zamba2's global skip), whose output is projected 2d→d into the residual.
Weights are shared across applications; each application keeps its own KV
cache.

Scan structure: outer scan over groups, inner scan over the group's Mamba
layers — HLO stays O(1) in depth while allowing the heterogeneous interleave.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.common import (cross_entropy, dtype_of, maybe_scan,
                                 normal_init, pdtype_of, rmsnorm,
                                 rmsnorm_init, rope_angles)
from repro.sharding import shard


class HybridDecodeState(NamedTuple):
    ssm_grouped: ssm_mod.SSMState    # leaves (G, E, B, ...) grouped mamba
    ssm_tail: ssm_mod.SSMState       # leaves (T, B, ...) trailing mamba
    attn_caches: attn.KVCache        # (G, B, S_max, kv, hd)
    pos: jax.Array                   # (B,)


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, per_group, n_tail_mamba)."""
    per = cfg.hybrid_attn_every
    groups = cfg.num_layers // (per + 1)
    tail = cfg.num_layers - groups * (per + 1)
    return groups, per, tail


class Zamba2Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        # the shared attention block sees 2*d_model-wide inputs
        self.attn_cfg = dataclasses.replace(cfg, d_model=2 * cfg.d_model)

    def init(self, key) -> dict:
        cfg, pdt = self.cfg, pdtype_of(self.cfg)
        groups, per, tail = _layout(cfg)
        kE, kM, kT, kA, k1, k2, k3 = jax.random.split(key, 7)

        def mamba_init(k):
            return {
                "norm": rmsnorm_init(cfg.d_model, pdt),
                "mamba": ssm_mod.mamba2_init(k, cfg, pdt),
            }

        mk = jax.random.split(kM, groups * per)
        grouped = jax.vmap(mamba_init)(mk)
        grouped = jax.tree.map(
            lambda t: t.reshape((groups, per) + t.shape[1:]), grouped)
        tk = jax.random.split(kT, max(tail, 1))
        tail_p = jax.vmap(mamba_init)(tk)
        d2 = 2 * cfg.d_model
        shared = {
            "attn_norm": rmsnorm_init(d2, pdt),
            "attn": attn.attn_init(kA, self.attn_cfg, dtype=pdt),
            "ffn_norm": rmsnorm_init(d2, pdt),
            "fc1": normal_init(k1, (d2, cfg.d_ff), d2 ** -0.5, pdt),
            "fc2": normal_init(k2, (cfg.d_ff, d2), cfg.d_ff ** -0.5, pdt),
            "out_proj": normal_init(k3, (d2, cfg.d_model), d2 ** -0.5, pdt),
        }
        return {
            "embedding": normal_init(
                kE, (cfg.vocab_size, cfg.d_model), 0.02, pdt),
            "grouped": grouped,
            "tail": tail_p,
            "shared": shared,
            "final_norm": rmsnorm_init(cfg.d_model, pdt),
        }

    def _shared_block(self, sp, x, x0, rope, mode, cache, pos):
        """Shared transformer block over concat(hidden, embedding) -> d."""
        cfg = self.cfg
        y = jnp.concatenate([x, x0], axis=-1)              # (B, S, 2d)
        h = rmsnorm(sp["attn_norm"], y, cfg.norm_eps)
        a, new_cache = attn.attend(sp["attn"], h, self.attn_cfg, rope=rope,
                                   mode=mode, cache=cache, pos=pos)
        y = y + a
        h = rmsnorm(sp["ffn_norm"], y, cfg.norm_eps)
        f = jnp.einsum("bsd,df->bsf", h, sp["fc1"].astype(h.dtype))
        f = shard(jax.nn.gelu(f.astype(jnp.float32)).astype(h.dtype),
                  "batch", "seq", "mlp")
        y = y + jnp.einsum("bsf,fd->bsd", f, sp["fc2"].astype(h.dtype))
        out = jnp.einsum("bse,ed->bsd", y, sp["out_proj"].astype(y.dtype))
        return x + out, new_cache

    def _mamba(self, lp, x, mode, state=None):
        cfg = self.cfg
        h = rmsnorm(lp["norm"], x, cfg.norm_eps)
        if mode == "step":
            y, new_state = ssm_mod.mamba2_step(lp["mamba"], h, cfg, state)
            return x + y, new_state
        y, new_state = ssm_mod.mamba2_forward(
            lp["mamba"], h, cfg, return_state=(mode == "prefill"))
        return x + y, new_state

    # -- full forward -----------------------------------------------------
    def forward(self, params, tokens, remat: bool = True,
                collect_state: bool = False, s_max: int = 0):
        cfg = self.cfg
        groups, per, tail = _layout(cfg)
        b, s = tokens.shape
        x = params["embedding"][tokens].astype(dtype_of(cfg))
        x = shard(x, "batch", "seq", "embed")
        x0 = x
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        rope = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
        mode = "prefill" if collect_state else "train"
        empty = (attn.init_cache(self.attn_cfg, b, s_max,
                                 cfg.num_kv_heads, dtype_of(cfg))
                 if collect_state else None)

        def group_body(x, gp):
            def inner(x, lp):
                return self._mamba(lp, x, mode)
            x, states = maybe_scan(inner, x, gp, cfg.scan_layers)
            x, cache = self._shared_block(params["shared"], x, x0, rope,
                                          mode, empty, None)
            if collect_state:
                return x, (states, cache)
            return x, states

        if remat and not collect_state:
            group_body = jax.checkpoint(
                group_body, policy=jax.checkpoint_policies.nothing_saveable)
        x, group_out = maybe_scan(group_body, x, params["grouped"],
                                  cfg.scan_layers)

        def tail_body(x, lp):
            return self._mamba(lp, x, mode)

        if tail > 0:
            x, tail_states = maybe_scan(tail_body, x, params["tail"],
                                        cfg.scan_layers)
        else:
            tail_states = jax.tree.map(
                lambda t: jnp.zeros((1,) + t.shape, t.dtype),
                ssm_mod.init_ssm_state(cfg, b, dtype_of(cfg)))
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embedding"].astype(x.dtype))
        logits = shard(logits, "batch", "seq", "vocab")
        if collect_state:
            ssm_grouped, caches = group_out
            return logits, (ssm_grouped, tail_states, caches)
        return logits

    def loss(self, params, batch, remat: bool = True) -> jax.Array:
        logits = self.forward(params, batch["tokens"], remat=remat)
        return cross_entropy(logits, batch["targets"], batch["mask"])

    # -- serving -----------------------------------------------------------
    def prefill(self, params, tokens, s_max: int
                ) -> Tuple[jax.Array, HybridDecodeState]:
        b, s = tokens.shape
        logits, (ssm_g, ssm_t, caches) = self.forward(
            params, tokens, remat=False, collect_state=True, s_max=s_max)
        return logits[:, -1:], HybridDecodeState(
            ssm_grouped=ssm_g, ssm_tail=ssm_t, attn_caches=caches,
            pos=jnp.full((b,), s, jnp.int32))

    def init_decode_state(self, batch: int, s_max: int) -> HybridDecodeState:
        cfg = self.cfg
        groups, per, tail = _layout(cfg)
        one = ssm_mod.init_ssm_state(cfg, batch, dtype_of(cfg))
        g_state = jax.tree.map(
            lambda t: jnp.zeros((groups, per) + t.shape, t.dtype), one)
        t_state = jax.tree.map(
            lambda t: jnp.zeros((max(tail, 1),) + t.shape, t.dtype), one)
        cache1 = attn.init_cache(self.attn_cfg, batch, s_max,
                                 cfg.num_kv_heads, dtype_of(cfg))
        caches = jax.tree.map(
            lambda t: jnp.zeros((groups,) + t.shape, t.dtype), cache1)
        return HybridDecodeState(
            ssm_grouped=g_state, ssm_tail=t_state, attn_caches=caches,
            pos=jnp.zeros((batch,), jnp.int32))

    def decode_step(self, params, state: HybridDecodeState, token: jax.Array
                    ) -> Tuple[jax.Array, HybridDecodeState]:
        cfg = self.cfg
        groups, per, tail = _layout(cfg)
        b = token.shape[0]
        x = params["embedding"][token].astype(dtype_of(cfg))
        x0 = x
        rope = rope_angles(state.pos[:, None].astype(jnp.float32),
                           cfg.resolved_head_dim, cfg.rope_theta)

        def group_body(x, inp):
            gp, gstate, cache = inp

            def inner(x, lp_st):
                lp, st = lp_st
                return self._mamba(lp, x, "step", st)

            x, new_states = maybe_scan(inner, x, (gp, gstate),
                                       cfg.scan_layers)
            x, new_cache = self._shared_block(params["shared"], x, x0, rope,
                                              "decode", cache, state.pos)
            return x, (new_states, new_cache)

        x, (new_g, new_caches) = maybe_scan(
            group_body, x,
            (params["grouped"], state.ssm_grouped, state.attn_caches),
            cfg.scan_layers)

        def tail_body(x, lp_st):
            lp, st = lp_st
            return self._mamba(lp, x, "step", st)

        if tail > 0:
            x, new_t = maybe_scan(tail_body, x,
                                  (params["tail"], state.ssm_tail),
                                  cfg.scan_layers)
        else:
            new_t = state.ssm_tail
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embedding"].astype(x.dtype))
        return logits, HybridDecodeState(
            ssm_grouped=new_g, ssm_tail=new_t, attn_caches=new_caches,
            pos=state.pos + 1)
