"""Grouped-query attention with KV cache, RoPE/M-RoPE, sliding window.

Three entry points share one core:
  * ``attend(..., mode="train")``   — full causal self-attention
  * ``attend(..., mode="prefill")`` — causal, writes the cache
  * ``attend(..., mode="decode")``  — one query step against the cache

The KV cache layout is (B, S_max, kv_heads, head_dim) with the *sequence*
dimension annotated ``kv_seq`` → context parallelism on the model axis for
long-context decode; GSPMD inserts the softmax partial reductions.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import apply_rope, normal_init
from repro.sharding import shard


class KVCache(NamedTuple):
    k: jax.Array      # (B, S_max, kv_heads, head_dim)
    v: jax.Array      # (B, S_max, kv_heads, head_dim)


def attn_init(key, cfg: ModelConfig, d_in: Optional[int] = None,
              dtype=None) -> dict:
    d = d_in or cfg.d_model
    h = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    scale = 1.0 / (d ** 0.5)
    p = {
        "wq": normal_init(ks[0], (d, nq * h), scale, dtype),
        "wk": normal_init(ks[1], (d, nkv * h), scale, dtype),
        "wv": normal_init(ks[2], (d, nkv * h), scale, dtype),
        "wo": normal_init(ks[3], (nq * h, cfg.d_model),
                          1.0 / ((nq * h) ** 0.5), dtype),
    }
    if cfg.qkv_bias:
        p["wq_b"] = jnp.zeros((nq * h,), dtype)
        p["wk_b"] = jnp.zeros((nkv * h,), dtype)
        p["wv_b"] = jnp.zeros((nkv * h,), dtype)
    return p


def _proj_qkv(p: dict, x: jax.Array, cfg: ModelConfig):
    b, s, _ = x.shape
    h = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt))
    if "wq_b" in p:
        q = q + p["wq_b"].astype(dt)
        k = k + p["wk_b"].astype(dt)
        v = v + p["wv_b"].astype(dt)
    q = shard(q.reshape(b, s, nq, h), "batch", "seq", "heads", None)
    k = shard(k.reshape(b, s, nkv, h), "batch", "seq", "kv_heads", None)
    v = shard(v.reshape(b, s, nkv, h), "batch", "seq", "kv_heads", None)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Scaled dot-product attention with GQA head-group expansion.

    q (B,Sq,Hq,D); k/v (B,Sk,Hkv,D); mask broadcastable (B,1,Sq,Sk) bool.

    K/V are consumed in their storage dtype (bf16) with f32 MXU accumulation
    (``preferred_element_type``) — converting the KV cache to f32 would 3×
    its HBM traffic, which dominated the decode-cell memory roofline
    (§Perf iteration C1).
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    qs = (q.astype(jnp.float32) / (d ** 0.5)).astype(q.dtype)
    qg = qs.reshape(b, sq, hkv, groups, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    # normalize mask (B?, 1, Sq, Sk) -> (B?, 1, 1, Sq, Sk) for the group axis
    scores = jnp.where(mask[:, :, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def causal_mask(sq: int, sk: int, offset: int = 0,
                window: int = 0) -> jax.Array:
    """(1, 1, sq, sk) causal (+optional sliding window) mask."""
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window > 0:
        m &= ki > qi - window
    return m[None, None]


def attend(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rope: Optional[Tuple[jax.Array, jax.Array]] = None,
    mode: str = "train",
    cache: Optional[KVCache] = None,
    pos: Optional[jax.Array] = None,      # decode: (B,) current positions
    kv_x: Optional[jax.Array] = None,     # cross-attention source
    causal: bool = True,
) -> Tuple[jax.Array, Optional[KVCache]]:
    b, s, _ = x.shape
    h = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads

    if kv_x is not None:                          # cross-attention
        q, _, _ = _proj_qkv(p, x, cfg)
        _, k, v = _proj_qkv(p, kv_x, cfg)
        if rope is not None:
            q = apply_rope(q, *rope)
        mask = jnp.ones((1, 1, s, k.shape[1]), bool)
        out = _sdpa(q, k, v, mask, cfg)
        return _wo(p, out, cfg), None

    q, k, v = _proj_qkv(p, x, cfg)
    if rope is not None:
        q = apply_rope(q, *rope)
        k = apply_rope(k, *rope)

    if mode == "train":
        mask = (causal_mask(s, s, 0, cfg.sliding_window)
                if causal else jnp.ones((1, 1, s, s), bool))
        out = _sdpa(q, k, v, mask, cfg)
        return _wo(p, out, cfg), None

    if mode == "prefill":
        assert cache is not None
        s_max = cache.k.shape[1]
        k_pad = jnp.zeros_like(cache.k).at[:, :s].set(k.astype(cache.k.dtype))
        v_pad = jnp.zeros_like(cache.v).at[:, :s].set(v.astype(cache.v.dtype))
        k_pad = shard(k_pad, "batch", "kv_seq", "kv_heads", None)
        v_pad = shard(v_pad, "batch", "kv_seq", "kv_heads", None)
        mask = causal_mask(s, s, 0, cfg.sliding_window)
        out = _sdpa(q, k, v, mask, cfg)
        return _wo(p, out, cfg), KVCache(k=k_pad, v=v_pad)

    if mode == "decode":
        assert cache is not None and pos is not None
        # write this step's k/v at pos (B,) with a where-mask.  (§Perf
        # iteration C2 tried batched dynamic_update_slice here — REFUTED:
        # vmapped dus lowers to scatter, which breaks in-place aliasing
        # under SPMD and copies the whole cache; the masked select fuses
        # into a single aliased pass instead.)
        idx = pos[:, None, None, None]                     # (B,1,1,1)
        seq_iota = jnp.arange(cache.k.shape[1])[None, :, None, None]
        sel = seq_iota == idx
        k_new = jnp.where(sel, k.astype(cache.k.dtype), cache.k)
        v_new = jnp.where(sel, v.astype(cache.v.dtype), cache.v)
        k_new = shard(k_new, "batch", "kv_seq", "kv_heads", None)
        v_new = shard(v_new, "batch", "kv_seq", "kv_heads", None)
        # attend over positions <= pos (and window if set)
        ki = jnp.arange(cache.k.shape[1])[None, None, None, :]
        mask = ki <= pos[:, None, None, None]
        if cfg.sliding_window > 0:
            mask &= ki > (pos[:, None, None, None] - cfg.sliding_window)
        out = _sdpa(q, k_new, v_new, mask, cfg)
        return _wo(p, out, cfg), KVCache(k=k_new, v=v_new)

    raise ValueError(mode)


def _wo(p: dict, out: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, nq, h = out.shape
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, nq * h),
                   p["wo"].astype(out.dtype))
    return shard(y, "batch", "seq", "embed")


def init_cache(cfg: ModelConfig, batch: int, s_max: int, n_kv: int,
               dtype=jnp.bfloat16) -> KVCache:
    h = cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, s_max, n_kv, h), dtype),
        v=jnp.zeros((batch, s_max, n_kv, h), dtype))
