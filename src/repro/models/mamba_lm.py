"""Mamba2 decoder-only LM (mamba2-2.7b) — attention-free, O(T) context."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.common import (cross_entropy, dtype_of, maybe_scan,
                                 normal_init, pdtype_of, rmsnorm,
                                 rmsnorm_init)
from repro.sharding import shard


class SSMDecodeState(NamedTuple):
    states: ssm_mod.SSMState     # leaves stacked (L, B, ...)
    pos: jax.Array


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _layer_init(self, key):
        cfg, pdt = self.cfg, pdtype_of(self.cfg)
        return {
            "norm": rmsnorm_init(cfg.d_model, pdt),
            "mamba": ssm_mod.mamba2_init(key, cfg, pdt),
        }

    def init(self, key) -> dict:
        cfg, pdt = self.cfg, pdtype_of(self.cfg)
        kE, kL = jax.random.split(key)
        layers = jax.vmap(self._layer_init)(
            jax.random.split(kL, cfg.num_layers))
        return {
            "embedding": normal_init(
                kE, (cfg.vocab_size, cfg.d_model), 0.02, pdt),
            "layers": layers,
            "final_norm": rmsnorm_init(cfg.d_model, pdt),
        }

    def forward(self, params, tokens, remat: bool = True,
                collect_state: bool = False):
        cfg = self.cfg
        x = params["embedding"][tokens].astype(dtype_of(cfg))
        x = shard(x, "batch", "seq", "embed")
        mode = "prefill" if collect_state else "train"

        def body(x, lp):
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, st = ssm_mod.mamba2_forward(
                lp["mamba"], h, cfg, return_state=collect_state)
            return x + y, st

        if remat and not collect_state:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, states = maybe_scan(body, x, params["layers"], cfg.scan_layers)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embedding"].astype(x.dtype))
        logits = shard(logits, "batch", "seq", "vocab")
        if collect_state:
            return logits, states
        return logits

    def loss(self, params, batch, remat: bool = True) -> jax.Array:
        logits = self.forward(params, batch["tokens"], remat=remat)
        return cross_entropy(logits, batch["targets"], batch["mask"])

    def prefill(self, params, tokens, s_max: int = 0
                ) -> Tuple[jax.Array, SSMDecodeState]:
        b, s = tokens.shape
        logits, states = self.forward(params, tokens, remat=False,
                                      collect_state=True)
        return logits[:, -1:], SSMDecodeState(
            states=states, pos=jnp.full((b,), s, jnp.int32))

    def init_decode_state(self, batch: int, s_max: int = 0) -> SSMDecodeState:
        cfg = self.cfg
        one = ssm_mod.init_ssm_state(cfg, batch, dtype_of(cfg))
        states = jax.tree.map(
            lambda t: jnp.zeros((cfg.num_layers,) + t.shape, t.dtype), one)
        return SSMDecodeState(states=states,
                              pos=jnp.zeros((batch,), jnp.int32))

    def decode_step(self, params, state: SSMDecodeState, token: jax.Array
                    ) -> Tuple[jax.Array, SSMDecodeState]:
        cfg = self.cfg
        x = params["embedding"][token].astype(dtype_of(cfg))

        def body(x, lp_st):
            lp, st = lp_st
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, new_st = ssm_mod.mamba2_step(lp["mamba"], h, cfg, st)
            return x + y, new_st

        x, new_states = maybe_scan(body, x, (params["layers"], state.states),
                                   cfg.scan_layers)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embedding"].astype(x.dtype))
        return logits, SSMDecodeState(states=new_states, pos=state.pos + 1)
