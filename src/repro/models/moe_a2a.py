"""Expert-parallel MoE via explicit shard_map all-to-all.

WHY: the einsum/scatter MoE in ``moe.py`` lowers terribly under GSPMD — the
global dispatch scatter gets partitioned into one-hot dense ops and full
rematerializations (measured: useful-FLOPs ratio 0.001–0.011 on the MoE
cells, §Perf).  Inside ``shard_map`` every scatter is device-LOCAL, and the
inter-device movement is two explicit ``all_to_all``s — the textbook
expert-parallel schedule (GShard/Switch).

Two paths, chosen by divisibility of num_experts by the model-axis size:

* **a2a path** (qwen3: 128 experts / 16 devices → 8 local experts):
  tokens are bucketed by destination device (send capacity
  ``cf·T_local·k/M``), exchanged with all_to_all, regrouped per local
  expert, FFN'd, exchanged back, and combined locally.
* **tp path** (grok-1: 8 experts on a 16-wide axis): experts keep their
  tensor-parallel f-shard; tokens stay put; dispatch/combine are local;
  the down-projection psums over the model axis.  Weight FSDP shards are
  all-gathered over ``data`` explicitly (one tiled all-gather per layer —
  exactly what GSPMD would emit, minus the scatter pathology).

Interface mirrors ``moe.moe_ffn``; ``moe_ffn_sharded`` is dropped into the
transformer when ``use_rules(..., moe_impl="a2a")`` is active.  Numerics
match ``moe.moe_ffn`` exactly when capacities are generous (tested on an
8-device subprocess mesh).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding import _active_mesh

# set by use_moe_impl / dryrun rules-override to route through this module
_IMPL = {"mode": "gspmd"}   # "gspmd" | "a2a"


def set_moe_impl(mode: str):
    _IMPL["mode"] = mode


def moe_impl() -> str:
    return _IMPL["mode"]


def _axis_size(axis: str) -> int:
    try:
        return jax.lax.axis_size(axis)
    except NameError:
        return 1


def _rank_within(ids: jax.Array, num_buckets: int) -> jax.Array:
    """Exclusive rank of each element within its bucket (local, exact)."""
    onehot = jax.nn.one_hot(ids, num_buckets, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.sum(pos * onehot, axis=-1)


def _gather_fsdp(w: jax.Array, axis: int, data_axes) -> jax.Array:
    """Explicit FSDP all-gather of a weight shard inside shard_map.

    Crucially, the TRANSPOSE of all_gather is psum_scatter: the weight
    cotangent leaves as a reduce-scatter into the FSDP shard instead of a
    full all-reduce (§Perf iteration A4 — halves grad-sync wire bytes).
    """
    for a in data_axes:
        if a != "model":
            w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
    return w


def _local_ffn(disp: jax.Array, wg, wu, wd) -> jax.Array:
    """(E_loc, C, d) × per-expert SwiGLU -> (E_loc, C, d_out)."""
    dt = disp.dtype
    g = jnp.einsum("ecd,edf->ecf", disp, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", disp, wu.astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))


def moe_ffn_a2a_local(
    x: jax.Array,           # (T_local, d): tokens sharded over ALL axes
    router_w: jax.Array,    # (d, E) replicated
    wg: jax.Array, wu: jax.Array, wd: jax.Array,   # local expert shards
    cfg: ModelConfig,
    token_axes: Tuple[str, ...] = ("data", "model"),
    model_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """shard_map body: expert-parallel MoE with explicit all_to_all.

    Tokens are sharded over every mesh axis (DP×EP token layout) so each
    token exists exactly once; experts shard over ``model_axis``.
    wg/wu: (E_local, d, f); wd: (E_local, f, d).
    Returns (y (T_local, d), aux ()).
    """
    m = cfg.moe
    t, d = x.shape
    k = m.top_k
    n_dev = _axis_size(model_axis)
    e_local = m.num_experts // n_dev

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, m.num_experts,
                                         dtype=jnp.float32), axis=1), axis=0)
    # aux over the global batch: mean over every token-sharding axis
    aux = m.aux_loss_weight * m.num_experts * jnp.sum(
        jax.lax.pmean(me, token_axes) * jax.lax.pmean(ce, token_axes))

    # ---- bucket assignments by destination device (all local ops) ----
    flat_e = top_e.reshape(-1)                       # (T*k,)
    gates = top_p.reshape(-1)
    tok = jnp.repeat(jnp.arange(t), k)
    dst = flat_e // e_local                          # (T*k,) in [0, n_dev)
    cap_s = max(8, int(m.capacity_factor * t * k / n_dev + 3) // 4 * 4)
    send_pos = _rank_within(dst, n_dev)
    keep = send_pos < cap_s
    send_pos_c = jnp.where(keep, send_pos, 0)
    dst_c = jnp.where(keep, dst, 0)

    send_x = jnp.zeros((n_dev, cap_s, d), x.dtype)
    send_x = send_x.at[dst_c, send_pos_c].add(
        jnp.where(keep[:, None], x[tok], 0).astype(x.dtype))
    send_eid = jnp.full((n_dev, cap_s), -1, jnp.int32)
    send_eid = send_eid.at[dst_c, send_pos_c].max(
        jnp.where(keep, flat_e % e_local, -1))

    # ---- exchange: tokens travel to their experts' device ----
    recv_x = jax.lax.all_to_all(send_x, model_axis, split_axis=0,
                                concat_axis=0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
    rx = recv_x.reshape(n_dev * cap_s, d)
    re = recv_eid.reshape(n_dev * cap_s)
    valid = re >= 0
    re_c = jnp.where(valid, re, 0)

    # FSDP: gather d-dim weight shards (bwd = reduce-scatter, not AR)
    wg = _gather_fsdp(wg, 1, token_axes)
    wu = _gather_fsdp(wu, 1, token_axes)
    wd = _gather_fsdp(wd, 2, token_axes)

    # ---- regroup by local expert (local scatter) ----
    cap_e = max(8, int(m.capacity_factor * t * k * n_dev
                       / m.num_experts + 3) // 4 * 4)
    pos_e = _rank_within(jnp.where(valid, re_c, e_local), e_local + 1)
    keep_e = valid & (pos_e < cap_e)
    pos_e_c = jnp.where(keep_e, pos_e, 0)
    ebuf = jnp.zeros((e_local, cap_e, d), x.dtype)
    ebuf = ebuf.at[jnp.where(keep_e, re_c, 0), pos_e_c].add(
        jnp.where(keep_e[:, None], rx, 0).astype(x.dtype))

    y_e = _local_ffn(ebuf, wg, wu, wd)               # (E_loc, cap_e, d)

    # ---- route results back through the same slots ----
    back = jnp.where(
        keep_e[:, None],
        y_e[jnp.where(keep_e, re_c, 0), pos_e_c], 0).astype(x.dtype)
    back = back.reshape(n_dev, cap_s, d)
    recv_back = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                   concat_axis=0, tiled=False)

    # ---- combine locally: weighted sum per source token ----
    got = recv_back[dst_c, send_pos_c]               # (T*k, d)
    w = (gates * keep).astype(jnp.float32)
    yt = jnp.zeros((t, d), jnp.float32)
    yt = yt.at[tok].add(got.astype(jnp.float32) * w[:, None])
    return yt.astype(x.dtype), aux


def moe_ffn_tp_local(
    x: jax.Array,           # (T_local, d): tokens sharded over data axes only
    router_w: jax.Array,    # (d, E)
    wg: jax.Array, wu: jax.Array, wd: jax.Array,  # (E, d, f_loc)/(E, f_loc, d)
    cfg: ModelConfig,
    token_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """shard_map body for E < model-axis: tensor-parallel experts.

    Tokens stay on their data shard (REPLICATED over ``model_axis`` — the
    work split there is the f dim); every device computes all experts on
    its token shard with its f-shard; the down-projection psums over
    ``model_axis``.  Dispatch/combine scatters are local.
    """
    m = cfg.moe
    t, d = x.shape
    k = m.top_k
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, m.num_experts,
                                         dtype=jnp.float32), axis=1), axis=0)
    aux = m.aux_loss_weight * m.num_experts * jnp.sum(
        jax.lax.pmean(me, token_axes) * jax.lax.pmean(ce, token_axes))

    wg = _gather_fsdp(wg, 1, token_axes)
    wu = _gather_fsdp(wu, 1, token_axes)
    wd = _gather_fsdp(wd, 2, token_axes)

    flat_e = top_e.reshape(-1)
    gates = top_p.reshape(-1)
    tok = jnp.repeat(jnp.arange(t), k)
    cap = max(8, int(m.capacity_factor * t * k / m.num_experts + 3) // 4 * 4)
    pos = _rank_within(flat_e, m.num_experts)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    e_c = jnp.where(keep, flat_e, 0)
    disp = jnp.zeros((m.num_experts, cap, d), x.dtype)
    disp = disp.at[e_c, pos_c].add(
        jnp.where(keep[:, None], x[tok], 0).astype(x.dtype))

    y_e = _local_ffn(disp, wg, wu, wd)               # f_loc partial sums
    y_e = jax.lax.psum(y_e, model_axis)              # TP reduction

    got = y_e[e_c, pos_c]
    w = (gates * keep).astype(jnp.float32)
    yt = jnp.zeros((t, d), jnp.float32)
    yt = yt.at[tok].add(got.astype(jnp.float32) * w[:, None])
    return yt.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# jit-level wrapper (called from the transformer layer)
# ---------------------------------------------------------------------------

def moe_ffn_sharded(p: dict, x: jax.Array, cfg: ModelConfig
                    ) -> Tuple[jax.Array, jax.Array]:
    """Drop-in replacement for ``moe.moe_ffn`` using shard_map EP/TP.

    Falls back to the GSPMD einsum path when no mesh is active.
    """
    from jax.sharding import PartitionSpec as P

    mesh = _active_mesh.get()
    if mesh is None:
        from repro.models import moe as moe_mod
        return moe_mod.moe_ffn(p, x, cfg)

    b, s, d = x.shape
    names = mesh.axis_names
    msize = dict(zip(names, mesh.devices.shape)).get("model", 1)
    a2a = msize > 1 and cfg.moe.num_experts % msize == 0
    token_axes = tuple(a for a in ("pod", "data", "model") if a in names
                       and (a != "model" or a2a))
    xt = x.reshape(b * s, d)

    data_only = tuple(a for a in ("pod", "data") if a in names)
    dspec = data_only if len(data_only) > 1 else (
        data_only[0] if data_only else None)
    if a2a:
        # weight in_specs MIRROR the FSDP storage sharding so nothing is
        # re-sharded at the shard_map boundary; gathers happen inside
        # (transpose = reduce-scatter, §Perf A4)
        eg = P("model", dspec, None)
        ed = P("model", None, dspec)
        body = functools.partial(moe_ffn_a2a_local, cfg=cfg,
                                 token_axes=token_axes, model_axis="model")
    else:
        eg = P(None, dspec, "model")
        ed = P(None, "model", dspec)
        body = functools.partial(moe_ffn_tp_local, cfg=cfg,
                                 token_axes=token_axes, model_axis="model")

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(token_axes if len(token_axes) > 1 else
                    (token_axes[0] if token_axes else None), None),
                  P(), eg, eg, ed),
        out_specs=(P(token_axes if len(token_axes) > 1 else
                     (token_axes[0] if token_axes else None), None), P()),
        check_vma=False)
    y, aux = fn(xt, p["router"], p["moe_gate"], p["moe_up"], p["moe_down"])
    return y.reshape(b, s, d), aux
