"""Sharded, atomic, reshard-on-restore checkpointing.

Layout:  <dir>/step_<N>/arrays.npz  + manifest.json (tree structure, shapes,
dtypes).  Writes go to a temp dir + atomic rename, so a crash mid-save never
corrupts the latest checkpoint (fault tolerance requirement).  Restore can
re-shard onto ANY mesh (elastic scaling): leaves are loaded on host and
``jax.device_put`` against the target sharding, so a checkpoint taken on a
16×16 pod restores onto 2×16×16, a single host, or anything in between.

``CheckpointManager`` adds async (background-thread) saves and keep-last-k
garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


from repro.sharding import keystr_simple


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[keystr_simple(path)] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree, extra: Optional[dict]
                    = None) -> str:
    """Atomic save: write to tmp, fsync, rename."""
    flat = _flatten(tree)
    target = os.path.join(directory, f"step_{step:08d}")
    tmp = target + f".tmp.{os.getpid()}.{int(time.time() * 1e6)}"
    os.makedirs(tmp, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
        "treedef": None,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(target):
        shutil.rmtree(target)
    os.replace(tmp, target)
    return target


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and "tmp" not in d]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like,
                    shardings=None):
    """Restore into the structure of ``like``; optional target shardings
    (pytree of jax.sharding.Sharding) re-shard every leaf (elastic)."""
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    restored_flat = {}
    for k, leaf in flat_like.items():
        arr = data[k]
        restored_flat[k] = arr
    # rebuild the tree in ``like``'s structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = [keystr_simple(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    new_leaves = [restored_flat[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
    else:
        tree = jax.tree.map(
            lambda arr, ref: jax.numpy.asarray(arr, dtype=ref.dtype),
            tree, like)
    return tree


class CheckpointManager:
    """Async saves + keep-last-k retention."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        # snapshot to host synchronously (cheap vs device compute), write
        # in the background so the train loop keeps going
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and "tmp" not in d)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return load_checkpoint(self.directory, step, like, shardings), step
