from repro.checkpoint.ckpt import (CheckpointManager, load_checkpoint,  # noqa: F401
                                   save_checkpoint, latest_step)
