from repro.data.vectors import make_vector_dataset, VectorDataset  # noqa: F401
from repro.data.tokens import TokenStream, synthetic_batches  # noqa: F401
