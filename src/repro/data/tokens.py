"""Deterministic synthetic token pipeline for LM training.

Shard-aware: each data-parallel host slice draws a disjoint, reproducible
stream (seeded by (seed, shard, step)), so restarts resume mid-epoch exactly
— required for checkpoint/restart fault tolerance.  A background prefetch
thread hides host-side generation latency.
"""
from __future__ import annotations

import queue as _queue
import threading
from typing import Iterator, NamedTuple

import numpy as np


class TokenStream(NamedTuple):
    vocab_size: int
    seq_len: int
    batch: int              # per-shard batch
    seed: int
    shard: int
    num_shards: int


def _batch_at(stream: TokenStream, step: int) -> dict:
    """Markov-ish synthetic tokens: structured enough that loss decreases."""
    rng = np.random.RandomState(
        (stream.seed * 1_000_003 + stream.shard * 7919 + step) % (2**31 - 1))
    b, s, v = stream.batch, stream.seq_len, stream.vocab_size
    # mixture of a few "topics" -> learnable bigram structure
    topic = rng.randint(0, 8, size=(b, 1))
    base = rng.randint(0, v, size=(b, s))
    drift = (np.arange(s)[None, :] * (topic + 1)) % v
    tokens = ((base // 4) * 4 + drift % 4) % v
    inputs = tokens[:, :-1].astype(np.int32)
    targets = tokens[:, 1:].astype(np.int32)
    return {"tokens": inputs, "targets": targets,
            "mask": np.ones_like(inputs, np.float32)}


def synthetic_batches(
    stream: TokenStream, start_step: int = 0, prefetch: int = 2,
) -> Iterator[dict]:
    """Iterator with background prefetch, resumable at ``start_step``."""
    q: _queue.Queue = _queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(_batch_at(stream, step), timeout=0.1)
                step += 1
            except _queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
