"""Synthetic vector datasets standing in for SIFT/GIST/DEEP (Table 3).

Clustered Gaussians reproduce the locality structure graph-ANN relies on;
scale/dimension are configurable so each paper dataset has a laptop-scale
analog with the same dimensionality (SIFT: d=128, GIST: d=960, DEEP: d=96).
Exact ground truth comes from the blocked brute-force kNN in core.build.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.build import exact_knn


class VectorDataset(NamedTuple):
    name: str
    base: np.ndarray        # (N, d) float32
    queries: np.ndarray     # (Q, d) float32
    gt_ids: np.ndarray      # (Q, k) int32 exact nearest neighbors
    gt_dists: np.ndarray    # (Q, k) float32
    centers: np.ndarray     # (n_clusters, d) generative cluster centers


# dimensionalities of the paper's datasets (Table 3)
PAPER_DIMS = {"sift": 128, "gist": 960, "deep": 96}


def make_vector_dataset(
    name: str = "sift",
    n: int = 10_000,
    n_queries: int = 100,
    k: int = 100,
    n_clusters: int = 64,
    seed: int = 0,
    dim: int | None = None,
) -> VectorDataset:
    d = dim or PAPER_DIMS.get(name, 128)
    rng = np.random.RandomState(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * 4.0
    assign = rng.randint(0, n_clusters, size=n)
    base = centers[assign] + rng.normal(size=(n, d)).astype(np.float32)
    qa = rng.randint(0, n_clusters, size=n_queries)
    queries = centers[qa] + rng.normal(size=(n_queries, d)).astype(np.float32)
    gt_ids, gt_dists = exact_knn(base, queries, k)
    return VectorDataset(name, base.astype(np.float32),
                         queries.astype(np.float32), gt_ids, gt_dists,
                         centers)
