"""``AnnIndex`` — the one public API for vector search.

The paper separates the index (CSR topology + vectors, §3.2) from the search
algorithm (BFiS / top-M / Speed-ANN, Alg. 1–3); this class is that
separation as an object with a full lifecycle::

    from repro.ann import AnnIndex, IndexSpec, SearchParams

    index = AnnIndex.build(dataset, IndexSpec(metric="cosine", degree=24))
    index.save("/tmp/idx.npz")

    index = AnnIndex.load("/tmp/idx.npz")
    res = index.search(queries, SearchParams(algorithm="speedann", m_max=8))
    engine = index.serve(SearchParams(k=10))        # batched AnnEngine

Every algorithm in {bfis, topm, speedann, sharded} and every registered
distance backend serves every metric in {l2, ip, cosine}: metric handling
(query normalization for cosine, negative-inner-product kernels for ip) and
neighbor-grouping id remapping live HERE, so callers never hand-wire
``PaddedCSR`` + ``SearchConfig`` + ``resolve_dist_fn`` again.

Quantized storage (``repro.quant``) threads through the same lifecycle:
``IndexSpec(quant="int8"|"bf16")`` trains scales at build time and attaches
a codes table the quantized distance backends (``ref_int8`` |
``rowgather_int8`` | ``ref_bf16``) gather from, ``save``/``load`` round-trip
codes + scales, and ``SearchParams(rerank_k=...)`` turns any search into the
AQR-HNSW two-stage shape — quantized traversal over a widened pool, then
exact float32 re-ranking::

    spec = IndexSpec(metric="l2", quant="int8")
    index = AnnIndex.build(dataset, spec)
    res = index.search(queries, SearchParams(k=10, backend="ref_int8",
                                             rerank_k=30))

Searches are BATCH-MAJOR end to end: a (B, d) query batch advances through
one traversal loop with one distance-kernel launch per global step (see
``core.bfis``), so larger batches amortize per-step launch cost.  For
``metric="ip"``, ``IndexSpec(entry_policy="max_norm")`` seeds traversals at
the max-norm vertex instead of the centroid medoid (the MIPS entry
heuristic for skewed-norm distributions).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.spec import IndexSpec, SearchParams
from repro.core.bfis import (bfis_search_batch, hnsw_search_batch,
                             search_topm_batch)
from repro.core.build import (HNSWIndex, build_hnsw, build_nsg, exact_knn,
                              insert_points, normalize_rows, repair_deleted)
from repro.core.graph import (PaddedCSR, compute_medoid, group_by_indegree,
                              remap_sentinels)
from repro.core.speedann import search_speedann_batch
from repro.quant import codec as quant_codec
from repro.quant.scheme import required_quant_dtype

# format 2 adds quantized storage: codes + scales arrays, and indices whose
# f32 vectors are not persisted (QuantSpec.keep_float=False) — readable only
# by code that knows to dequantize.  Format-1 files load unchanged.
# format 3 adds the tombstone array (incremental delete) — stamped only when
# at least one vertex is actually tombstoned, so add-only/static indices stay
# readable by format-2 readers.
_SAVE_FORMAT = 3


class SearchResult(NamedTuple):
    """One batched search: ids/dists (B, k) + per-query SearchStats."""
    ids: jax.Array
    dists: jax.Array
    stats: object


def default_search_mesh():
    """(data=1, model=n_devices) mesh for the "sharded" algorithm when the
    caller does not provide one.  On a single-device host this degenerates
    to one walker — the same code path, no special-casing."""
    from repro.core.distributed import make_search_mesh
    return make_search_mesh((1, len(jax.devices())), ("data", "model"))


def normalize_queries(q: jax.Array) -> jax.Array:
    """Unit-normalize a (B, d) query batch (cosine = ip on the unit
    sphere).  Shared by ``AnnIndex.searcher`` and the serving engine so the
    two paths cannot drift."""
    return q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)


def remap_result_ids(ids: jax.Array, old_from_new: jax.Array,
                     n_nodes: int) -> jax.Array:
    """Map grouped (relabelled) result ids back to the caller's original id
    space; sentinel/invalid ids (>= n_nodes) pass through unchanged."""
    safe = jnp.minimum(ids, n_nodes - 1)
    return jnp.where(ids < n_nodes, old_from_new[safe], ids)


def exact_rerank(graph: PaddedCSR, q: jax.Array, ids: jax.Array, k: int,
                 metric: str):
    """Second stage of the two-stage search: exactly re-rank a (B, P)
    candidate pool against the float32 vectors and return the top k.

    Runs in INTERNAL (pre-remap) id space so the vector gather is direct;
    sentinel ids (>= N) re-rank to +inf and sink to the tail.  Ties break on
    id, so the result order is deterministic across backends.
    """
    n = graph.n_nodes
    safe = jnp.minimum(ids, n - 1)
    vecs = graph.vectors[safe].astype(jnp.float32)        # (B, P, d)
    qf = q.astype(jnp.float32)[:, None, :]
    if metric in ("ip", "cosine"):
        d = -jnp.sum(vecs * qf, axis=-1)
    else:
        d = jnp.sum((vecs - qf) ** 2, axis=-1)
    d = jnp.where(ids < n, d, jnp.inf)
    d, ids = jax.lax.sort((d, ids.astype(jnp.int32)), num_keys=2,
                          is_stable=True, dimension=-1)
    return ids[:, :k], d[:, :k]


def apply_entry_policy(graph: PaddedCSR, spec: IndexSpec) -> PaddedCSR:
    """Build-time traversal-entry selection (``IndexSpec.entry_policy``).

    ``"max_norm"`` replaces the medoid with the max-norm vertex — the MIPS
    seed heuristic: inner-product search converges to a region dominated by
    large-norm points, so seeding there skips the climb out of the centroid
    vertex's small-norm neighborhood.  Runs LAST in the build pipeline, on
    the stored (post-relabelling, post-quantization) vectors, so the entry
    id is in internal id space and consistent with what searches will see.
    """
    if spec.entry_policy != "max_norm":
        return graph
    norms = np.linalg.norm(np.asarray(graph.vectors, np.float32), axis=1)
    return graph._replace(
        medoid=jnp.asarray(int(np.argmax(norms)), jnp.int32))


def quantize_graph(graph: PaddedCSR, quant) -> PaddedCSR:
    """Attach a trained quantized table (codes + scales) to a built graph.

    Scales are calibrated on the STORED vectors — post-normalization (cosine)
    and post-relabelling (neighbor grouping) — so ``codes[i]`` always encodes
    ``vectors[i]``.

    With ``keep_float=False`` the exact f32 table is dropped HERE, already at
    build time: ``vectors`` (and the flattened hot-vertex blocks) become the
    dequantized codes, so an in-memory index and its save/load round-trip are
    bit-identical — persistence never changes search results."""
    if not quant.enabled:
        return graph
    scales = quant_codec.fit_scales(graph.vectors, quant)
    codes = quant_codec.quantize(graph.vectors, quant, scales)
    graph = graph._replace(codes=codes,
                           scales=jnp.asarray(scales, jnp.float32))
    if not quant.keep_float:
        vectors = quant_codec.dequantize(codes, quant, graph.scales)
        flat = graph.flat
        if graph.n_top > 0:
            from repro.core.graph import _flatten_top
            flat = jnp.asarray(_flatten_top(
                np.asarray(graph.nbrs), np.asarray(vectors), graph.n_top))
        graph = graph._replace(vectors=vectors, flat=flat)
    return graph


class AnnIndex:
    """A built similarity-graph index + its :class:`IndexSpec`.

    Construct via :meth:`build` or :meth:`load`, never directly (the
    constructor is public only for internal wiring and tests).
    """

    def __init__(self, spec: IndexSpec, graph: PaddedCSR,
                 hnsw: Optional[HNSWIndex] = None,
                 old_from_new: Optional[np.ndarray] = None,
                 tombstone: Optional[np.ndarray] = None):
        self.spec = spec
        self.graph = graph
        self.hnsw = hnsw
        # neighbor grouping relabels vertices; old_from_new maps result ids
        # back to the caller's original ids (None when no relabelling)
        self.old_from_new = (None if old_from_new is None
                             else np.asarray(old_from_new, np.int64))
        # incremental delete: (N,) bool in INTERNAL id space; tombstoned
        # vertices stay in the graph as navigable waypoints but are masked
        # out of every search/exact result (None == nothing deleted)
        self.tombstone = (None if tombstone is None
                          else np.asarray(tombstone, bool))
        # device-resident remap table, uploaded once per index (it enters
        # every searcher's executable as a jit argument, like the graph)
        self._ofn = (jnp.asarray(self.old_from_new, jnp.int32)
                     if self.old_from_new is not None
                     else jnp.zeros((0,), jnp.int32))
        self._tomb = (jnp.asarray(self.tombstone)
                      if self.tombstone is not None
                      else jnp.zeros((0,), jnp.bool_))
        self._searcher_cache: Dict = {}
        self._host_vectors: Optional[np.ndarray] = None  # exact() cache

    # -- introspection -----------------------------------------------------

    @property
    def n_nodes(self) -> int:
        return self.graph.n_nodes

    @property
    def dim(self) -> int:
        return self.graph.dim

    @property
    def metric(self) -> str:
        return self.spec.metric

    @property
    def n_alive(self) -> int:
        """Live (non-tombstoned) vertex count."""
        dead = 0 if self.tombstone is None else int(self.tombstone.sum())
        return self.n_nodes - dead

    def __repr__(self) -> str:
        return (f"AnnIndex(builder={self.spec.builder!r}, "
                f"metric={self.spec.metric!r}, n={self.n_nodes}, "
                f"d={self.dim}, degree={self.graph.degree})")

    # -- build -------------------------------------------------------------

    @classmethod
    def build(cls, data, spec: IndexSpec = IndexSpec()) -> "AnnIndex":
        """Build an index over ``data`` ((N, d) array-like, or anything with
        a ``.base`` attribute such as ``repro.data.VectorDataset``).

        For ``metric="cosine"`` the base vectors are unit-normalized here
        and stored normalized (cosine == inner product on the unit sphere);
        queries are normalized symmetrically at search time.
        """
        # unwrap dataset-like objects (e.g. repro.data.VectorDataset) — but
        # never raw arrays: np.ndarray itself exposes a ``.base`` attribute
        # (its memory owner), which must not be mistaken for a dataset field
        if not isinstance(data, (np.ndarray, jax.Array)) \
                and getattr(data, "base", None) is not None:
            data = data.base
        data = np.asarray(data, np.float32)
        if data.ndim != 2:
            raise ValueError(f"data must be (N, d), got {data.shape}")
        if spec.metric == "cosine":
            data = normalize_rows(data)
        build_metric = "l2" if spec.metric == "cosine" else spec.metric

        if spec.builder == "hnsw":
            hnsw = build_hnsw(data, degree=spec.degree,
                              upper_degree=spec.upper_degree,
                              seed=spec.seed, alpha=spec.alpha,
                              metric=build_metric,
                              build_batch=spec.build_batch,
                              build_backend=spec.build_backend)
            base = apply_entry_policy(
                quantize_graph(hnsw.base, spec.quant), spec)
            return cls(spec, base, hnsw=hnsw._replace(base=base))

        graph = build_nsg(data, degree=spec.degree,
                          knn_k=spec.resolved_knn_k, alpha=spec.alpha,
                          ef_construction=spec.resolved_ef, seed=spec.seed,
                          passes=spec.passes, metric=build_metric,
                          build_batch=spec.build_batch,
                          build_backend=spec.build_backend)
        old_from_new = None
        if spec.n_top_fraction > 0:
            graph, old_from_new = group_by_indegree(
                np.asarray(graph.nbrs), np.asarray(graph.vectors),
                medoid=int(graph.medoid),
                top_fraction=spec.n_top_fraction)
        graph = apply_entry_policy(quantize_graph(graph, spec.quant), spec)
        return cls(spec, graph, old_from_new=old_from_new)

    # -- incremental maintenance -------------------------------------------

    def _build_metric(self) -> str:
        return "l2" if self.spec.metric == "cosine" else self.spec.metric

    def _invalidate(self) -> None:
        """Drop every cache derived from the graph arrays (after mutation)."""
        self._searcher_cache = {}
        self._host_vectors = None

    def add(self, new_vectors) -> np.ndarray:
        """Insert new vectors into the live index without a rebuild.

        Runs the SAME batched insertion path as construction
        (:func:`repro.core.build.insert_points`) against the live graph:
        one candidate-search round through the jit engine, vectorized
        α-prune, deterministic reverse edges.  Cosine inputs are normalized
        here; quantized indices quantize the new rows consistently
        (per-vector scales are fit per new row, per-dim scales are reused so
        existing codes stay bit-identical); the flattened top level is
        rebuilt when present.  Returns the assigned ids in the caller's
        (original) id space.
        """
        if self.spec.builder == "hnsw":
            raise NotImplementedError(
                "incremental add() is supported for the nsg builder only "
                "(the hnsw upper levels would need re-sampling)")
        new = np.asarray(new_vectors, np.float32)
        if new.ndim == 1:
            new = new[None, :]
        if new.ndim != 2 or new.shape[1] != self.dim:
            raise ValueError(
                f"new vectors must be (K, {self.dim}), got {new.shape}")
        if new.shape[0] == 0:
            return np.zeros((0,), np.int64)
        if self.spec.metric == "cosine":
            new = normalize_rows(new)

        spec, quant = self.spec, self.spec.quant
        n_old = self.n_nodes
        n_new = n_old + new.shape[0]

        # grow the adjacency; the sentinel changes value with N, so the old
        # rows' padding must be rewritten BEFORE the table grows
        nbrs = np.full((n_new, self.graph.degree), n_new, np.int32)
        nbrs[:n_old] = remap_sentinels(
            np.asarray(self.graph.nbrs), n_old, n_new)

        vectors = np.asarray(self.graph.vectors, np.float32)
        codes = scales = None
        store_new = new
        if quant.enabled:
            if quant.dtype == "int8" and not quant.per_dim:
                # per-vector granularity: each row owns its scale, so new
                # rows calibrate independently and old codes are untouched
                s_new = quant_codec.fit_scales(new, quant)
                scales = jnp.concatenate(
                    [self.graph.scales, jnp.asarray(s_new, jnp.float32)])
            else:
                # per-dim (or bf16's placeholder): reuse the trained scales
                # — refitting would silently re-encode the whole table
                s_new = self.graph.scales
                scales = self.graph.scales
            c_new = quant_codec.quantize(new, quant, s_new)
            codes = jnp.concatenate([self.graph.codes, c_new])
            if not quant.keep_float:
                store_new = np.asarray(
                    quant_codec.dequantize(c_new, quant, s_new), np.float32)
        vectors = np.concatenate([vectors, store_new])

        new_ids = np.arange(n_old, n_new, dtype=np.int64)
        insert_points(
            nbrs, vectors, int(self.graph.medoid), new_ids, n_old,
            degree=spec.degree, alpha=spec.alpha, ef=spec.resolved_ef,
            metric=self._build_metric(), build_batch=spec.build_batch,
            build_backend=spec.build_backend)

        from repro.core.graph import _flatten_top
        flat = _flatten_top(nbrs, vectors, self.graph.n_top)
        self.graph = PaddedCSR(
            nbrs=jnp.asarray(nbrs), vectors=jnp.asarray(vectors),
            medoid=self.graph.medoid, n_top=self.graph.n_top,
            flat=jnp.asarray(flat), codes=codes, scales=scales)
        self.graph = apply_entry_policy(self.graph, spec)
        if self.old_from_new is not None:
            # new points keep identity labels past the grouped prefix
            self.old_from_new = np.concatenate(
                [self.old_from_new, new_ids])
            self._ofn = jnp.asarray(self.old_from_new, jnp.int32)
        if self.tombstone is not None:
            self.tombstone = np.concatenate(
                [self.tombstone, np.zeros(new_ids.shape[0], bool)])
            self._tomb = jnp.asarray(self.tombstone)
        self._invalidate()
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone vertices and repair their neighborhoods in place.

        FreshDiskANN-style lazy delete: the rows stay in the graph as
        navigable waypoints (their out-edges survive), every live
        in-neighbor re-prunes over its survivors plus the deleted vertex's
        live out-edges (:func:`repro.core.build.repair_deleted`), and every
        search / ``exact`` call masks tombstoned ids from results.  Returns
        the number of newly deleted vertices; already-deleted and duplicate
        ids are ignored.  Deleting every remaining vertex is refused.
        """
        if self.spec.builder == "hnsw":
            raise NotImplementedError(
                "incremental delete() is supported for the nsg builder only")
        ids = np.unique(np.asarray(ids, np.int64).ravel())
        if ids.shape[0] == 0:
            return 0
        n = self.n_nodes
        if self.old_from_new is not None:
            # callers speak original ids; tombstones live in internal space
            new_from_old = np.empty(self.old_from_new.shape[0], np.int64)
            new_from_old[self.old_from_new] = np.arange(
                self.old_from_new.shape[0])
            if ids[0] < 0 or ids[-1] >= new_from_old.shape[0]:
                raise ValueError(f"ids out of range [0, "
                                 f"{new_from_old.shape[0]})")
            internal = new_from_old[ids]
        else:
            if ids[0] < 0 or ids[-1] >= n:
                raise ValueError(f"ids out of range [0, {n})")
            internal = ids
        tomb = (self.tombstone.copy() if self.tombstone is not None
                else np.zeros(n, bool))
        fresh = internal[~tomb[internal]]
        if fresh.shape[0] == 0:
            return 0
        if int(tomb.sum()) + fresh.shape[0] >= n:
            raise ValueError("delete() would tombstone every vertex; "
                             "drop the index instead")
        tomb[fresh] = True

        spec = self.spec
        nbrs = np.asarray(self.graph.nbrs).copy()
        vectors = np.asarray(self.graph.vectors, np.float32)
        repair_deleted(nbrs, vectors, tomb, degree=spec.degree,
                       alpha=spec.alpha, metric=self._build_metric())

        medoid = self.graph.medoid
        if tomb[int(medoid)]:
            # the entry vertex died: re-elect among survivors (the row
            # itself stays — it is still a fine navigable waypoint)
            if spec.entry_policy == "max_norm":
                norms = np.linalg.norm(vectors, axis=1)
                medoid = jnp.asarray(
                    int(np.argmax(np.where(tomb, -np.inf, norms))),
                    jnp.int32)
            else:
                medoid = jnp.asarray(
                    compute_medoid(vectors, metric=self._build_metric(),
                                   alive=~tomb), jnp.int32)

        from repro.core.graph import _flatten_top
        flat = _flatten_top(nbrs, np.asarray(self.graph.vectors),
                            self.graph.n_top)
        self.graph = self.graph._replace(
            nbrs=jnp.asarray(nbrs), medoid=medoid, flat=jnp.asarray(flat))
        self.tombstone = tomb
        self._tomb = jnp.asarray(tomb)
        self._invalidate()
        return int(fresh.shape[0])

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> str:
        """npz round-trip of CSR + flat layout + medoid + spec (+ HNSW
        levels + grouping permutation + quantized codes/scales).  Returns
        the actual path written (numpy appends ``.npz`` when missing).

        With quantization and ``keep_float=False`` the float32 vectors are
        NOT persisted — the vector payload shrinks 4x (int8) / 2x (bf16) and
        ``load`` rebuilds the f32 table by dequantizing, so exact() and
        re-ranking then reference the quantized values."""
        path = str(path)
        if not path.endswith(".npz"):
            path += ".npz"
        quant = self.spec.quant
        # default-valued NEW spec fields are stripped from the json so
        # artifacts that don't use them stay loadable by readers that
        # predate the field: unquantized artifacts stay format-1 END TO END
        # (format-1 stamp AND no quant key), and a default "medoid" entry
        # policy leaves no entry_policy key
        has_tomb = self.tombstone is not None and bool(self.tombstone.any())
        fmt = 1
        if self.graph.codes is not None:
            fmt = 2
        if has_tomb:
            fmt = _SAVE_FORMAT
        spec_dict = dataclasses.asdict(self.spec)
        if not quant.enabled:
            del spec_dict["quant"]
        if self.spec.entry_policy == "medoid":
            del spec_dict["entry_policy"]
        if self.spec.build_batch == 32:
            del spec_dict["build_batch"]
        if self.spec.build_backend == "ref":
            del spec_dict["build_backend"]
        arrays = dict(
            format=np.int64(fmt),
            spec=np.asarray(json.dumps(spec_dict)),
            nbrs=np.asarray(self.graph.nbrs),
            medoid=np.asarray(self.graph.medoid, np.int32),
            n_top=np.int64(self.graph.n_top),
            flat=np.asarray(self.graph.flat),
        )
        if not quant.enabled or quant.keep_float:
            arrays["vectors"] = np.asarray(self.graph.vectors)
        if self.graph.codes is not None:
            codes = np.asarray(self.graph.codes)
            if quant.dtype == "bf16":
                # npz has no bfloat16 descr; persist the raw bit pattern
                codes = codes.view(np.uint16)
            arrays["codes"] = codes
            arrays["scales"] = np.asarray(self.graph.scales, np.float32)
        if self.old_from_new is not None:
            arrays["old_from_new"] = self.old_from_new
        if has_tomb:
            arrays["tombstone"] = self.tombstone
        if self.hnsw is not None:
            arrays["hnsw_entry"] = np.int64(self.hnsw.entry)
            arrays["hnsw_num_levels"] = np.int64(len(self.hnsw.level_nbrs))
            for i, (ln, nn) in enumerate(zip(self.hnsw.level_nbrs,
                                             self.hnsw.level_nodes)):
                arrays[f"hnsw_level_nbrs_{i}"] = np.asarray(ln)
                arrays[f"hnsw_level_nodes_{i}"] = np.asarray(nn)
        np.savez(path, **arrays)
        return path

    @classmethod
    def load(cls, path: str) -> "AnnIndex":
        path = str(path)
        if not path.endswith(".npz"):
            path += ".npz"
        z = np.load(path, allow_pickle=False)
        fmt = int(z["format"])
        if fmt > _SAVE_FORMAT:
            raise ValueError(f"index file format {fmt} is newer than this "
                             f"code ({_SAVE_FORMAT})")
        spec = IndexSpec(**json.loads(str(z["spec"])))
        codes = scales = None
        if "codes" in z.files:
            raw = z["codes"]
            if spec.quant.dtype == "bf16":
                import ml_dtypes
                raw = raw.view(ml_dtypes.bfloat16)
            codes = jnp.asarray(raw)
            scales = jnp.asarray(z["scales"], jnp.float32)
        if "vectors" in z.files:
            vectors = jnp.asarray(z["vectors"])
        else:
            # keep_float=False artifact: the f32 table is the dequantized
            # codes (exact() / re-ranking reference the quantized values)
            vectors = quant_codec.dequantize(codes, spec.quant, scales)
        graph = PaddedCSR(
            nbrs=jnp.asarray(z["nbrs"]),
            vectors=vectors,
            medoid=jnp.asarray(z["medoid"], jnp.int32),
            n_top=int(z["n_top"]),
            flat=jnp.asarray(z["flat"]),
            codes=codes,
            scales=scales,
        )
        old_from_new = (np.asarray(z["old_from_new"])
                        if "old_from_new" in z.files else None)
        tombstone = (np.asarray(z["tombstone"], bool)
                     if "tombstone" in z.files else None)
        hnsw = None
        if "hnsw_entry" in z.files:
            n_levels = int(z["hnsw_num_levels"])
            hnsw = HNSWIndex(
                base=graph,
                level_nbrs=tuple(jnp.asarray(z[f"hnsw_level_nbrs_{i}"])
                                 for i in range(n_levels)),
                level_nodes=tuple(jnp.asarray(z[f"hnsw_level_nodes_{i}"])
                                  for i in range(n_levels)),
                entry=int(z["hnsw_entry"]),
            )
        return cls(spec, graph, hnsw=hnsw, old_from_new=old_from_new,
                   tombstone=tombstone)

    # -- search ------------------------------------------------------------

    def searcher(self, params: SearchParams = SearchParams(), *,
                 mesh=None):
        """A jit-ready batched callable ``fn(queries (B, d)) ->
        SearchResult``.

        The compiled executable takes the graph arrays as jit ARGUMENTS (not
        closure constants), so searchers for different params share one
        device-resident embedding table.  Query normalization (cosine) and
        grouping id-remap run inside the jitted function.  Searchers are
        cached per (params, mesh) — repeated ``search`` calls reuse them.
        """
        key = (params, id(mesh) if mesh is not None else None)
        cached = self._searcher_cache.get(key)
        if cached is not None:
            return cached

        need = required_quant_dtype(params.backend)
        if need != "none" and self.spec.quant.dtype != need:
            raise ValueError(
                f"backend {params.backend!r} reads a {need} codes table; "
                f"this index has quant={self.spec.quant.dtype!r} — rebuild "
                f"with IndexSpec(quant={need!r}) or pick a matching backend")

        cfg = params.to_search_config(self.spec.metric)
        metric = self.spec.metric
        k, rerank_k = params.k, params.rerank_k
        if rerank_k > 0:
            # stage 1 traverses over a pool widened to max(k, rerank_k);
            # stage 2 re-ranks that pool exactly against the f32 vectors
            pool = max(k, rerank_k)
            cfg = cfg.with_(k=pool, queue_len=max(cfg.queue_len, pool))
        normalize = metric == "cosine"
        has_remap = self.old_from_new is not None
        has_tomb = self.tombstone is not None and bool(self.tombstone.any())
        ofn, tomb = self._ofn, self._tomb
        n_top, n_nodes = self.graph.n_top, self.graph.n_nodes
        algorithm = params.algorithm
        hnsw = self.hnsw

        if algorithm == "sharded":
            if need != "none":
                raise ValueError(
                    "quantized backends are not wired into the sharded "
                    "walker path; use a single-host algorithm "
                    "(bfis | topm | speedann) with backend "
                    f"{params.backend!r}")
            from repro.core.distributed import walker_sharded_search
            the_mesh = mesh if mesh is not None else default_search_mesh()

            def run(g, q):
                return walker_sharded_search(g, q, cfg, the_mesh)
        elif algorithm == "bfis" and hnsw is not None:
            # greedy upper-level descent, then Algorithm 1 at level 0; the
            # (small) upper-level tables ride along as closure constants
            def run(g, q):
                idx = hnsw._replace(base=g)
                return hnsw_search_batch(idx, q, cfg)
        elif algorithm == "bfis":
            def run(g, q):
                return bfis_search_batch(g, q, cfg)
        elif algorithm == "topm":
            def run(g, q):
                return search_topm_batch(g, q, cfg)
        elif algorithm == "speedann":
            def run(g, q):
                return search_speedann_batch(g, q, cfg)
        else:  # pragma: no cover - SearchParams validates
            raise ValueError(algorithm)

        @jax.jit
        def jitted(nbrs, vectors, medoid, flat, codes, scales, ofn_arr,
                   tomb_arr, q):
            g = PaddedCSR(nbrs=nbrs, vectors=vectors, medoid=medoid,
                          n_top=n_top, flat=flat, codes=codes, scales=scales)
            q = q.astype(jnp.float32)
            if normalize:
                q = normalize_queries(q)
            ids, dists, stats = run(g, q)
            if has_tomb:
                # tombstoned vertices are waypoints, never answers: mask
                # them to the sentinel (their slot distance to +inf) and
                # stable-sort live results to the front — BEFORE re-ranking
                # (which treats sentinels as +inf) and the grouping remap
                safe = jnp.minimum(ids, n_nodes - 1)
                dead = tomb_arr[safe] & (ids < n_nodes)
                dists = jnp.where(dead, jnp.inf, dists)
                ids = jnp.where(dead, n_nodes, ids).astype(jnp.int32)
                if rerank_k == 0:
                    dists, ids = jax.lax.sort(
                        (dists, ids), num_keys=2, is_stable=True,
                        dimension=-1)
            if rerank_k > 0:
                # the AQR-HNSW two-stage shape: quantized (or plain) best-
                # first traversal, then exact f32 re-ranking of the pool —
                # in internal id space, BEFORE the grouping remap
                ids, dists = exact_rerank(g, q, ids, k, metric)
            if has_remap:
                ids = remap_result_ids(ids, ofn_arr, n_nodes)
            return ids, dists, stats

        graph = self.graph

        def fn(queries) -> SearchResult:
            q = jnp.asarray(queries)
            if q.ndim != 2:
                raise ValueError(f"queries must be (B, d), got {q.shape}")
            out = jitted(graph.nbrs, graph.vectors, graph.medoid,
                         graph.flat, graph.codes, graph.scales, ofn, tomb,
                         q)
            return SearchResult(*out)

        self._searcher_cache[key] = fn
        return fn

    def search(self, queries, params: SearchParams = SearchParams(), *,
               mesh=None) -> SearchResult:
        """Search a (B, d) query batch; dispatches to ``params.algorithm``
        (including the ``shard_map`` walker path for "sharded")."""
        return self.searcher(params, mesh=mesh)(queries)

    # -- ground truth ------------------------------------------------------

    def exact(self, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Metric-aware exact kNN over the indexed vectors (brute force) —
        the recall reference for this index.  Returns original ids even for
        grouped (relabelled) indices."""
        if self._host_vectors is None:
            # one device->host copy per index, not per call (serving loops
            # compute per-batch ground truth); stored vectors are already
            # normalized for cosine, so "ip" gives identical distances
            # without re-normalizing the table every call
            self._host_vectors = np.asarray(self.graph.vectors, np.float32)
        q = np.asarray(queries, np.float32)
        metric = self.spec.metric
        if metric == "cosine":
            q = q / np.maximum(
                np.linalg.norm(q, axis=1, keepdims=True), 1e-12)
            metric = "ip"
        has_tomb = self.tombstone is not None and bool(self.tombstone.any())
        if has_tomb:
            # over-fetch so k live results survive the tombstone filter
            kk = min(k + int(self.tombstone.sum()), self.n_nodes)
            ids, dists = exact_knn(self._host_vectors, q, kk, metric=metric)
            dead = self.tombstone[ids]
            order = np.argsort(dead, axis=1, kind="stable")
            ids = np.take_along_axis(ids, order, axis=1)[:, :k]
            dists = np.take_along_axis(dists, order, axis=1)[:, :k]
        else:
            ids, dists = exact_knn(self._host_vectors, q, k, metric=metric)
        if self.old_from_new is not None:
            ids = self.old_from_new[ids].astype(np.int32)
        return ids, dists

    # -- serving -----------------------------------------------------------

    def serve(self, params: SearchParams = SearchParams(), *, mesh=None,
              obs=None, **engine_kw):
        """A bucketed, jit-cached :class:`repro.serve.AnnEngine` over this
        index (``engine_kw`` forwards e.g. ``bucket_sizes``).

        The engine serves the single-host algorithms (bfis | topm |
        speedann) and, with ``SearchParams(algorithm="sharded")``, the
        multi-device walker path — one Speed-ANN walker per device along
        ``mesh``'s ``model`` axis (``mesh=None``: the default
        (1, n_devices) search mesh).

        ``obs`` takes a :class:`repro.obs.Observability` bundle to enable
        request-scoped tracing + convergence telemetry (None: the no-op
        ``NULL_OBS`` — zero instrumentation cost).  See
        docs/observability.md."""
        from repro.serve.ann_engine import AnnEngine
        return AnnEngine(self, params, mesh=mesh, obs=obs, **engine_kw)

    def serve_async(self, params: SearchParams = SearchParams(), *,
                    max_batch: Optional[int] = None,
                    max_wait_ms: float = 2.0,
                    default_deadline_ms: Optional[float] = None,
                    mesh=None, start: bool = True, obs=None,
                    cache=None, admission=None, clock=None, **engine_kw):
        """An async coalescing front-end (:class:`repro.serve.coalescer.
        AsyncAnnEngine`) over :meth:`serve`: single queries with
        per-request deadlines in, bucketed batches through the jit cache,
        per-request futures back.

        ``max_batch`` defaults to the engine's top bucket so a full flush
        exactly fills the biggest compiled executable.  The wrapped batched
        engine stays reachable as ``.engine``.  One ``obs`` bundle covers
        both layers: the coalescer inherits the engine's.

        The serving-tier knobs pass straight through: ``cache`` (a
        ``repro.serve.CachePolicy`` or ready ``ResultCache``) replays
        repeated queries from their quantized-code key, ``admission`` (an
        ``AdmissionPolicy`` or ``AdmissionController``) sheds by priority
        class at queue-depth watermarks, and ``clock`` injects a virtual
        clock for deterministic tests (pair with ``start=False``).
        """
        from repro.serve.coalescer import AsyncAnnEngine, CoalescePolicy
        engine = self.serve(params, mesh=mesh, obs=obs, **engine_kw)
        policy = CoalescePolicy(
            max_batch=max_batch if max_batch is not None
            else engine.bucket_sizes[-1],
            max_wait_ms=max_wait_ms,
            default_deadline_ms=default_deadline_ms)
        return AsyncAnnEngine(engine, policy, start=start, cache=cache,
                              admission=admission, clock=clock)
