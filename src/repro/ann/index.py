"""``AnnIndex`` — the one public API for vector search.

The paper separates the index (CSR topology + vectors, §3.2) from the search
algorithm (BFiS / top-M / Speed-ANN, Alg. 1–3); this class is that
separation as an object with a full lifecycle::

    from repro.ann import AnnIndex, IndexSpec, SearchParams

    index = AnnIndex.build(dataset, IndexSpec(metric="cosine", degree=24))
    index.save("/tmp/idx.npz")

    index = AnnIndex.load("/tmp/idx.npz")
    res = index.search(queries, SearchParams(algorithm="speedann", m_max=8))
    engine = index.serve(SearchParams(k=10))        # batched AnnEngine

Every algorithm in {bfis, topm, speedann, sharded} and every registered
distance backend serves every metric in {l2, ip, cosine}: metric handling
(query normalization for cosine, negative-inner-product kernels for ip) and
neighbor-grouping id remapping live HERE, so callers never hand-wire
``PaddedCSR`` + ``SearchConfig`` + ``resolve_dist_fn`` again.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.spec import IndexSpec, SearchParams
from repro.core.bfis import (bfis_search_batch, hnsw_search_batch,
                             search_topm_batch)
from repro.core.build import (HNSWIndex, build_hnsw, build_nsg, exact_knn,
                              normalize_rows)
from repro.core.graph import PaddedCSR, group_by_indegree
from repro.core.speedann import search_speedann_batch

_SAVE_FORMAT = 1


class SearchResult(NamedTuple):
    """One batched search: ids/dists (B, k) + per-query SearchStats."""
    ids: jax.Array
    dists: jax.Array
    stats: object


def default_search_mesh():
    """(data=1, model=n_devices) mesh for the "sharded" algorithm when the
    caller does not provide one.  On a single-device host this degenerates
    to one walker — the same code path, no special-casing."""
    from repro.core.distributed import make_search_mesh
    return make_search_mesh((1, len(jax.devices())), ("data", "model"))


def normalize_queries(q: jax.Array) -> jax.Array:
    """Unit-normalize a (B, d) query batch (cosine = ip on the unit
    sphere).  Shared by ``AnnIndex.searcher`` and the serving engine so the
    two paths cannot drift."""
    return q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)


def remap_result_ids(ids: jax.Array, old_from_new: jax.Array,
                     n_nodes: int) -> jax.Array:
    """Map grouped (relabelled) result ids back to the caller's original id
    space; sentinel/invalid ids (>= n_nodes) pass through unchanged."""
    safe = jnp.minimum(ids, n_nodes - 1)
    return jnp.where(ids < n_nodes, old_from_new[safe], ids)


class AnnIndex:
    """A built similarity-graph index + its :class:`IndexSpec`.

    Construct via :meth:`build` or :meth:`load`, never directly (the
    constructor is public only for internal wiring and tests).
    """

    def __init__(self, spec: IndexSpec, graph: PaddedCSR,
                 hnsw: Optional[HNSWIndex] = None,
                 old_from_new: Optional[np.ndarray] = None):
        self.spec = spec
        self.graph = graph
        self.hnsw = hnsw
        # neighbor grouping relabels vertices; old_from_new maps result ids
        # back to the caller's original ids (None when no relabelling)
        self.old_from_new = (None if old_from_new is None
                             else np.asarray(old_from_new, np.int64))
        # device-resident remap table, uploaded once per index (it enters
        # every searcher's executable as a jit argument, like the graph)
        self._ofn = (jnp.asarray(self.old_from_new, jnp.int32)
                     if self.old_from_new is not None
                     else jnp.zeros((0,), jnp.int32))
        self._searcher_cache: Dict = {}
        self._host_vectors: Optional[np.ndarray] = None  # exact() cache

    # -- introspection -----------------------------------------------------

    @property
    def n_nodes(self) -> int:
        return self.graph.n_nodes

    @property
    def dim(self) -> int:
        return self.graph.dim

    @property
    def metric(self) -> str:
        return self.spec.metric

    def __repr__(self) -> str:
        return (f"AnnIndex(builder={self.spec.builder!r}, "
                f"metric={self.spec.metric!r}, n={self.n_nodes}, "
                f"d={self.dim}, degree={self.graph.degree})")

    # -- build -------------------------------------------------------------

    @classmethod
    def build(cls, data, spec: IndexSpec = IndexSpec()) -> "AnnIndex":
        """Build an index over ``data`` ((N, d) array-like, or anything with
        a ``.base`` attribute such as ``repro.data.VectorDataset``).

        For ``metric="cosine"`` the base vectors are unit-normalized here
        and stored normalized (cosine == inner product on the unit sphere);
        queries are normalized symmetrically at search time.
        """
        # unwrap dataset-like objects (e.g. repro.data.VectorDataset) — but
        # never raw arrays: np.ndarray itself exposes a ``.base`` attribute
        # (its memory owner), which must not be mistaken for a dataset field
        if not isinstance(data, (np.ndarray, jax.Array)) \
                and getattr(data, "base", None) is not None:
            data = data.base
        data = np.asarray(data, np.float32)
        if data.ndim != 2:
            raise ValueError(f"data must be (N, d), got {data.shape}")
        if spec.metric == "cosine":
            data = normalize_rows(data)
        build_metric = "l2" if spec.metric == "cosine" else spec.metric

        if spec.builder == "hnsw":
            hnsw = build_hnsw(data, degree=spec.degree,
                              upper_degree=spec.upper_degree,
                              seed=spec.seed, alpha=spec.alpha,
                              metric=build_metric)
            return cls(spec, hnsw.base, hnsw=hnsw)

        graph = build_nsg(data, degree=spec.degree,
                          knn_k=spec.resolved_knn_k, alpha=spec.alpha,
                          ef_construction=spec.resolved_ef, seed=spec.seed,
                          passes=spec.passes, metric=build_metric)
        old_from_new = None
        if spec.n_top_fraction > 0:
            graph, old_from_new = group_by_indegree(
                np.asarray(graph.nbrs), np.asarray(graph.vectors),
                medoid=int(graph.medoid),
                top_fraction=spec.n_top_fraction)
        return cls(spec, graph, old_from_new=old_from_new)

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> str:
        """npz round-trip of CSR + flat layout + medoid + spec (+ HNSW
        levels + grouping permutation).  Returns the actual path written
        (numpy appends ``.npz`` when missing)."""
        path = str(path)
        if not path.endswith(".npz"):
            path += ".npz"
        arrays = dict(
            format=np.int64(_SAVE_FORMAT),
            spec=np.asarray(json.dumps(dataclasses.asdict(self.spec))),
            nbrs=np.asarray(self.graph.nbrs),
            vectors=np.asarray(self.graph.vectors),
            medoid=np.asarray(self.graph.medoid, np.int32),
            n_top=np.int64(self.graph.n_top),
            flat=np.asarray(self.graph.flat),
        )
        if self.old_from_new is not None:
            arrays["old_from_new"] = self.old_from_new
        if self.hnsw is not None:
            arrays["hnsw_entry"] = np.int64(self.hnsw.entry)
            arrays["hnsw_num_levels"] = np.int64(len(self.hnsw.level_nbrs))
            for i, (ln, nn) in enumerate(zip(self.hnsw.level_nbrs,
                                             self.hnsw.level_nodes)):
                arrays[f"hnsw_level_nbrs_{i}"] = np.asarray(ln)
                arrays[f"hnsw_level_nodes_{i}"] = np.asarray(nn)
        np.savez(path, **arrays)
        return path

    @classmethod
    def load(cls, path: str) -> "AnnIndex":
        path = str(path)
        if not path.endswith(".npz"):
            path += ".npz"
        z = np.load(path, allow_pickle=False)
        fmt = int(z["format"])
        if fmt > _SAVE_FORMAT:
            raise ValueError(f"index file format {fmt} is newer than this "
                             f"code ({_SAVE_FORMAT})")
        spec = IndexSpec(**json.loads(str(z["spec"])))
        graph = PaddedCSR(
            nbrs=jnp.asarray(z["nbrs"]),
            vectors=jnp.asarray(z["vectors"]),
            medoid=jnp.asarray(z["medoid"], jnp.int32),
            n_top=int(z["n_top"]),
            flat=jnp.asarray(z["flat"]),
        )
        old_from_new = (np.asarray(z["old_from_new"])
                        if "old_from_new" in z.files else None)
        hnsw = None
        if "hnsw_entry" in z.files:
            n_levels = int(z["hnsw_num_levels"])
            hnsw = HNSWIndex(
                base=graph,
                level_nbrs=tuple(jnp.asarray(z[f"hnsw_level_nbrs_{i}"])
                                 for i in range(n_levels)),
                level_nodes=tuple(jnp.asarray(z[f"hnsw_level_nodes_{i}"])
                                  for i in range(n_levels)),
                entry=int(z["hnsw_entry"]),
            )
        return cls(spec, graph, hnsw=hnsw, old_from_new=old_from_new)

    # -- search ------------------------------------------------------------

    def searcher(self, params: SearchParams = SearchParams(), *,
                 mesh=None):
        """A jit-ready batched callable ``fn(queries (B, d)) ->
        SearchResult``.

        The compiled executable takes the graph arrays as jit ARGUMENTS (not
        closure constants), so searchers for different params share one
        device-resident embedding table.  Query normalization (cosine) and
        grouping id-remap run inside the jitted function.  Searchers are
        cached per (params, mesh) — repeated ``search`` calls reuse them.
        """
        key = (params, id(mesh) if mesh is not None else None)
        cached = self._searcher_cache.get(key)
        if cached is not None:
            return cached

        cfg = params.to_search_config(self.spec.metric)
        normalize = self.spec.metric == "cosine"
        has_remap = self.old_from_new is not None
        ofn = self._ofn
        n_top, n_nodes = self.graph.n_top, self.graph.n_nodes
        algorithm = params.algorithm
        hnsw = self.hnsw

        if algorithm == "sharded":
            from repro.core.distributed import walker_sharded_search
            the_mesh = mesh if mesh is not None else default_search_mesh()

            def run(g, q):
                return walker_sharded_search(g, q, cfg, the_mesh)
        elif algorithm == "bfis" and hnsw is not None:
            # greedy upper-level descent, then Algorithm 1 at level 0; the
            # (small) upper-level tables ride along as closure constants
            def run(g, q):
                idx = hnsw._replace(base=g)
                return hnsw_search_batch(idx, q, cfg)
        elif algorithm == "bfis":
            def run(g, q):
                return bfis_search_batch(g, q, cfg)
        elif algorithm == "topm":
            def run(g, q):
                return search_topm_batch(g, q, cfg)
        elif algorithm == "speedann":
            def run(g, q):
                return search_speedann_batch(g, q, cfg)
        else:  # pragma: no cover - SearchParams validates
            raise ValueError(algorithm)

        @jax.jit
        def jitted(nbrs, vectors, medoid, flat, ofn_arr, q):
            g = PaddedCSR(nbrs=nbrs, vectors=vectors, medoid=medoid,
                          n_top=n_top, flat=flat)
            q = q.astype(jnp.float32)
            if normalize:
                q = normalize_queries(q)
            ids, dists, stats = run(g, q)
            if has_remap:
                ids = remap_result_ids(ids, ofn_arr, n_nodes)
            return ids, dists, stats

        graph = self.graph

        def fn(queries) -> SearchResult:
            q = jnp.asarray(queries)
            if q.ndim != 2:
                raise ValueError(f"queries must be (B, d), got {q.shape}")
            out = jitted(graph.nbrs, graph.vectors, graph.medoid,
                         graph.flat, ofn, q)
            return SearchResult(*out)

        self._searcher_cache[key] = fn
        return fn

    def search(self, queries, params: SearchParams = SearchParams(), *,
               mesh=None) -> SearchResult:
        """Search a (B, d) query batch; dispatches to ``params.algorithm``
        (including the ``shard_map`` walker path for "sharded")."""
        return self.searcher(params, mesh=mesh)(queries)

    # -- ground truth ------------------------------------------------------

    def exact(self, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Metric-aware exact kNN over the indexed vectors (brute force) —
        the recall reference for this index.  Returns original ids even for
        grouped (relabelled) indices."""
        if self._host_vectors is None:
            # one device->host copy per index, not per call (serving loops
            # compute per-batch ground truth); stored vectors are already
            # normalized for cosine, so "ip" gives identical distances
            # without re-normalizing the table every call
            self._host_vectors = np.asarray(self.graph.vectors, np.float32)
        q = np.asarray(queries, np.float32)
        metric = self.spec.metric
        if metric == "cosine":
            q = q / np.maximum(
                np.linalg.norm(q, axis=1, keepdims=True), 1e-12)
            metric = "ip"
        ids, dists = exact_knn(self._host_vectors, q, k, metric=metric)
        if self.old_from_new is not None:
            ids = self.old_from_new[ids].astype(np.int32)
        return ids, dists

    # -- serving -----------------------------------------------------------

    def serve(self, params: SearchParams = SearchParams(), **engine_kw):
        """A bucketed, jit-cached :class:`repro.serve.AnnEngine` over this
        index (``engine_kw`` forwards e.g. ``bucket_sizes``).

        The engine serves the single-host algorithms (bfis | topm |
        speedann); for the multi-device "sharded" path use
        :meth:`search`/:meth:`searcher` with a mesh directly."""
        from repro.serve.ann_engine import AnnEngine
        return AnnEngine(self, params, **engine_kw)
