"""Public configuration for the :class:`repro.ann.AnnIndex` facade.

The legacy ``SearchConfig`` conflated index-time knobs with per-query knobs;
the facade splits them:

* :class:`IndexSpec` — everything fixed at BUILD time and persisted with the
  index: the graph builder (nsg | hnsw), its degree/pruning parameters, the
  distance metric (l2 | ip | cosine), and the two-level neighbor-grouping
  fraction (§4.4).  Two indices with different specs are different artifacts.
* :class:`SearchParams` — everything a CALLER chooses per query batch: k, the
  queue capacity L, expansion width M, walker count, the search algorithm
  (bfis | topm | speedann | sharded), and the distance-kernel backend.

Both are frozen dataclasses (hashable ⇒ usable as jit static arguments and
as searcher-cache keys).  ``SearchParams.to_search_config`` lowers onto the
legacy :class:`repro.core.config.SearchConfig` (re-exported from
``repro.config`` for backward compatibility), which remains the internal
plumbing type threaded through ``repro.core`` — existing call sites keep
working unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.config import SearchConfig
from repro.quant.scheme import QuantSpec, coerce_quant

BUILDERS = ("nsg", "hnsw")
METRICS = ("l2", "ip", "cosine")
ALGORITHMS = ("bfis", "topm", "speedann", "sharded")
ENTRY_POLICIES = ("medoid", "max_norm")


@dataclass(frozen=True)
class IndexSpec:
    """Index-time configuration, persisted alongside the index arrays."""
    builder: str = "nsg"         # "nsg" | "hnsw"
    metric: str = "l2"           # "l2" | "ip" | "cosine"
    degree: int = 32             # graph out-degree R
    knn_k: int = 0               # kNN-seed width (0 -> degree)
    alpha: float = 1.2           # robust-prune occlusion factor (l2/cosine)
    ef_construction: int = 0     # builder beam width (0 -> 2 * degree)
    passes: int = 2              # NSG refinement passes
    n_top_fraction: float = 0.0  # §4.4 neighbor grouping: fraction of
    #                              hottest (in-degree-ranked) vertices whose
    #                              neighbor embeddings are flattened; > 0
    #                              relabels vertices (results are mapped back
    #                              to original ids transparently)
    upper_degree: int = 16       # HNSW upper-level out-degree
    seed: int = 0
    entry_policy: str = "medoid"  # traversal entry point: "medoid" (NSG's
    #                              navigating node — closest/most-aligned to
    #                              the centroid) | "max_norm" (the max-norm
    #                              vertex; metric="ip" only).  MIPS searches
    #                              over skewed-norm data converge to a
    #                              high-inner-product region dominated by
    #                              large-norm points — seeding there skips
    #                              the climb out of the centroid's
    #                              small-norm neighborhood.  Applies to
    #                              every medoid-seeded search; the one
    #                              exception is algorithm="bfis" on an
    #                              hnsw-built index, which enters via the
    #                              upper-level greedy descent instead (its
    #                              own MIPS-aware entry path).
    quant: QuantSpec = QuantSpec()  # stored-vector quantization
    #                              (repro.quant): "int8" | "bf16" | "none",
    #                              accepted as a dtype string, QuantSpec, or
    #                              the json-round-tripped dict
    build_batch: int = 32        # construction compute tile: how many
    #                              candidate searches ride in one jit-compiled
    #                              search_topm_batch call during batch
    #                              insertion.  A THROUGHPUT knob only — the
    #                              built graph is bit-identical for every
    #                              value (build_batch=1 reproduces the serial
    #                              builder exactly); see core/build.py.
    build_backend: str = "ref"   # distance backend for construction's
    #                              candidate searches (kernel registry name).
    #                              Like build_batch it cannot change the
    #                              result — only how fast it is computed.

    def __post_init__(self):
        object.__setattr__(self, "quant", coerce_quant(self.quant))
        if self.builder not in BUILDERS:
            raise ValueError(
                f"unknown builder {self.builder!r}; one of {BUILDERS}")
        if self.metric not in METRICS:
            raise ValueError(
                f"unknown metric {self.metric!r}; one of {METRICS}")
        if not 0.0 <= self.n_top_fraction <= 1.0:
            raise ValueError("n_top_fraction must be in [0, 1]")
        if self.entry_policy not in ENTRY_POLICIES:
            raise ValueError(
                f"unknown entry_policy {self.entry_policy!r}; one of "
                f"{ENTRY_POLICIES}")
        if self.entry_policy == "max_norm" and self.metric != "ip":
            raise ValueError(
                "entry_policy='max_norm' is the MIPS seed heuristic; it "
                "requires metric='ip' (for l2/cosine the medoid is the "
                "right navigating node)")
        if self.builder == "hnsw" and self.n_top_fraction > 0:
            raise ValueError("neighbor grouping (n_top_fraction) is "
                             "supported for the nsg builder only")
        if self.build_batch < 1:
            raise ValueError("build_batch must be >= 1")

    @property
    def resolved_knn_k(self) -> int:
        return self.knn_k or self.degree

    @property
    def resolved_ef(self) -> int:
        return self.ef_construction or 2 * self.degree

    def with_(self, **kw) -> "IndexSpec":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class SearchParams:
    """Per-query-batch configuration for ``AnnIndex.search``/``.searcher``."""
    k: int = 10                  # neighbors to return
    queue_len: int = 64          # L, bounded frontier capacity (recall knob)
    m_max: int = 8               # max expansion width M
    staged: bool = True          # §4.2 staged search (M doubles)
    stage_every: int = 1         # t: double M every t global steps
    num_walkers: int = 1         # W: private-queue workers
    local_steps: int = 4         # max local steps between sync checks
    sync_ratio: float = 0.8      # Algorithm 2 merge trigger
    max_steps: int = 64          # global step budget
    algorithm: str = "speedann"  # "bfis" | "topm" | "speedann" | "sharded"
    backend: str = "ref"         # distance backend (kernel registry name)
    dma_group: int = 8           # G: rows per DMA tile ("dma" backend)
    visited_mode: str = "bitmap"  # "bitmap" | "loose" | "hash"
    hash_bits: int = 14
    global_rounds: int = 12      # static round budget ("sharded" algorithm)
    rerank_k: int = 0            # two-stage search: traverse with the
    #                              configured backend over a pool widened to
    #                              max(k, rerank_k), then exactly re-rank the
    #                              pool against the f32 vectors and return
    #                              the top k.  0 disables the second stage.
    #                              The recall recovery knob for quantized
    #                              backends (AQR-HNSW shape).  queue_len is
    #                              only raised to FIT the pool; it remains
    #                              the traversal-depth knob — quantized
    #                              stages on hard (clustered, normalized)
    #                              data want it wider than the fp32 run.

    def __post_init__(self):
        if self.algorithm not in ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {self.algorithm!r}; one of {ALGORITHMS}")
        if self.rerank_k < 0:
            raise ValueError("rerank_k must be >= 0")

    def with_(self, **kw) -> "SearchParams":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_search_config(cls, cfg: SearchConfig,
                           algorithm: str = "speedann") -> "SearchParams":
        """Lift a legacy ``SearchConfig``'s per-query fields onto params
        (the metric, an index-time property, is intentionally dropped)."""
        return cls(
            k=cfg.k, queue_len=cfg.queue_len, m_max=cfg.m_max,
            staged=cfg.staged, stage_every=cfg.stage_every,
            num_walkers=cfg.num_walkers, local_steps=cfg.local_steps,
            sync_ratio=cfg.sync_ratio, max_steps=cfg.max_steps,
            algorithm=algorithm, backend=cfg.dist_backend,
            dma_group=cfg.dma_group, visited_mode=cfg.visited_mode,
            hash_bits=cfg.hash_bits, global_rounds=cfg.global_rounds)

    def to_search_config(self, metric: str = "l2") -> SearchConfig:
        """Lower onto the internal plumbing config.  ``metric`` comes from
        the index's :class:`IndexSpec`, never from the caller — the params
        object carries only per-query knobs."""
        cfg = SearchConfig(
            k=self.k,
            metric=metric,
            queue_len=self.queue_len,
            m_max=self.m_max,
            staged=self.staged,
            stage_every=self.stage_every,
            num_walkers=self.num_walkers,
            local_steps=self.local_steps,
            sync_ratio=self.sync_ratio,
            max_steps=self.max_steps,
            visited_mode=self.visited_mode,
            hash_bits=self.hash_bits,
            dist_backend=self.backend,
            dma_group=self.dma_group,
            global_rounds=self.global_rounds,
        )
        if self.algorithm == "bfis":
            # Algorithm 1 exactly: single sequential best-first walker
            cfg = cfg.with_(m_max=1, num_walkers=1, staged=False)
        return cfg
