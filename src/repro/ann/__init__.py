# The public vector-search API: one facade over build -> save/load ->
# search -> serve, metric-general (l2 | ip | cosine) across every search
# algorithm and distance backend.  See repro.ann.index for the lifecycle.
from repro.ann.spec import (ALGORITHMS, BUILDERS, METRICS,  # noqa: F401
                            IndexSpec, SearchParams)
from repro.ann.index import (AnnIndex, SearchResult,  # noqa: F401
                             default_search_mesh)
