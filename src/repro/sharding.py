"""Logical-axis sharding rules (GSPMD / pjit layer).

Models annotate tensors with *logical* axis names; a rule set maps those to
mesh axes per parallelism style:

    batch   -> ("pod", "data")     DP across pods, DP/FSDP within
    embed   -> "data"              FSDP parameter sharding (ZeRO-3 style)
    heads/mlp/vocab -> "model"     tensor parallelism (Megatron style)
    expert  -> "model"             expert parallelism for MoE
    kv_seq  -> "model"             context parallelism for long KV caches

A logical axis is silently dropped (replicated) when the tensor dimension is
not divisible by the mesh axis size — e.g. whisper's 20 heads on a 16-wide
model axis, or grok-1's 8 experts — so every architecture lowers on every
mesh without bespoke configs; the roofline then shows what the fallback
costs.  Rules are plain data; §Perf iterations swap them per-arch.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
Rules = Dict[str, object]

# Parameter / persistent-state rules ("data", "model") or ("pod", "data",
# "model") mesh: FSDP shards the embed dim of WEIGHTS over "data".
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "embed": "data",          # FSDP (weights + optimizer state + caches)
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "kv_seq": "model",        # context-parallel KV cache (decode)
    "seq": None,
    "capacity": None,
    "state": None,
    "conv": None,
    "head_dim": None,
    "frames": None,
    "layers": None,           # scan-stacked leading axis, never sharded
}

# Activation rules: the embed dim of ACTIVATIONS stays replicated (batch owns
# "data"); tensor-parallel dims (heads/mlp/vocab/expert) shard over "model".
ACT_RULES: Rules = dict(DEFAULT_RULES, embed=None)

_active_rules: contextvars.ContextVar[Rules] = contextvars.ContextVar(
    "rules", default=DEFAULT_RULES)
_active_act_rules: contextvars.ContextVar[Rules] = contextvars.ContextVar(
    "act_rules", default=ACT_RULES)
_active_mesh: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "mesh", default=None)


@contextlib.contextmanager
def use_rules(rules: Rules, mesh: Optional[Mesh] = None,
              act_rules: Optional[Rules] = None):
    t1 = _active_rules.set(rules)
    t2 = _active_mesh.set(mesh)
    t3 = _active_act_rules.set(
        act_rules if act_rules is not None else dict(rules, embed=None))
    try:
        yield
    finally:
        _active_rules.reset(t1)
        _active_mesh.reset(t2)
        _active_act_rules.reset(t3)


def current_mesh() -> Optional[Mesh]:
    m = _active_mesh.get()
    if m is not None:
        return m
    # fall back to the ambient jax mesh if one is set
    try:
        env = jax.sharding.get_abstract_mesh()
        if env is not None and env.shape_tuple:
            return None  # abstract mesh: rely on with_sharding_constraint ctx
    except Exception:
        pass
    return None


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= _mesh_axis_size(mesh, a)
        return n
    # works for both concrete Mesh and AbstractMesh
    return dict(mesh.shape).get(axis, 1)


def resolve_spec(
    shape: Sequence[int], logical: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None, rules: Optional[Rules] = None,
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-divisible axes."""
    rules = rules or _active_rules.get()
    mesh = mesh or _active_mesh.get()
    out = []
    used: set = set()   # a mesh axis may shard at most one dim per spec
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name else None
        if axis is None or mesh is None:
            # no rule, or no mesh to validate divisibility against
            out.append(axis)
            continue
        # drop mesh axes that are absent, already used, or don't divide
        if isinstance(axis, (tuple, list)):
            kept = []
            rem = dim
            for a in axis:
                s = _mesh_axis_size(mesh, a)
                if s > 1 and rem % s == 0 and a not in used:
                    kept.append(a)
                    used.add(a)
                    rem //= s
            out.append(tuple(kept) if len(kept) > 1 else
                       (kept[0] if kept else None))
        else:
            s = _mesh_axis_size(mesh, axis)
            ok = s > 1 and dim % s == 0 and axis not in used
            if ok:
                used.add(axis)
            out.append(axis if ok else None)
    return P(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an intermediate with a sharding constraint (no-op w/o mesh).

    Uses the ACTIVATION rule set (embed replicated; batch owns "data")."""
    mesh = _active_mesh.get()
    if mesh is None:
        return x
    spec = resolve_spec(x.shape, logical, mesh, _active_act_rules.get())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs by path convention
# ---------------------------------------------------------------------------

# Regexes over jax.tree_util key paths -> logical axes (excluding any leading
# scan-stacked "layers" dim, which is detected by rank mismatch).
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embedding$", ("vocab", "embed")),
    (r"pos_embedding$", ("seq", "embed")),
    (r"lm_head$", ("embed", "vocab")),
    (r"(wq|wk|wv)$", ("embed", "heads")),       # fused heads*head_dim dim
    (r"(wq_b|wk_b|wv_b)$", ("heads",)),
    (r"wo$", ("heads", "embed")),
    (r"(w_gate|w_up|fc1)$", ("embed", "mlp")),
    (r"(w_down|fc2)$", ("mlp", "embed")),
    (r"(fc1_b)$", ("mlp",)),
    (r"(fc2_b)$", ("embed",)),
    (r"router$", ("embed", "expert")),
    (r"moe_(gate|up)$", ("expert", "embed", "mlp")),
    (r"moe_down$", ("expert", "mlp", "embed")),
    (r"in_proj$", ("embed", "mlp")),            # mamba2 d_inner ~ mlp axis
    (r"out_proj$", ("mlp", "embed")),
    (r"conv_w$", ("conv", "mlp")),
    (r"(conv_b|dt_bias|A_log|D|ssm_norm)$", ("mlp",)),
    # serving-state leaves (KV caches, SSM states)
    (r"caches/k$|caches/v$", ("layers", "batch", "kv_seq", "kv_heads", None)),
    (r"(cross_k|cross_v)$", ("layers", "batch", "frames", "kv_heads", None)),
    (r"conv$", ("layers", "batch", None, "mlp")),
    (r"/ssm$", ("layers", "batch", "heads", None, None)),
    (r"(^|/)pos$", ("batch",)),
    (r"(scale|bias|norm.*)$", ("embed",)),
)


def spec_for_path(path: str, shape: Tuple[int, ...],
                  mesh: Optional[Mesh] = None,
                  rules: Optional[Rules] = None,
                  scanned: bool = False) -> P:
    """PartitionSpec for a parameter leaf, by naming convention.

    Rank adaptation: a rule one short of the leaf rank gains a leading
    ``layers`` axis (scan-stacked params/caches); any remaining rank gap is
    leading-padded with None (e.g. zamba2's (groups, per_group, ...) stacks)
    so the trailing — semantically meaningful — dims stay aligned.
    """
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            logical = tuple(logical)
            if scanned or len(logical) == len(shape) - 1:
                logical = ("layers",) + logical
            if len(logical) < len(shape):
                logical = (None,) * (len(shape) - len(logical)) + logical
            elif len(logical) > len(shape):
                logical = logical[len(logical) - len(shape):]
            return resolve_spec(shape, logical, mesh, rules)
    return resolve_spec(shape, (None,) * len(shape), mesh, rules)


def keystr_simple(path) -> str:
    """"simple" /-separated tree-path key, stable across jax versions
    (``jax.tree_util.keystr`` only grew simple=/separator= kwargs in newer
    releases)."""
    parts = []
    for p in path:
        if hasattr(p, "key"):          # DictKey / FlattenedIndexKey
            parts.append(str(p.key))
        elif hasattr(p, "name"):       # GetAttrKey
            parts.append(str(p.name))
        elif hasattr(p, "idx"):        # SequenceKey
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, mesh: Optional[Mesh] = None,
                rules: Optional[Rules] = None):
    """PartitionSpec pytree for a parameter pytree, by path convention."""
    def one(path, leaf):
        return spec_for_path(keystr_simple(path), leaf.shape, mesh, rules)
    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, mesh: Mesh, rules: Optional[Rules] = None):
    specs = param_specs(params, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
