import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds ``ShapeDtypeStruct`` stand-ins for every input (``input_specs``)
     — weak-type-correct, shardable, NO device allocation;
  2. ``jax.jit(step, in_shardings=…, out_shardings=…).lower(...).compile()``
     on the production mesh (16×16 single-pod and 2×16×16 multi-pod);
  3. records ``memory_analysis()`` (proves it fits), ``cost_analysis()``
     (FLOPs/bytes for §Roofline), and the collective bytes parsed from the
     optimized HLO, into ``dryrun_results.json`` (incremental — resumable).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k \
        --mesh single,multi
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import (FAMILY_ENCDEC, FAMILY_VLM, ModelConfig,  # noqa: E402
                          ShapeConfig, SHAPES_BY_NAME, TrainConfig)
from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.configs.shapes import cell_matrix  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.sharding import (ACT_RULES, DEFAULT_RULES, param_specs,  # noqa: E402
                            resolve_spec, use_rules)
from repro.train.train_step import init_train_state, make_train_step  # noqa: E402

RESULTS_PATH = os.environ.get("DRYRUN_RESULTS",
                              os.path.join(os.path.dirname(__file__),
                                           "../../../dryrun_results.json"))

I32 = jnp.int32
BF16 = jnp.bfloat16
F32 = jnp.float32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_config_for(cfg: ModelConfig) -> TrainConfig:
    """>=100B params: bf16 moments so optimizer state fits a 256-chip pod.

    microbatches=8: 1M tokens/step at seq 4096 does not fit activations in
    16GB/chip without microbatching (baseline job config, not a perf trick).
    """
    big = cfg.param_count() >= 1e11
    return TrainConfig(
        moment_dtype="bfloat16" if big else "float32",
        remat="full", microbatches=8)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": sds((b, s), I32),
            "targets": sds((b, s), I32),
            "mask": sds((b, s), F32),
        }
        if cfg.family == FAMILY_ENCDEC:
            # stub frontend: precomputed frame embeddings
            batch["frames"] = sds((b, cfg.encoder_ctx, cfg.d_model), BF16)
        if cfg.family == FAMILY_VLM:
            batch["positions"] = sds((3, b, s), I32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s), I32)}
        if cfg.family == FAMILY_ENCDEC:
            batch["frames"] = sds((b, cfg.encoder_ctx, cfg.d_model), BF16)
        if cfg.family == FAMILY_VLM:
            batch["positions"] = sds((3, b, s), I32)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"token": sds((b, 1), I32)}


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """NamedShardings for the input batch: every spec carries the leading
    ("batch", ...) axis (token/target/mask grids, (B, 1) decode tokens)."""
    logical = {
        "tokens": ("batch", "seq"), "targets": ("batch", "seq"),
        "mask": ("batch", "seq"),
        "frames": ("batch", "frames", "embed"),
        "positions": (None, "batch", "seq"),
        "token": ("batch", None),
    }
    batch = input_specs(cfg, shape)
    return {
        k: NamedSharding(mesh, resolve_spec(v.shape, logical[k], mesh,
                                            ACT_RULES))
        for k, v in batch.items()
    }


def _shardings_for(tree_sds, mesh):
    specs = param_specs(tree_sds, mesh)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)


def _reduced_depths(cfg: ModelConfig):
    """Two reduced depths for per-layer cost extrapolation (unrolled).

    XLA's cost_analysis counts a while-loop body once, so FLOPs/bytes/
    collective bytes of scan-over-layers lowerings understate full depth.
    We lower two small UNROLLED variants and extrapolate linearly:
        total(D) = f(d2) + (D - d2) * (f(d4) - f(d2)) / (d4 - d2).
    Hybrid (zamba2) uses whole groups (7 = 6 mamba + 1 attn) as the unit;
    the 4 trailing mamba layers are counted at the blended per-layer rate
    (~2% overestimate of their attention share — documented).
    """
    if cfg.family == "hybrid":
        per = cfg.hybrid_attn_every + 1
        return per, 2 * per
    return 2, 4


def _with_depth(cfg: ModelConfig, depth: int) -> ModelConfig:
    kw = {"num_layers": depth, "scan_layers": False}
    if cfg.family == "encdec":
        kw["encoder_layers"] = depth
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules=None, extra_tcfg: Optional[dict] = None,
               cfg_override: Optional[ModelConfig] = None):
    """Lower + compile one cell; returns (lowered, compiled, meta)."""
    cfg = cfg_override or get_config(arch)
    pd = os.environ.get("DRYRUN_PARAM_DTYPE")
    if pd:   # §Perf knob: parameter storage dtype (FSDP gather bytes)
        cfg = dataclasses.replace(cfg, param_dtype=pd)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    tcfg = train_config_for(cfg)
    if extra_tcfg:
        tcfg = dataclasses.replace(tcfg, **extra_tcfg)
    key = jax.random.PRNGKey(0)

    with use_rules(rules or DEFAULT_RULES, mesh):
        batch = input_specs(cfg, shape)
        bspecs = batch_specs(cfg, shape, mesh)

        if shape.kind == "train":
            state_sds = jax.eval_shape(
                lambda k: init_train_state(model, k, tcfg), key)
            state_sh = _shardings_for(state_sds, mesh)
            step = make_train_step(model, tcfg)
            jf = jax.jit(step, in_shardings=(state_sh, bspecs),
                         out_shardings=(state_sh, None))
            lowered = jf.lower(state_sds, batch)

        elif shape.kind == "prefill":
            params_sds = jax.eval_shape(model.init, key)
            params_sh = _shardings_for(params_sds, mesh)

            if cfg.family == FAMILY_ENCDEC:
                def step(params, b):
                    return model.prefill(params, b["frames"], b["tokens"],
                                         s_max=shape.seq_len)
            elif cfg.family == FAMILY_VLM:
                def step(params, b):
                    return model.prefill(params, b["tokens"],
                                         s_max=shape.seq_len,
                                         positions=b["positions"])
            else:
                def step(params, b):
                    return model.prefill(params, b["tokens"],
                                         s_max=shape.seq_len)

            jf = jax.jit(step, in_shardings=(params_sh, bspecs))
            lowered = jf.lower(params_sds, batch)

        else:  # decode
            params_sds = jax.eval_shape(model.init, key)
            params_sh = _shardings_for(params_sds, mesh)
            dstate_sds = jax.eval_shape(
                functools.partial(model.init_decode_state,
                                  shape.global_batch, shape.seq_len))
            dstate_sh = _shardings_for(dstate_sds, mesh)

            def step(params, dstate, b):
                return model.decode_step(params, dstate, b["token"])

            jf = jax.jit(step,
                         in_shardings=(params_sh, dstate_sh, bspecs),
                         out_shardings=(None, dstate_sh))
            lowered = jf.lower(params_sds, dstate_sds, batch)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": 512 if multi_pod else 256,
            "compile_s": round(compile_s, 1)}
    return lowered, compiled, meta, cfg, shape


def _costs_of(compiled) -> Dict:
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": rl.collective_bytes(hlo),
    }


def _extrapolate(arch, shape_name, multi_pod, cfg, full_depth,
                 shape_kind: str) -> Dict:
    """Full-depth FLOPs/bytes/collectives from two reduced unrolled
    lowerings (see _reduced_depths).

    Train cost lowerings use microbatches=1: total per-step FLOPs/bytes are
    identical to the microbatched schedule (same tokens), and the once-per-
    step gradient all-reduce is counted exactly once.  (The microbatched
    schedule re-gathers FSDP weight shards per microbatch, which this
    undercounts — noted in EXPERIMENTS.md; the full-depth compile that
    proves memory fit still uses the real microbatched config.)
    """
    d2, d4 = _reduced_depths(cfg)
    tc = {"microbatches": 1} if shape_kind == "train" else None
    c2 = _costs_of(lower_cell(arch, shape_name, multi_pod, extra_tcfg=tc,
                              cfg_override=_with_depth(cfg, d2))[1])
    c4 = _costs_of(lower_cell(arch, shape_name, multi_pod, extra_tcfg=tc,
                              cfg_override=_with_depth(cfg, d4))[1])
    mult = 1

    def lin(f2, f4):
        per = (f4 - f2) / (d4 - d2)
        return (f2 + (full_depth - d2) * per) * mult, per * mult

    flops, flops_per = lin(c2["flops"], c4["flops"])
    nbytes, _ = lin(c2["bytes"], c4["bytes"])
    coll = {}
    for k in c2["coll"]:
        coll[k] = int(max(lin(c2["coll"][k], c4["coll"][k])[0], 0))
    return {"flops": flops, "bytes": nbytes, "coll": coll,
            "flops_per_layer": flops_per, "depths_used": [d2, d4],
            "microbatch_mult": mult}


def analyze(lowered, compiled, meta, cfg, shape,
            extrapolated: Optional[Dict] = None) -> Dict:
    chips = meta["chips"]
    scan_costs = _costs_of(compiled)
    if extrapolated is not None:
        flops = extrapolated["flops"]
        bytes_accessed = extrapolated["bytes"]
        coll = extrapolated["coll"]
    else:
        flops = scan_costs["flops"]
        bytes_accessed = scan_costs["bytes"]
        coll = scan_costs["coll"]
    # cost_analysis is PER-DEVICE (the compiled module is the SPMD
    # partition): scale to module-global so the §Roofline formulas
    # (x / (chips × rate)) apply as written.
    flops *= chips
    bytes_accessed *= chips
    coll = {k: v * chips for k, v in coll.items()}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_info = {}
    terms = rl.roofline_terms(flops, bytes_accessed, coll, chips)
    mf = rl.model_flops(cfg, shape)
    out = dict(meta)
    out.update({
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "collectives": coll,
        "scan_hlo_flops": scan_costs["flops"],   # body-counted-once raw
        "memory": mem_info,
        "model_flops": mf,
        "useful_flops_ratio": (mf / flops) if flops else None,
        "extrapolation": (extrapolated or {}).get("depths_used"),
        **terms,
    })
    return out


def load_results() -> Dict:
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            return json.load(f)
    return {}


def save_results(res: Dict):
    with open(RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)


def run_cell(arch: str, shape_name: str, mesh_kind: str, res: Dict,
             force: bool = False, tag: str = "") -> bool:
    key = f"{arch}|{shape_name}|{mesh_kind}" + (f"#{tag}" if tag else "")
    if key in res and not force and res[key].get("status") == "ok":
        print(f"[skip cached] {key}")
        return True
    t0 = time.time()
    try:
        multi = mesh_kind == "multi"
        lowered, compiled, meta, cfg, shape = lower_cell(
            arch, shape_name, multi)
        # depth-extrapolated roofline costs: single-pod only (the §Roofline
        # table is single-pod; multi-pod proves compile + the pod axis)
        extra = None
        if not multi:
            extra = _extrapolate(arch, shape_name, multi, cfg,
                                 cfg.num_layers, shape.kind)
        out = analyze(lowered, compiled, meta, cfg, shape, extra)
        out["status"] = "ok"
        res[key] = out
        print(f"[ok] {key}  compile={out['compile_s']}s "
              f"flops={out['hlo_flops']:.3e} dominant={out['dominant']}"
              f"  ({time.time() - t0:.0f}s total)")
        ok = True
    except Exception as e:  # noqa: BLE001 — record the failure
        res[key] = {"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]}
        print(f"[FAIL] {key}: {type(e).__name__}: {e}")
        ok = False
    save_results(res)
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--moe-impl", default="gspmd", choices=("gspmd", "a2a"))
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    if args.moe_impl != "gspmd":
        from repro.models import moe_a2a
        moe_a2a.set_moe_impl(args.moe_impl)

    res = load_results()
    meshes = args.mesh.split(",")
    cells = cell_matrix()
    n_ok = n_fail = 0
    for cell in cells:
        if args.arch and cell.arch != args.arch:
            continue
        if args.shape and cell.shape.name != args.shape:
            continue
        if cell.skip is not None:
            key_base = f"{cell.arch}|{cell.shape.name}"
            for mk in meshes:
                res[f"{key_base}|{mk}"] = {"status": "skip",
                                           "reason": cell.skip}
            save_results(res)
            print(f"[documented skip] {key_base}: {cell.skip.split(';')[0]}")
            continue
        for mk in meshes:
            if run_cell(cell.arch, cell.shape.name, mk, res,
                        force=args.force, tag=args.tag):
                n_ok += 1
            else:
                n_fail += 1
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed "
          f"(results -> {os.path.abspath(RESULTS_PATH)})")


if __name__ == "__main__":
    main()
