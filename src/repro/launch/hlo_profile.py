"""HLO-level profiling for the perf loop (no real hardware).

Parses the optimized per-device HLO of a compiled cell and ranks ops by
modeled cost: dots by FLOPs (2·Πdims·contraction), everything else by
result bytes.  This is the dry-run substitute for a profiler trace — it
answers "which op dominates the roofline term" so hypotheses target the
right op (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_SHAPE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
          "u64": 8}


def _dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def dot_flops(line: str) -> int:
    """FLOPs of a dot from 'result = TYPE dot(a, b), ... contracting_dims'."""
    m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) dot\((.+?)\)", line)
    if not m:
        return 0
    res = _dims(m.group(1))
    if not res:
        return 0
    res_n = _numel(res[0][1])
    # contraction size: parse lhs shape and contracting dims
    ops = m.group(2)
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    shapes = _dims(ops)
    if not mdims or not shapes:
        return 2 * res_n  # fallback
    lhs = shapes[0][1]
    contract = 1
    for d in mdims.group(1).split(","):
        if d:
            contract *= lhs[int(d)]
    return 2 * res_n * contract


def profile(hlo_text: str, top: int = 15) -> Dict:
    """Rank dots by FLOPs and all ops by result bytes."""
    dots: List[Tuple[int, str]] = []
    bytes_by_op: Dict[str, int] = defaultdict(int)
    flops_total = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) ([a-z\-]+)[\(.]", line)
        if not m:
            continue
        op = m.group(2)
        res = _dims(m.group(1))
        rb = sum(_numel(d) * _BYTES.get(dt, 4) for dt, d in res)
        bytes_by_op[op] += rb
        if op == "dot":
            f = dot_flops(line)
            flops_total += f
            dots.append((f, line[:160]))
    dots.sort(reverse=True)
    return {
        "dot_flops_total": flops_total,
        "top_dots": dots[:top],
        "bytes_by_op": dict(sorted(bytes_by_op.items(),
                                   key=lambda kv: -kv[1])[:top]),
    }


def print_profile(hlo_text: str, top: int = 12):
    p = profile(hlo_text, top)
    print(f"total dot flops (per device, loop bodies once): "
          f"{p['dot_flops_total']:.3e}")
    print("-- top dots --")
    for f, line in p["top_dots"]:
        print(f"  {f:.3e}  {line}")
    print("-- result bytes by op --")
    for op, b in p["bytes_by_op"].items():
        print(f"  {b / 1e9:8.2f} GB  {op}")
    return p
