"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / (chips × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective = collective_bytes / (chips × 50e9 B/s/link ICI)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the optimized HLO and sum the
result-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (documented convention: result bytes ≈
wire bytes for AG/RS/CP; all-reduce counted 2× for the ring RS+AG).
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (~per-chip effective)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.  f32[16,1024]{1,0}  or  bf16[8,128,2048]
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string
    (handles tuples like (f32[8,2], f32[8,2]))."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type result bytes summed over the module."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-done"):
            continue                       # async pair: count -start only
        base = op[:-len("-start")] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        out[base] += _shape_bytes(m.group(1))
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll: Dict[str, int], chips: int) -> Dict[str, float]:
    wire = (2 * coll.get("all-reduce", 0)
            + coll.get("all-gather", 0)
            + coll.get("reduce-scatter", 0)
            + coll.get("all-to-all", 0)
            + coll.get("collective-permute", 0))
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = bytes_accessed / (chips * HBM_BW)
    t_coll = wire / (chips * ICI_BW)
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "collective_wire_bytes": wire,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode D=new
    tokens only."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one new token per sequence
    return 2.0 * n * shape.global_batch
