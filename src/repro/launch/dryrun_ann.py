import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Dry-run of the PAPER'S OWN system at production scale: the Speed-ANN
search service lowered + compiled on the 16×16 and 2×16×16 meshes with
ShapeDtypeStruct graphs (no allocation).

Two configurations, mirroring §5.5 (billion-scale practicality):

* corpus-sharded: DEEP-like d=96 corpus, 48M nodes × R=24 per model-axis
  shard → 768M nodes single-pod / 1.5B nodes multi-pod; per-device graph
  bytes = 48M×(96×2B + 24×4B) ≈ 13.8 GB — fits 16 GB HBM, proving the
  billion-point regime of Figure 20 is servable from a pod of v5e.
* walker-sharded (the paper's intra-query parallelism): DEEP10M-scale
  graph replicated per device; 16 walkers along the model axis; hash
  visited sets (memory independent of N); queries sharded over data.

Outputs to ``ann_dryrun_results.json``:
    PYTHONPATH=src python -m repro.launch.dryrun_ann
"""
import functools     # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.ann import SearchParams  # noqa: E402
from repro.core.distributed import (ShardedIndex, corpus_sharded_search,  # noqa: E402
                                    walker_sharded_search)
from repro.core.graph import PaddedCSR  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__),
                       "../../../ann_dryrun_results.json")

D = 96          # DEEP dimensionality
R = 24          # graph out-degree
N_SHARD = 48_000_000
N_WALKER_GRAPH = 10_000_000
QUERIES = 1024
# per-query knobs via the facade's params type; the distributed cells lower
# the resolved internal config (the l2 DEEP-analog metric)
PARAMS = SearchParams(k=10, queue_len=128, m_max=16, num_walkers=16,
                      max_steps=64, local_steps=8, sync_ratio=0.8,
                      visited_mode="hash", hash_bits=16, global_rounds=12)
CFG = PARAMS.to_search_config("l2")


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def corpus_cell(multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shards = 16
    index = ShardedIndex(
        nbrs=sds((shards, N_SHARD, R), jnp.int32),
        vectors=sds((shards, N_SHARD, D), jnp.bfloat16),
        medoids=sds((shards,), jnp.int32),
        offsets=sds((shards,), jnp.int32),
    )
    queries = sds((QUERIES, D), jnp.float32)
    cfg = CFG.with_(m_max=1, num_walkers=1, staged=False)

    def step(nbrs, vectors, medoids, offsets, q):
        idx = ShardedIndex(nbrs, vectors, medoids, offsets)
        return corpus_sharded_search(idx, q, cfg, mesh)

    shard_spec = NamedSharding(mesh, P("model"))
    qspec = NamedSharding(mesh, P("data"))
    jf = jax.jit(step, in_shardings=(shard_spec, shard_spec, shard_spec,
                                     shard_spec, qspec))
    return jf.lower(index.nbrs, index.vectors, index.medoids, index.offsets,
                    queries), mesh


def walker_cell(multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    rep = NamedSharding(mesh, P())
    graph = PaddedCSR(
        nbrs=sds((N_WALKER_GRAPH, R), jnp.int32),
        vectors=sds((N_WALKER_GRAPH, D), jnp.bfloat16),
        medoid=sds((), jnp.int32),
        n_top=0,
        flat=sds((0, R, D), jnp.bfloat16),
    )
    queries = sds((QUERIES, D), jnp.float32)

    def step(nbrs, vectors, medoid, flat, q):
        g = PaddedCSR(nbrs=nbrs, vectors=vectors, medoid=medoid, n_top=0,
                      flat=flat)
        return walker_sharded_search(g, q, CFG, mesh)

    jf = jax.jit(step, in_shardings=(rep, rep, rep, rep,
                                     NamedSharding(mesh, P("data"))))
    return jf.lower(graph.nbrs, graph.vectors, graph.medoid, graph.flat,
                    queries), mesh


def run(name, fn, multi_pod):
    chips = 512 if multi_pod else 256
    t0 = time.time()
    lowered, mesh = fn(multi_pod)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = rl.collective_bytes(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        mem_info = {"argument_bytes": getattr(mem, "argument_size_in_bytes",
                                              None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None)}
    except Exception:
        mem_info = {}
    terms = rl.roofline_terms(float(cost.get("flops", 0)) * chips,
                              float(cost.get("bytes accessed", 0)) * chips,
                              {k: v * chips for k, v in coll.items()}, chips)
    out = {"status": "ok", "chips": chips,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "compile_s": round(time.time() - t0, 1),
           "hlo_flops": float(cost.get("flops", 0)) * chips,
           "hlo_bytes": float(cost.get("bytes accessed", 0)) * chips,
           "collectives": coll, "memory": mem_info, **terms}
    print(f"[ok] {name}  compile={out['compile_s']}s "
          f"dominant={out['dominant']} arg_bytes/dev="
          f"{(mem_info.get('argument_bytes') or 0) / 1e9:.1f}GB")
    return out


def main():
    res = {}
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            res = json.load(f)
    jobs = [
        ("speedann-corpus-768M|serve|single", corpus_cell, False),
        ("speedann-corpus-1.5B|serve|multi", corpus_cell, True),
        ("speedann-walker-10M|serve|single", walker_cell, False),
        ("speedann-walker-10M|serve|multi", walker_cell, True),
    ]
    for name, fn, multi in jobs:
        if res.get(name, {}).get("status") == "ok":
            print(f"[cached] {name}")
            continue
        try:
            res[name] = run(name, fn, multi)
        except Exception as e:  # noqa: BLE001
            res[name] = {"status": "fail",
                         "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {name}: {type(e).__name__}: {e}")
        with open(RESULTS, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
    print("ann dry-run complete ->", os.path.abspath(RESULTS))


if __name__ == "__main__":
    main()
