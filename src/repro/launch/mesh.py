"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512
host devices via XLA_FLAGS before any jax import, while tests/benches must
keep the default single device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
