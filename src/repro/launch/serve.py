"""Serving launcher: ANN search service or LM decode service.

    PYTHONPATH=src python -m repro.launch.serve --mode ann [--n 8000]
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch yi-9b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_ann(args):
    from repro.config import SearchConfig
    from repro.core import build_nsg, recall_at_k, search_speedann_batch
    from repro.data import make_vector_dataset

    ds = make_vector_dataset("sift", n=args.n, n_queries=args.batch, k=10,
                             dim=32)
    graph = build_nsg(ds.base, degree=32, knn_k=32, ef_construction=96)
    cfg = SearchConfig(k=10, queue_len=96, m_max=8, num_walkers=8,
                       max_steps=384, local_steps=8)
    search = jax.jit(lambda q: search_speedann_batch(graph, q, cfg))
    jax.block_until_ready(search(jnp.asarray(ds.queries))[0])
    t0 = time.perf_counter()
    ids, _, _ = search(jnp.asarray(ds.queries))
    jax.block_until_ready(ids)
    dt = time.perf_counter() - t0
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    print(f"ann-serve: {args.batch} queries in {dt * 1e3:.1f}ms "
          f"({dt / args.batch * 1e3:.2f}ms/q) recall@10={r:.3f}")


def serve_lm(args):
    from repro.configs import get_config, get_smoke_config
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, s_max=64)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 8), 0,
                                cfg.vocab_size)
    toks, _ = eng.generate(prompt, steps=16, temperature=0.8)
    jax.block_until_ready(toks)
    t0 = time.perf_counter()
    toks, _ = eng.generate(prompt, steps=16, temperature=0.8)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"lm-serve: arch={cfg.name} {args.batch}x16 tokens in "
          f"{dt * 1e3:.1f}ms; sample row: {np.asarray(toks)[0][:8]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("ann", "lm"), default="ann")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()
    (serve_ann if args.mode == "ann" else serve_lm)(args)


if __name__ == "__main__":
    main()
