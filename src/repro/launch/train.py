"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        [--smoke] [--steps 100] [--data N] [--model M] [--compress]

On this CPU container use ``--smoke`` (reduced config, 1 device).  On a real
cluster the same entry point runs the full config on the production mesh
(jax.distributed.initialize is called when JAX_COORDINATOR is set).
"""
from __future__ import annotations

import argparse
import os

import jax

from repro.config import TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.sharding import DEFAULT_RULES, use_rules
from repro.train import Trainer
from repro.train.train_step import make_compressed_dp_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient all-reduce (explicit-DP step)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
        learning_rate=3e-3, checkpoint_every=max(args.steps // 5, 1),
        checkpoint_dir=args.ckpt_dir or f"/tmp/repro_train_{args.arch}",
        grad_compression="int8" if args.compress else "none")
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch=args.batch, seed=0, shard=0, num_shards=1)

    mesh = make_host_mesh(args.data, args.model)
    with use_rules(DEFAULT_RULES, mesh):
        step = None
        if args.compress:
            step = make_compressed_dp_train_step(model, tcfg, mesh)
        trainer = Trainer(model, tcfg, stream, train_step=step)
        trainer.run(steps=args.steps)
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"done: arch={cfg.name} loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
