# Quantized vector storage: QuantSpec schemes (int8 | bf16), the jit-friendly
# codec, and quantized distance backends.  The backends in
# repro.quant.kernels self-register with repro.kernels.registry (imported
# from the registry module, NOT here, to keep the import graph acyclic) and
# are selected purely via SearchParams.backend on an index built with
# IndexSpec(quant=...).
from repro.quant.codec import (cache_codes, code_key,  # noqa: F401
                               dequantize, fit_scales, max_error_bound,
                               no_scales, quantize, quantize_query,
                               query_cache_key)
from repro.quant.scheme import (QUANT_DTYPES, QuantSpec,  # noqa: F401
                                coerce_quant, required_quant_dtype)
