"""Quantization schemes for stored index vectors.

Speed-ANN's neighbor expansion is memory-bound (Challenges II & IV): the hot
loop gathers ≤ M·R candidate vectors per step, so the bytes-per-candidate of
the STORED representation directly bounds expansion throughput.  A
:class:`QuantSpec` describes how the embedding table is stored:

* ``dtype="none"`` — float32, the seed behaviour;
* ``dtype="bf16"`` — bfloat16 storage (2x smaller gathers, no scales);
* ``dtype="int8"`` — symmetric int8 codes + float32 scales (4x smaller
  gathers; distances accumulate in int32 and rescale — see
  ``repro.quant.kernels``).

Scales are *trained from data* (max-abs calibration over the table, see
``repro.quant.codec.fit_scales``) with two granularities:

* per-vector (``per_dim=False``, scales ``(N, 1)``) — each row has its own
  scale, so the int8 dot against an int8 query rescales with ONE f32 multiply
  per candidate (the int32-accumulation fast path);
* per-dimension (``per_dim=True``, scales ``(1, d)``) — columns share a scale
  (better for anisotropic embeddings); distances dequantize the gathered rows
  and reduce in f32 (the memory win is kept, the integer-dot win is not).

Quantized traversal is approximate; the AQR-HNSW-style two-stage search
(``SearchParams.rerank_k``) recovers full-precision recall by exactly
re-ranking a widened candidate pool against the float32 vectors —
``keep_float`` controls whether that copy is persisted with the index.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

QUANT_DTYPES = ("none", "int8", "bf16")


@dataclass(frozen=True)
class QuantSpec:
    """How the index's embedding table is quantized (an index-time property,
    persisted with the index inside ``IndexSpec``)."""
    dtype: str = "none"       # "none" | "int8" | "bf16"
    per_dim: bool = False     # int8 scale granularity: per-vector rows
    #                           (False) or per-dimension columns (True)
    keep_float: bool = True   # persist the float32 vectors alongside the
    #                           codes so search can re-rank exactly; False
    #                           stores codes+scales only (smallest artifact;
    #                           the f32 table is rebuilt by dequantization)

    def __post_init__(self):
        if self.dtype not in QUANT_DTYPES:
            raise ValueError(
                f"unknown quant dtype {self.dtype!r}; one of {QUANT_DTYPES}")

    @property
    def enabled(self) -> bool:
        return self.dtype != "none"

    def with_(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)


def coerce_quant(value) -> QuantSpec:
    """Normalize the user-facing forms of a quant spec.

    ``IndexSpec(quant="int8")`` and the json round-trip (a plain dict) both
    lower onto a :class:`QuantSpec`; ``None`` means disabled."""
    if value is None:
        return QuantSpec()
    if isinstance(value, QuantSpec):
        return value
    if isinstance(value, str):
        return QuantSpec(dtype=value)
    if isinstance(value, dict):
        return QuantSpec(**value)
    raise TypeError(f"quant must be a QuantSpec, dtype string, or dict; "
                    f"got {type(value).__name__}")


def required_quant_dtype(backend: str) -> str:
    """The quant dtype a distance backend needs ("none" for f32 backends).

    Quantized backends follow the ``<base>_<dtype>`` naming convention
    (``ref_int8``, ``rowgather_int8``, ``ref_bf16``); the facade uses this to
    validate ``SearchParams.backend`` against ``IndexSpec.quant`` before
    tracing."""
    for dtype in ("int8", "bf16"):
        if backend.endswith("_" + dtype):
            return dtype
    return "none"
