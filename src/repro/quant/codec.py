"""jit-friendly quantize / dequantize for stored vectors.

The codec is pure shape-static ``jnp`` so it can run inside jitted build /
search code or on host arrays interchangeably.  Conventions:

* int8 is SYMMETRIC around zero with 127 levels per side: ``code =
  round(x / s)`` with ``s = max|x| / 127`` over the scale group, so no value
  clips and the reconstruction error is bounded by ``s / 2`` elementwise
  (the bound the hypothesis property test asserts);
* scales are float32 with broadcast-ready shapes — ``(N, 1)`` per-vector,
  ``(1, d)`` per-dimension — and a zero-size ``(0, 0)`` placeholder when the
  scheme has no scales (bf16 / none), so pytrees stay uniform;
* bf16 is scale-free storage rounding (``x.astype(bfloat16)``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.scheme import QuantSpec

INT8_LEVELS = 127.0          # symmetric: codes in [-127, 127]
_EPS = 1e-12                 # all-zero scale groups quantize to code 0


def no_scales() -> jax.Array:
    """The zero-size scales placeholder for scale-free schemes."""
    return jnp.zeros((0, 0), jnp.float32)


def fit_scales(x, spec: QuantSpec) -> jax.Array:
    """Train scales from data (max-abs calibration over the table).

    x: (N, d) float vectors; returns (N, 1) for per-vector int8, (1, d) for
    per-dimension int8, and the zero-size placeholder otherwise.
    """
    x = jnp.asarray(x, jnp.float32)
    if spec.dtype != "int8":
        return no_scales()
    axis = 0 if spec.per_dim else 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, _EPS) / INT8_LEVELS


def quantize(x, spec: QuantSpec, scales=None) -> jax.Array:
    """Encode (N, d) float vectors into the scheme's storage dtype.

    For int8 the ``scales`` must come from :func:`fit_scales` on the SAME
    scale groups (rows may be a gather of the calibration table only for
    per-dimension scales).
    """
    x = jnp.asarray(x, jnp.float32)
    if spec.dtype == "int8":
        if scales is None:
            raise ValueError("int8 quantize requires scales (fit_scales)")
        codes = jnp.round(x / jnp.asarray(scales, jnp.float32))
        return jnp.clip(codes, -INT8_LEVELS, INT8_LEVELS).astype(jnp.int8)
    if spec.dtype == "bf16":
        return x.astype(jnp.bfloat16)
    return x


def dequantize(codes, spec: QuantSpec, scales=None) -> jax.Array:
    """Decode stored codes back to float32 (the rerank-free f32 view)."""
    if spec.dtype == "int8":
        if scales is None:
            raise ValueError("int8 dequantize requires scales")
        return (jnp.asarray(codes, jnp.float32)
                * jnp.asarray(scales, jnp.float32))
    return jnp.asarray(codes).astype(jnp.float32)


def query_levels(d: int) -> float:
    """Integer levels for query codes in the int8 integer-dot fast path.

    The query is transient (never stored or gathered), so it does NOT pay
    the table's 8-bit budget: it quantizes onto the widest symmetric grid —
    up to 15 bits — such that a length-``d`` dot of int8 table codes against
    the query codes cannot overflow the int32 accumulator
    (``127 · levels · d < 2^31``).  This keeps the asymmetric distance error
    dominated by the STORED codes, matching the recall of an exact-query
    reduction while every operand stays integer.
    """
    return float(min(32767, (2 ** 31 - 1) // (128 * max(d, 1))))


def quantize_query(q: jax.Array, levels: float | None = None) -> tuple:
    """Symmetrically quantize a query for the integer-dot fast path.

    q: (..., d) float; returns (codes int32 (..., d), scale f32 (..., 1)).
    Each query row gets its own max-abs scale — queries are never part of
    the table's calibration.  ``levels`` defaults to :func:`query_levels`
    for the query's dimensionality.
    """
    q = jnp.asarray(q, jnp.float32)
    if levels is None:
        levels = query_levels(q.shape[-1])
    amax = jnp.max(jnp.abs(q), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / levels
    codes = jnp.clip(jnp.round(q / scale), -levels, levels).astype(jnp.int32)
    return codes, scale


def cache_codes(q, levels: float = INT8_LEVELS) -> tuple:
    """Symmetric per-query int8 codes + scale for the serving result cache.

    Host-side numpy (the cache key is computed on the submit path, outside
    any jit): ``code = clip(round(q / s), -levels, levels)`` with ``s =
    max|q| / levels`` — the same symmetric max-abs construction as the
    stored table, narrowed to the int8 grid so the key is 1 byte/dim.
    Identical queries always produce identical (codes, scale); two queries
    with equal codes AND equal scale reconstruct to the same vector within
    half a quantization step per element, which is what makes the codes a
    collision-bounded cache key.

    q: (d,) float vector; returns (codes int8 (d,), scale float32 scalar).
    """
    q = np.asarray(q, np.float32).reshape(-1)
    amax = float(np.max(np.abs(q))) if q.size else 0.0
    scale = np.float32(max(amax, _EPS) / levels)
    codes = np.clip(np.rint(q / scale), -levels, levels).astype(np.int8)
    return codes, scale


def code_key(codes, scale) -> bytes:
    """Stable exact-match key bytes for a quantized query.

    The key is the int8 code vector verbatim plus the little-endian float32
    bit pattern of the scale: key equality is EXACTLY (codes, scale)
    equality — no hashing, so no false hits by construction (the property
    ``tests/test_serve_tier.py`` pins with Hypothesis).  Stable across
    processes and platforms (fixed dtypes, fixed byte order).
    """
    codes = np.ascontiguousarray(codes, dtype=np.int8)
    scale_bits = np.asarray(scale, dtype="<f4").tobytes()
    return codes.tobytes() + scale_bits


def query_cache_key(q, levels: float = INT8_LEVELS) -> bytes:
    """:func:`cache_codes` + :func:`code_key` in one step — the key the
    serving tier's result cache (``repro.serve.cache``) uses."""
    return code_key(*cache_codes(q, levels))


def max_error_bound(spec: QuantSpec, scales) -> jax.Array:
    """Elementwise reconstruction-error bound of the scheme.

    int8: half a quantization step (broadcasts like ``scales``); bf16: 2^-8
    relative (bfloat16 has 8 mantissa bits incl. the implicit one); none: 0.
    """
    if spec.dtype == "int8":
        return jnp.asarray(scales, jnp.float32) * 0.5
    if spec.dtype == "bf16":
        return jnp.float32(2.0 ** -8)   # RELATIVE bound, caller scales by |x|
    return jnp.float32(0.0)
