"""Quantized distance backends for the neighbor-expansion hot path.

These are drop-in BATCH-MAJOR ``DistFn`` implementations (see
``core.bfis.DistFn``: (B, M, R) ids in, (B, M, R) f32 distances out, one
launch per global step for the whole query batch)
that read the index's QUANTIZED table (``PaddedCSR.codes`` + ``.scales``)
instead of the float32 ``vectors`` — the gather-side payload shrinks 4x
(int8) or 2x (bf16), which is exactly what the paper's memory-hierarchy
analysis says bounds expansion throughput.  They register themselves with
``repro.kernels.registry`` so search algorithms never change:

* ``ref_int8``       — pure-jnp int8 gather; per-vector scales take the
  integer fast path (int32-accumulated dot against an integer-quantized
  query on the widest non-overflowing grid, ONE f32 rescale per candidate);
  per-dimension scales dequantize the gathered rows and reduce in f32
  (memory win only).
* ``rowgather_int8`` — scalar-prefetch Pallas kernel: candidate ids drive
  the BlockSpec index_map of BOTH the int8 code rows and their per-vector
  scale rows, the VPU accumulates the code dot in int32 and rescales once.
  Per-vector scales only (the integer path is the point of the kernel).
* ``ref_bf16``       — pure-jnp bf16 gather, f32 reduction (scale-free).

Every backend serves every metric: "l2" uses
``s²·‖cx‖² − 2·s·s_q·(cx·c_q) + ‖q‖²`` with the EXACT f32 query norm (the
only exact term available without touching the f32 table), "ip"/"cosine"
use ``−s·s_q·(cx·c_q)``.  Distances are float32, padded ids (≥ N) map to
+inf — identical contracts to the f32 backends, so the two-stage re-ranked
search composes with any of them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import register_backend
from repro.quant.codec import quantize_query


def require_codes(graph, dtype: str):
    """Trace-time check that the graph carries a ``dtype`` quantized table.

    Raises with build guidance instead of a shape error deep inside jit."""
    codes, scales = getattr(graph, "codes", None), getattr(graph, "scales",
                                                           None)
    if codes is None or codes.size == 0:
        raise ValueError(
            f"the '{dtype}' distance backends need a quantized table; "
            f"build the index with IndexSpec(quant=\"{dtype}\")")
    want = jnp.int8 if dtype == "int8" else jnp.bfloat16
    if codes.dtype != want:
        raise ValueError(
            f"index is quantized as {codes.dtype}, not {dtype}; pick the "
            f"matching backend or rebuild with IndexSpec(quant=\"{dtype}\")")
    return codes, scales


def _kmetric(metric: str) -> str:
    if metric in ("ip", "cosine"):
        return "ip"
    if metric == "l2":
        return "l2"
    raise ValueError(f"unknown metric {metric!r}")


# ---------------------------------------------------------------------------
# ref_int8 / ref_bf16: pure-jnp quantized gathers
# ---------------------------------------------------------------------------

def make_int8_dist_fn(metric: str = "l2"):
    """Batch-major int8 DistFn: int32-accumulated integer dot (per-vector
    scales) or dequantize-and-reduce (per-dimension scales).  One call
    gathers every query's (B, M·R) code rows at once."""
    kmetric = _kmetric(metric)

    def dist_fn(graph, active_ids, nbr_ids, queries):
        codes, scales = require_codes(graph, "int8")
        b, m, r = nbr_ids.shape
        flat = nbr_ids.reshape(b, m * r)
        n = graph.n_nodes
        safe = jnp.minimum(flat, n - 1)
        rows = codes[safe]                                 # (B, C, d) int8
        qf = queries.astype(jnp.float32)                   # (B, d)
        per_dim = scales.shape[0] == 1                     # static at trace
        if per_dim:
            x = rows.astype(jnp.float32) * scales          # (B, C, d) f32
            if kmetric == "ip":
                d = -jnp.sum(x * qf[:, None, :], axis=-1)
            else:
                d = jnp.sum((x - qf[:, None, :]) ** 2, axis=-1)
        else:
            # query codes live on a wider grid (codec.query_levels) sized so
            # the int8 x query dot cannot overflow the int32 accumulator;
            # the asymmetric error stays dominated by the stored codes.
            # Integer arithmetic is exact, so the batched einsum is
            # bit-identical to the per-query matvec it replaces.
            qc, qs = quantize_query(qf)                    # (B,d) i32, (B,1)
            acc = jnp.einsum("bcd,bd->bc", rows.astype(jnp.int32), qc)
            s = scales[safe, 0]                            # (B, C) f32
            xq = s * qs * acc.astype(jnp.float32)
            if kmetric == "ip":
                d = -xq
            else:
                rn2 = jnp.sum(rows.astype(jnp.int32) ** 2, axis=-1)
                q2 = jnp.sum(qf * qf, axis=-1, keepdims=True)
                d = jnp.maximum(
                    s * s * rn2.astype(jnp.float32) - 2.0 * xq + q2, 0.0)
        d = jnp.where(flat < n, d, jnp.inf)
        return d.reshape(b, m, r)
    return dist_fn


def make_bf16_dist_fn(metric: str = "l2"):
    """Batch-major bf16 DistFn: half-width gather, f32 reduction, no
    scales."""
    kmetric = _kmetric(metric)

    def dist_fn(graph, active_ids, nbr_ids, queries):
        codes, _ = require_codes(graph, "bf16")
        b, m, r = nbr_ids.shape
        flat = nbr_ids.reshape(b, m * r)
        n = graph.n_nodes
        rows = codes[jnp.minimum(flat, n - 1)].astype(jnp.float32)
        qf = queries.astype(jnp.float32)                   # (B, d)
        if kmetric == "ip":
            d = -jnp.sum(rows * qf[:, None, :], axis=-1)
        else:
            d = jnp.sum((rows - qf[:, None, :]) ** 2, axis=-1)
        d = jnp.where(flat < n, d, jnp.inf)
        return d.reshape(b, m, r)
    return dist_fn


# ---------------------------------------------------------------------------
# rowgather_int8: scalar-prefetch Pallas kernel (int32 accumulate + rescale)
# ---------------------------------------------------------------------------

def _rowgather_int8_kernel(ids_ref, row_ref, scale_ref, qc_ref, qmeta_ref,
                           out_ref, *, n_nodes: int, metric: str):
    b = pl.program_id(0)
    c = pl.program_id(1)
    sid = ids_ref[b, c]
    row = row_ref[0, :].astype(jnp.int32)                  # int8 -> i32
    qc = qc_ref[0, :]                                      # i32 query codes
    acc = jnp.sum(row * qc)                                # i32 accumulation
    s = scale_ref[0, 0]                                    # per-vector scale
    xq = s * qmeta_ref[0, 0] * acc.astype(jnp.float32)     # one f32 rescale
    if metric == "ip":
        dist = -xq
    else:
        rn2 = jnp.sum(row * row)                           # i32 accumulation
        dist = jnp.maximum(
            s * s * rn2.astype(jnp.float32) - 2.0 * xq + qmeta_ref[0, 1],
            0.0)
    out_ref[0, 0] = jnp.where(sid < n_nodes, dist, jnp.float32(jnp.inf))


def int8dist_rowgather(
    codes: jax.Array, scales: jax.Array, ids: jax.Array, queries: jax.Array,
    *, interpret: bool | None = None, metric: str = "l2",
) -> jax.Array:
    """(N,d) int8 codes + (N,1) scales, (B,C) ids, (B,d) f32 queries ->
    (B,C) f32 distances.

    The prefetched candidate ids drive TWO index_maps — the int8 code row
    and its (1, 1) scale row stream together, so the pipeline's gather-side
    traffic is ~d bytes per candidate instead of 4d.  Query quantization
    (codes + [scale, ‖q‖²] meta) happens once per call outside the grid.
    """
    from repro.kernels import ops
    itp = ops.INTERPRET if interpret is None else interpret
    n, d = codes.shape
    bsz, c = ids.shape
    if scales.shape != (n, 1):
        # the scale BlockSpec below streams scales BY CANDIDATE ROW ID —
        # per-dimension (1, d) scales would silently mis-read block (0, 0)
        raise ValueError(
            f"int8dist_rowgather needs per-vector scales of shape "
            f"({n}, 1), got {scales.shape}; per-dimension scales are "
            f"served by the 'ref_int8' backend")
    qc, qs = quantize_query(queries)                       # (B,d) i8, (B,1)
    q2 = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    qmeta = jnp.concatenate([qs, q2], axis=1)              # (B, 2) f32

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, c),
        in_specs=[
            pl.BlockSpec(
                (1, d), lambda b, cc, ids_ref: (jnp.minimum(
                    ids_ref[b, cc], n - 1), 0)),
            pl.BlockSpec(
                (1, 1), lambda b, cc, ids_ref: (jnp.minimum(
                    ids_ref[b, cc], n - 1), 0)),
            pl.BlockSpec((1, d), lambda b, cc, ids_ref: (b, 0)),
            pl.BlockSpec((1, 2), lambda b, cc, ids_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, cc, ids_ref: (b, cc)),
    )
    kernel = functools.partial(_rowgather_int8_kernel, n_nodes=n,
                               metric=_kmetric(metric))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, c), jnp.float32),
        interpret=itp,
    )(ids, codes, scales, qc, qmeta)


def make_rowgather_int8_dist_fn(metric: str = "l2"):
    """Batch-major Pallas int8 DistFn (mirroring ``registry.make_dist_fn``):
    the whole (B, M·R) candidate grid is ONE scalar-prefetch launch."""
    def dist_fn(graph, active_ids, nbr_ids, queries):
        codes, scales = require_codes(graph, "int8")
        if scales.shape[0] == 1:
            raise NotImplementedError(
                "rowgather_int8 implements the per-vector-scale integer "
                "path; per-dimension scales are served by 'ref_int8'")
        b, m, r = nbr_ids.shape
        d = int8dist_rowgather(codes, scales,
                               nbr_ids.reshape(b, m * r), queries,
                               metric=metric)
        return d.reshape(b, m, r)
    return dist_fn


# ---------------------------------------------------------------------------
# registry entries — selectable purely via SearchParams.backend
# ---------------------------------------------------------------------------

def _cfg_metric(cfg) -> str:
    return getattr(cfg, "metric", "l2") or "l2"


@register_backend("ref_int8")
def _ref_int8_backend(cfg):
    return make_int8_dist_fn(_cfg_metric(cfg))


@register_backend("rowgather_int8")
def _rowgather_int8_backend(cfg):
    return make_rowgather_int8_dist_fn(_cfg_metric(cfg))


@register_backend("ref_bf16")
def _ref_bf16_backend(cfg):
    return make_bf16_dist_fn(_cfg_metric(cfg))
