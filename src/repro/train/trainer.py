"""Fault-tolerant training loop.

Responsibilities:
  * periodic async checkpoints (atomic, keep-k) + auto-resume from latest,
  * failure recovery: any exception in a step (device loss, preemption —
    simulated via ``runtime.failures`` in tests) triggers restore-from-last-
    checkpoint and continues, up to ``max_recoveries``,
  * elastic restart: ``resume(mesh)`` re-shards the restored state onto
    whatever mesh the job now has (more or fewer devices),
  * data pipeline resumption (step-seeded synthetic stream restarts exactly).
"""
from __future__ import annotations

import logging
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig
from repro.data.tokens import TokenStream, _batch_at
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step)

log = logging.getLogger("repro.trainer")


class Trainer:
    def __init__(self, model, tcfg: TrainConfig, stream: TokenStream,
                 train_step: Optional[Callable] = None,
                 max_recoveries: int = 3):
        self.model = model
        self.tcfg = tcfg
        self.stream = stream
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep=tcfg.keep_checkpoints)
        self.train_step = train_step or jax.jit(make_train_step(model, tcfg))
        self.max_recoveries = max_recoveries
        self.metrics_log = []

    def init_or_resume(self, shardings=None) -> tuple[TrainState, int]:
        state = init_train_state(self.model, jax.random.PRNGKey(
            self.tcfg.seed), self.tcfg)
        restored, step = self.ckpt.restore_latest(state, shardings)
        if restored is not None:
            log.info("resumed from checkpoint step %d", step)
            return restored, step
        return state, 0

    def run(self, steps: Optional[int] = None,
            fault_hook: Optional[Callable[[int], None]] = None
            ) -> TrainState:
        """Run to ``steps`` (default tcfg.total_steps) with auto-recovery.

        ``fault_hook(step)`` is called before each step; tests raise from it
        to simulate worker failures / preemptions.
        """
        steps = steps or self.tcfg.total_steps
        state, start = self.init_or_resume()
        step = start
        recoveries = 0
        while step < steps:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                batch = jax.tree.map(
                    lambda x: jax.numpy.asarray(x),
                    _batch_at(self.stream, step))
                state, metrics = self.train_step(state, batch)
                self.metrics_log.append(
                    {k: float(np.asarray(v)) for k, v in metrics.items()})
                step += 1
                if step % self.tcfg.checkpoint_every == 0 or step == steps:
                    self.ckpt.save(step, state)
            except Exception as e:  # noqa: BLE001 — recovery path
                recoveries += 1
                log.warning("step %d failed (%s); recovery %d/%d",
                            step, e, recoveries, self.max_recoveries)
                if recoveries > self.max_recoveries:
                    raise
                restored, ck_step = self.ckpt.restore_latest(
                    init_train_state(self.model, jax.random.PRNGKey(
                        self.tcfg.seed), self.tcfg))
                if restored is None:
                    state, step = self.init_or_resume()
                else:
                    state, step = restored, ck_step
        self.ckpt.wait()
        return state
