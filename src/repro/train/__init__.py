from repro.train.train_step import make_train_step, TrainState  # noqa: F401
from repro.train.trainer import Trainer  # noqa: F401
