"""The jitted training step: loss -> grads -> clip -> optimizer update.

Supports microbatch gradient accumulation (scan over microbatches — the
standard memory/throughput knob), remat policies, and an explicit-DP variant
with int8-compressed gradient all-reduce (shard_map over the data axis) for
bandwidth-constrained cross-pod training.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import TrainConfig
from repro.optim import (clip_by_global_norm, make_optimizer, apply_updates)
from repro.optim.grad import compressed_psum


class TrainState(NamedTuple):
    params: dict
    opt: dict
    # int8 error-feedback residuals (only allocated when compression is on)
    err: Optional[dict]


# which axis of each batch entry is the batch dimension (default 0);
# M-RoPE position ids are (3, B, S)
BATCH_AXIS = {"positions": 1}


def _mb_split(x, m: int, axis: int):
    """Split ``axis`` into (m, axis//m) and move the microbatch dim front."""
    shape = x.shape
    new = shape[:axis] + (m, shape[axis] // m) + shape[axis + 1:]
    return jnp.moveaxis(x.reshape(new), axis, 0)


def init_train_state(model, key, tcfg: TrainConfig) -> TrainState:
    params = model.init(key)
    opt_init, _ = make_optimizer(tcfg)
    err = None
    if tcfg.grad_compression == "int8":
        err = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt_init(params, tcfg), err=err)


def make_train_step(model, tcfg: TrainConfig):
    """GSPMD train step (sharding via in_shardings on params/batch)."""
    _, opt_update = make_optimizer(tcfg)
    remat = tcfg.remat != "none"

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)

    def train_step(state: TrainState, batch):
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                acc_loss, acc_g = carry
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            zero = (jnp.float32(0.0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params))
            mbs = {k: _mb_split(v, tcfg.microbatches, BATCH_AXIS.get(k, 0))
                   for k, v in batch.items()}
            (loss, grads), _ = jax.lax.scan(micro, zero, mbs)
            loss = loss / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        updates, opt = opt_update(grads, state.opt, state.params, tcfg)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt["step"].astype(jnp.float32)}
        return TrainState(params=params, opt=opt, err=state.err), metrics

    return train_step


def make_compressed_dp_train_step(model, tcfg: TrainConfig, mesh: Mesh,
                                  data_axis: str = "data"):
    """Explicit-DP train step with int8 gradient all-reduce + error feedback.

    Params replicated; batch sharded over ``data_axis``; each shard computes
    local grads, the all-reduce moves int8 (4× fewer bytes), and the
    optimizer applies identical updates everywhere.
    """
    _, opt_update = make_optimizer(tcfg)

    def shard_body(params, opt, err, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=tcfg.remat != "none")
        )(params)
        mean_grads, new_err = compressed_psum(grads, data_axis, err)
        mean_grads, gnorm = clip_by_global_norm(mean_grads, tcfg.grad_clip)
        updates, opt = opt_update(mean_grads, opt, params, tcfg)
        params = apply_updates(params, updates)
        loss = jax.lax.pmean(loss, data_axis)
        return params, opt, new_err, {"loss": loss, "grad_norm": gnorm}

    rep = None  # replicated spec tree built at call time

    @jax.jit
    def train_step(state: TrainState, batch):
        prep = jax.tree.map(lambda _: P(), state.params)
        popt = jax.tree.map(lambda _: P(), state.opt)
        perr = jax.tree.map(lambda _: P(), state.err)
        pbatch = jax.tree.map(lambda _: P(data_axis), batch)
        fn = jax.shard_map(
            shard_body, mesh=mesh,
            in_specs=(prep, popt, perr, pbatch),
            out_specs=(prep, popt, perr,
                       {"loss": P(), "grad_norm": P()}),
            check_vma=False)
        params, opt, err, metrics = fn(state.params, state.opt, state.err,
                                       batch)
        return TrainState(params, opt, err), metrics

    return train_step
