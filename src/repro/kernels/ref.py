"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package must agree (assert_allclose) with the functions
here across shape/dtype sweeps — see tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dist_ref(table: jax.Array, ids: jax.Array, queries: jax.Array,
             metric: str = "l2") -> jax.Array:
    """Gather + distance oracle (metric-general, batch-major: the (B, C)
    grid here is exactly the per-step workload the traversal engine hands
    the Pallas kernels).

    table:   (N, d) feature vectors
    ids:     (B, C) int32 candidate ids; ids >= N are padding -> +inf
    queries: (B, d)
    metric:  "l2" -> squared L2; "ip"/"cosine" -> negative inner product
             (cosine assumes pre-normalized rows/queries, so it IS ip)
    returns: (B, C) float32 distances, smaller = closer for every metric
    """
    n = table.shape[0]
    safe = jnp.minimum(ids, n - 1)
    rows = table[safe].astype(jnp.float32)                # (B, C, d)
    q = queries.astype(jnp.float32)[:, None, :]           # (B, 1, d)
    if metric in ("ip", "cosine"):
        d = -jnp.sum(rows * q, axis=-1)
    elif metric == "l2":
        d = jnp.sum((rows - q) ** 2, axis=-1)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.where(ids < n, d, jnp.inf).astype(jnp.float32)


def l2dist_ref(table: jax.Array, ids: jax.Array, queries: jax.Array
               ) -> jax.Array:
    """Squared-L2 special case of :func:`dist_ref` (kept for callers/tests)."""
    return dist_ref(table, ids, queries, metric="l2")


def sort_pairs_ref(keys: jax.Array, *payloads: jax.Array):
    """Ascending co-sort oracle: sort by (key, payload0) for determinism.

    keys: (B, n) float32; payloads: (B, n) int32 arrays.
    """
    if payloads:
        out = jax.lax.sort((keys, *payloads), num_keys=2, is_stable=True,
                           dimension=-1)
    else:
        out = jax.lax.sort((keys,), num_keys=1, is_stable=True, dimension=-1)
    return out


def topl_merge_ref(
    q_dists: jax.Array, q_ids: jax.Array, q_meta: jax.Array,
    c_dists: jax.Array, c_ids: jax.Array,
    invalid_id: int,
) -> tuple:
    """Frontier-merge oracle (mirrors core.queue.insert semantics).

    Queue rows (B, L) merge with candidate rows (B, C); duplicate ids keep
    the queue entry (meta carries the checked bit); output is the ascending
    (dist, id) top-L with the update position per row.
    """
    big = jnp.float32(jnp.inf)
    qlen = q_ids.shape[-1]
    ids = jnp.concatenate([q_ids, c_ids], axis=-1)
    dists = jnp.concatenate([q_dists, c_dists], axis=-1)
    meta = jnp.concatenate(
        [q_meta, jnp.zeros_like(c_ids)], axis=-1)
    is_new = jnp.concatenate(
        [jnp.zeros_like(q_ids), jnp.ones_like(c_ids)], axis=-1)
    # pass 1: by (id, is_new); drop dups
    ids, is_new, dists, meta = jax.lax.sort(
        (ids, is_new, dists, meta), num_keys=2, is_stable=True, dimension=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids[..., :1], bool),
         (ids[..., 1:] == ids[..., :-1]) & (ids[..., 1:] != invalid_id)],
        axis=-1)
    ids = jnp.where(dup, invalid_id, ids)
    dists = jnp.where(dup, big, dists)
    # pass 2: by (dist, id)
    dists, ids, meta, is_new = jax.lax.sort(
        (dists, ids, meta, is_new), num_keys=2, is_stable=True, dimension=-1)
    rank = jnp.arange(ids.shape[-1], dtype=jnp.int32)
    surv = (is_new == 1) & (ids != invalid_id) & (rank < qlen)
    up = jnp.min(jnp.where(surv, rank, qlen), axis=-1).astype(jnp.int32)
    return dists[..., :qlen], ids[..., :qlen], meta[..., :qlen], up
