# Pallas TPU kernels for the paper's compute hot-spots:
#   l2dist   — fused gather + squared-L2 distance (neighbor expansion)
#   bitonic  — VMEM bitonic co-sort (frontier merge / queue maintenance)
# ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles; registry.py
# the pluggable SearchConfig.dist_backend -> DistFn resolution seam.
from repro.kernels.ops import l2dist, sort_pairs, topl_merge  # noqa: F401
from repro.kernels.registry import (available_backends, make_dist_fn,  # noqa: F401
                                    pad_ids_to_tile, register_backend,
                                    resolve_backend)
