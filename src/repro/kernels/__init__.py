# Pallas TPU kernels for the paper's compute hot-spots:
#   l2dist  — fused gather + squared-L2 distance (neighbor expansion)
#   bitonic — VMEM bitonic co-sort (frontier merge / queue maintenance)
# ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles.
from repro.kernels.ops import l2dist, make_dist_fn, sort_pairs, topl_merge  # noqa: F401
