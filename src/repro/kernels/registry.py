"""Pluggable distance-backend registry for the search hot path.

The neighbor expansion (Challenges II & IV) is the paper's compute hot spot;
this module is the seam between the search algorithms (``core.bfis``,
``core.speedann``, ``core.distributed``) and the distance implementations
(``kernels.l2dist``).  Search code never names a kernel: it carries a
``SearchConfig.dist_backend`` string that is resolved here to a BATCH-MAJOR
``DistFn(graph, active_ids (B,M), nbr_ids (B,M,R), queries (B,d)) ->
(B,M,R)`` — one launch covers the whole query batch's expansion for a
global step, so the kernels see the full (B·M·R, d) × (B, d) workload they
can amortize instead of B per-lane gathers.

Built-in backends:

* ``ref``       — pure-jnp two-level gather (``core.bfis.dist_l2``); exploits
  the flattened neighbor layout for hot vertices.
* ``rowgather`` — scalar-prefetch Pallas kernel: candidate ids drive the
  BlockSpec index_map so the pipeline streams exactly the needed rows; the
  batch rides in the kernel grid's leading dimension.
* ``dma``       — explicit-DMA tile gather + MXU reduction; candidate counts
  are padded to the ``cfg.dma_group`` tile (padding ids map to +inf and are
  sliced off, so ragged M·R shapes are transparent to callers).
* ``dedup_gather`` — batch-deduplicating gather (``kernels.dedup``): the
  step's flattened (B·C,) candidate ids sort/unique first and each DISTINCT
  row is gathered ONCE for the whole batch, reduced against the stacked
  query block, and scattered back to lanes.  Bit-identical to ``ref``; the
  saved gathers are exactly ``SearchStats.batch_dup_comps``.

Quantized backends (``ref_int8`` | ``rowgather_int8`` | ``dedup_gather_int8``
| ``ref_bf16``, from ``repro.quant.kernels`` and ``kernels.dedup``) gather
from the index's int8/bf16 codes table
instead of the f32 vectors; they require an index built with
``IndexSpec(quant=...)`` and compose with the two-stage re-ranked search
(``SearchParams.rerank_k``).

New kernels register with :func:`register_backend` and become selectable via
``SearchConfig(dist_backend=...)`` without touching any search algorithm.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

# factory(cfg: SearchConfig) -> DistFn (see core.bfis.DistFn)
DistFactory = Callable[..., Callable]

_REGISTRY: Dict[str, DistFactory] = {}


def register_backend(name: str):
    """Decorator: register ``factory(cfg) -> DistFn`` under ``name``."""
    def deco(factory: DistFactory) -> DistFactory:
        _REGISTRY[name] = factory
        return factory
    return deco


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend(cfg) -> Callable:
    """``SearchConfig.dist_backend`` -> DistFn (raises on unknown names)."""
    name = getattr(cfg, "dist_backend", "ref") or "ref"
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown dist_backend {name!r}; available: "
            f"{available_backends()}") from None
    return factory(cfg)


def pad_ids_to_tile(ids: jax.Array, tile: int, n_nodes: int) -> jax.Array:
    """Pad a (..., C) id array along its LAST axis to a multiple of ``tile``
    with the sentinel ``n_nodes`` (>= N ids produce +inf distances in every
    kernel)."""
    c = ids.shape[-1]
    pad = (-c) % tile
    if pad == 0:
        return ids
    return jnp.concatenate(
        [ids, jnp.full(ids.shape[:-1] + (pad,), n_nodes, ids.dtype)],
        axis=-1)


def make_dist_fn(impl: str = "rowgather", *, metric: str = "l2",
                 dma_group: int = 8,
                 interpret: bool | None = None) -> Callable:
    """Adapter producing a batch-major ``core.bfis.DistFn`` that routes the
    whole batch's (B, M, R) expansion through ONE (B, C) kernel launch
    (C = M·R, padded to the DMA tile for ``impl="dma"``).

    ``metric`` is the index metric tag ("l2" | "ip" | "cosine"); every
    backend serves every metric (cosine = ip on pre-normalized vectors).

    Note: the kernel reads the flat embedding table; the two-level flattened
    layout is exploited by the pipeline's row streaming itself (hot rows stay
    in VMEM across adjacent grid steps), so no separate path is needed.
    """
    if impl == "ref":
        from repro.core.bfis import make_ref_dist_fn
        return make_ref_dist_fn(metric)

    def dist_fn(graph, active_ids, nbr_ids, queries):
        b, m, r = nbr_ids.shape
        flat = nbr_ids.reshape(b, m * r)
        if impl == "dma":
            flat = pad_ids_to_tile(flat, dma_group, graph.n_nodes)
        d = ops.l2dist(graph.vectors, flat, queries,
                       impl=impl, interpret=interpret, g=dma_group,
                       metric=metric)
        return d[:, :m * r].reshape(b, m, r)
    return dist_fn


def _cfg_metric(cfg) -> str:
    return getattr(cfg, "metric", "l2") or "l2"


@register_backend("ref")
def _ref_backend(cfg):
    # lazy import: core.bfis imports this module for resolution
    from repro.core.bfis import make_ref_dist_fn
    return make_ref_dist_fn(_cfg_metric(cfg))


@register_backend("rowgather")
def _rowgather_backend(cfg):
    return make_dist_fn("rowgather", metric=_cfg_metric(cfg))


@register_backend("dma")
def _dma_backend(cfg):
    return make_dist_fn("dma", metric=_cfg_metric(cfg),
                        dma_group=int(getattr(cfg, "dma_group", 8)))


# the quantized backends live next to their codec in repro.quant.kernels and
# self-register on import; importing them HERE (not from repro.quant's
# __init__) keeps the quant package importable without this module and this
# module the single place the backend catalogue is assembled
import repro.quant.kernels as _quant_kernels  # noqa: E402,F401
# the batch-dedup backends (dedup_gather / dedup_gather_int8) self-register
# the same way
import repro.kernels.dedup as _dedup_kernels  # noqa: E402,F401
