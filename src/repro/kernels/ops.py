"""jit'd public wrappers around the Pallas kernels + search integration.

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware set ``repro.kernels.ops.INTERPRET = False`` (or pass
``interpret=False``) and the same code lowers through Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import bitonic as _bitonic
from repro.kernels import l2dist as _l2
from repro.kernels import ref as _ref

INTERPRET = True   # flip on real TPU


@functools.partial(jax.jit,
                   static_argnames=("impl", "interpret", "g", "metric"))
def l2dist(
    table: jax.Array, ids: jax.Array, queries: jax.Array,
    impl: str = "rowgather", interpret: bool | None = None, g: int = 8,
    metric: str = "l2",
) -> jax.Array:
    """Fused gather + distance: (N,d), (B,C), (B,d) -> (B,C) f32.

    This is the batch-major hot-path launch: the traversal engine calls it
    ONCE per global step with the whole query batch's flattened candidate
    grid (B queries × C = M·R candidates each).

    ``metric`` selects the reduction: "l2" (squared L2) or "ip"/"cosine"
    (negative inner product; cosine callers pre-normalize, so the kernels
    treat it as ip).  Smaller = closer for every metric.

    ``g`` is the DMA tile size ("dma" impl only; requires C % g == 0 —
    ``registry.pad_ids_to_tile`` handles ragged candidate counts).
    """
    itp = INTERPRET if interpret is None else interpret
    kmetric = "ip" if metric in ("ip", "cosine") else "l2"
    if impl == "ref":
        return _ref.dist_ref(table, ids, queries, metric=kmetric)
    if impl == "rowgather":
        return _l2.l2dist_rowgather(table, ids, queries, interpret=itp,
                                    metric=kmetric)
    if impl == "dma":
        return _l2.l2dist_dma(table, ids, queries, g=g, interpret=itp,
                              metric=kmetric)
    raise ValueError(impl)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_pairs(keys, p0, p1, interpret: bool | None = None):
    """Row-wise (B, n) ascending co-sort by (key, p0); n must be 2**k."""
    itp = INTERPRET if interpret is None else interpret
    return _bitonic.sort_pairs(keys, p0, p1, interpret=itp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def topl_merge(
    q_dists: jax.Array, q_ids: jax.Array, q_meta: jax.Array,
    c_dists: jax.Array, c_ids: jax.Array,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Frontier merge on the bitonic kernel (B-batched, mirrors queue.insert).

    Queue (B, L) sorted rows + candidates (B, C) -> top-L (dists, ids, meta)
    and per-row update positions.  L + C is padded to the next power of two.
    """
    invalid = jnp.int32(2**31 - 1)
    big = jnp.float32(jnp.inf)
    bsz, qlen = q_ids.shape
    c = c_ids.shape[1]
    n = 1
    while n < qlen + c:
        n *= 2
    pad = n - (qlen + c)

    ids = jnp.concatenate(
        [q_ids, c_ids, jnp.full((bsz, pad), invalid, jnp.int32)], axis=1)
    dists = jnp.concatenate(
        [q_dists, c_dists, jnp.full((bsz, pad), big, jnp.float32)], axis=1)
    is_new = jnp.concatenate(
        [jnp.zeros((bsz, qlen), jnp.int32), jnp.ones((bsz, c), jnp.int32),
         jnp.zeros((bsz, pad), jnp.int32)], axis=1)
    meta = jnp.concatenate(
        [q_meta.astype(jnp.int32), jnp.zeros((bsz, c + pad), jnp.int32)],
        axis=1)
    # pack (meta, is_new) into one payload so the 3-array kernel suffices
    packed = meta * 2 + is_new

    # pass 1: group by (id, is_new) so existing entries precede fresh dups.
    # Split the id into (high 23 bits as an exact f32 key, low 8 bits in the
    # int payload) — exact ordering for ids up to 2^31 without denormal
    # bitcasts; is_new rides in the payload's LSB.
    key_hi = (ids >> 8).astype(jnp.float32)
    p0 = ((ids & 0xFF) << 1) | (packed & 1)
    positions = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[None, :], (bsz, n))
    _, _, pos = sort_pairs(key_hi, p0, positions, interpret=interpret)
    # gather full rows by the returned original positions
    take = jax.vmap(lambda a, p: a[p])
    ids_g = take(ids, pos)
    dists_g = take(dists, pos)
    packed_g = take(packed, pos)
    dup = jnp.concatenate(
        [jnp.zeros((bsz, 1), bool),
         (ids_g[:, 1:] == ids_g[:, :-1]) & (ids_g[:, 1:] != invalid)], axis=1)
    ids_g = jnp.where(dup, invalid, ids_g)
    dists_g = jnp.where(dup, big, dists_g)

    # pass 2: by (dist, id)
    d2, i2, pk2 = sort_pairs(dists_g, ids_g, packed_g, interpret=interpret)
    rank = jnp.arange(n, dtype=jnp.int32)[None, :]
    surv = (pk2 & 1 == 1) & (i2 != invalid) & (rank < qlen)
    up = jnp.min(jnp.where(surv, rank, qlen), axis=1).astype(jnp.int32)
    return d2[:, :qlen], i2[:, :qlen], (pk2[:, :qlen] >> 1), up
