"""Batch-deduplicating gather + distance Pallas backends.

PR 5 made every global step ONE (B, C) distance launch, but a hot vertex
sitting on several queries' frontiers is still gathered once PER LANE that
expands it.  NDSEARCH's observation (PAPERS.md) is that the gather — not
the reduction — bounds expansion throughput, so the right unit of work is
the UNIQUE row set of the whole batch step:

  1. **dedup** — sort the flattened (B·C,) candidate ids (stable), mark
     first occurrences, and compact the unique ids into a fixed-size
     (T = B·C, padded to the gather tile with the ``n_nodes`` sentinel)
     buffer; an inverse map remembers each lane slot's unique index.  All
     static shapes — the pass jits cleanly inside the traversal loop.
  2. **gather+reduce** — a scalar-prefetch Pallas kernel (the ``rowgather``
     idiom: prefetched ids drive the table BlockSpec index_map) on a
     (T, B) grid whose row index_map IGNORES the inner query index: each
     distinct row is fetched HBM→VMEM once and stays resident for its
     whole query sweep → a (T, B) distance matrix.  Sentinel slots clamp
     to row N−1; repeated grid steps on the same block skip the re-fetch,
     so the padded tail is ~free.
  3. **scatter** — lane (b, c) reads back ``D[inv[b, c], b]``.

Row reductions use the same f32 op order as ``ref``/``rowgather``, and
every (row, query) pair is still reduced exactly once, so results are
BIT-IDENTICAL to the non-dedup backends — the sort/unique pass only
changes how many times a row crosses the memory hierarchy.  The counters
``SearchStats.uniq_comps`` / ``batch_dup_comps`` (first-toucher
attribution, ``core.metrics.batch_unique_counts``) measure exactly the
gather traffic this backend saves.

``dedup_gather_int8`` composes with ``repro.quant``: the unique rows are
gathered from the int8 codes table (per-vector scales, int32-accumulated
integer dot, one f32 rescale — bit-identical to ``ref_int8``), so the 4x
payload shrink compounds with the dedup factor.

Both register with ``kernels.registry`` — selecting them is purely
``SearchConfig(dist_backend="dedup_gather")``; no search code changes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import pad_ids_to_tile, register_backend
from repro.quant.codec import quantize_query

# unique-buffer tile: sentinel-padded tail slots re-fetch the same clamped
# row, which the Pallas pipeline elides, so over-padding is cheap
TILE = 8


def unique_ids_inverse(
    ids: jax.Array, n_nodes: int, tile: int = TILE,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Static-shape sort/unique pass over a (B, C) candidate grid.

    Every id >= ``n_nodes`` (padding) is folded onto the single sentinel
    ``n_nodes`` before deduplication.  Returns:

    * ``uniq`` (T,) int32 — the distinct ids packed at the front, the rest
      of the buffer filled with the sentinel; T = B·C rounded up to
      ``tile`` (see :func:`registry.pad_ids_to_tile`).
    * ``inv`` (B, C) int32 — ``uniq[inv[b, c]]`` folds back to
      ``min(ids[b, c], n_nodes)``; the scatter map of step 3.
    * ``n_uniq`` () int32 — how many REAL (non-sentinel) distinct ids the
      batch step touches: the rows a dedup backend actually gathers.
    """
    bsz, c = ids.shape
    t = bsz * c
    sent = jnp.int32(n_nodes)
    flat = jnp.where(ids < n_nodes, ids, sent).astype(jnp.int32).reshape(-1)
    order = jnp.argsort(flat, stable=True)
    sorted_ids = flat[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    rank = jnp.cumsum(first.astype(jnp.int32)) - 1         # uniq idx / elt
    uniq = jnp.full((t,), sent, jnp.int32).at[rank].set(sorted_ids)
    inv = jnp.zeros((t,), jnp.int32).at[order].set(rank).reshape(bsz, c)
    n_uniq = jnp.sum(first & (sorted_ids < n_nodes)).astype(jnp.int32)
    return pad_ids_to_tile(uniq, tile, n_nodes), inv, n_uniq


# ---------------------------------------------------------------------------
# f32 table kernel
# ---------------------------------------------------------------------------

def _dedup_kernel(uids_ref, row_ref, q_ref, out_ref, *, n_nodes: int,
                  metric: str):
    # identical per-pair math to l2dist._rowgather_kernel — a (d,)-vector
    # reduction per (row, query) pair — so results are bit-identical to the
    # non-dedup backends (a (B, d)-block reduction would drift in the last
    # ulp: XLA picks a different accumulation order per shape)
    i = pl.program_id(0)
    sid = uids_ref[i]
    row = row_ref[0, :].astype(jnp.float32)                # (d,)
    q = q_ref[0, :].astype(jnp.float32)                    # (d,)
    if metric == "ip":
        dist = -jnp.sum(row * q)
    else:
        diff = row - q
        dist = jnp.sum(diff * diff)
    out_ref[0, 0] = jnp.where(sid < n_nodes, dist, jnp.float32(jnp.inf))


def dedupdist(
    table: jax.Array, ids: jax.Array, queries: jax.Array,
    *, interpret: bool | None = None, metric: str = "l2", tile: int = TILE,
) -> jax.Array:
    """(N,d) table, (B,C) ids, (B,d) queries -> (B,C) f32 distances with
    each DISTINCT candidate row gathered once for the whole batch.

    Same contract as :func:`l2dist.l2dist_rowgather` (padded ids >= N give
    +inf; "ip" = negative inner product) and bit-identical to it.
    """
    from repro.kernels import ops
    itp = ops.INTERPRET if interpret is None else interpret
    n, d = table.shape
    bsz, _ = ids.shape
    uniq, inv, _ = unique_ids_inverse(ids, n, tile)
    t = uniq.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t, bsz),
        in_specs=[
            # the row block's index_map ignores the inner query index, so
            # the pipeline fetches each unique row ONCE and keeps it in
            # VMEM for the whole b-sweep (sentinel slots clamp to the last
            # row and are masked to +inf in-kernel)
            pl.BlockSpec(
                (1, d), lambda i, b, uids_ref: (jnp.minimum(
                    uids_ref[i], n - 1), 0)),
            pl.BlockSpec((1, d), lambda i, b, uids_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, b, uids_ref: (i, b)),
    )
    kernel = functools.partial(_dedup_kernel, n_nodes=n, metric=metric)
    dmat = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, bsz), jnp.float32),
        interpret=itp,
    )(uniq, table, queries)
    # scatter: lane (b, c) reads its unique row's distance to query b
    return dmat[inv, jnp.arange(bsz, dtype=jnp.int32)[:, None]]


# ---------------------------------------------------------------------------
# int8 codes kernel (per-vector scales; composes with repro.quant)
# ---------------------------------------------------------------------------

def _dedup_int8_kernel(uids_ref, row_ref, scale_ref, qc_ref, qmeta_ref,
                       out_ref, *, n_nodes: int, metric: str):
    # per-pair math mirrors quant.kernels._rowgather_int8_kernel exactly
    # (int32-accumulated integer dot, ONE f32 rescale) — bit-identical to
    # ref_int8 / rowgather_int8
    i = pl.program_id(0)
    sid = uids_ref[i]
    row = row_ref[0, :].astype(jnp.int32)                  # int8 -> i32
    qc = qc_ref[0, :]                                      # i32 query codes
    acc = jnp.sum(row * qc)                                # i32 accumulation
    s = scale_ref[0, 0]                                    # per-vector scale
    xq = s * qmeta_ref[0, 0] * acc.astype(jnp.float32)     # one f32 rescale
    if metric == "ip":
        dist = -xq
    else:
        rn2 = jnp.sum(row * row)                           # i32 accumulation
        dist = jnp.maximum(
            s * s * rn2.astype(jnp.float32) - 2.0 * xq + qmeta_ref[0, 1],
            0.0)
    out_ref[0, 0] = jnp.where(sid < n_nodes, dist, jnp.float32(jnp.inf))


def dedupdist_int8(
    codes: jax.Array, scales: jax.Array, ids: jax.Array, queries: jax.Array,
    *, interpret: bool | None = None, metric: str = "l2", tile: int = TILE,
) -> jax.Array:
    """int8 variant of :func:`dedupdist`: unique rows gather from the
    (N,d) int8 codes table + (N,1) per-vector scales, so the 4x payload
    shrink compounds with the dedup factor.  Bit-identical to ``ref_int8``
    (same int32-accumulate + single-f32-rescale op order)."""
    from repro.kernels import ops
    itp = ops.INTERPRET if interpret is None else interpret
    n, d = codes.shape
    bsz, _ = ids.shape
    if scales.shape != (n, 1):
        raise ValueError(
            f"dedupdist_int8 needs per-vector scales of shape ({n}, 1), "
            f"got {scales.shape}; per-dimension scales are served by the "
            f"'ref_int8' backend")
    qc, qs = quantize_query(queries)                       # (B,d) i32, (B,1)
    q2 = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    qmeta = jnp.concatenate([qs, q2], axis=1)              # (B, 2) f32
    uniq, inv, _ = unique_ids_inverse(ids, n, tile)
    t = uniq.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t, bsz),
        in_specs=[
            # code row + its scale row stream once per unique id (their
            # index_maps ignore the inner query index)
            pl.BlockSpec(
                (1, d), lambda i, b, uids_ref: (jnp.minimum(
                    uids_ref[i], n - 1), 0)),
            pl.BlockSpec(
                (1, 1), lambda i, b, uids_ref: (jnp.minimum(
                    uids_ref[i], n - 1), 0)),
            pl.BlockSpec((1, d), lambda i, b, uids_ref: (b, 0)),
            pl.BlockSpec((1, 2), lambda i, b, uids_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, b, uids_ref: (i, b)),
    )
    kernel = functools.partial(_dedup_int8_kernel, n_nodes=n,
                               metric="ip" if metric in ("ip", "cosine")
                               else "l2")
    dmat = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, bsz), jnp.float32),
        interpret=itp,
    )(uniq, codes, scales, qc, qmeta)
    return dmat[inv, jnp.arange(bsz, dtype=jnp.int32)[:, None]]


# ---------------------------------------------------------------------------
# registry entries — zero search-code changes
# ---------------------------------------------------------------------------

def make_dedup_dist_fn(metric: str = "l2", tile: int = TILE):
    """Batch-major dedup DistFn: the step's whole (B, M·R) candidate grid
    dedups into ONE unique-row gather launch."""
    kmetric = "ip" if metric in ("ip", "cosine") else "l2"

    def dist_fn(graph, active_ids, nbr_ids, queries):
        b, m, r = nbr_ids.shape
        d = dedupdist(graph.vectors, nbr_ids.reshape(b, m * r), queries,
                      metric=kmetric, tile=tile)
        return d.reshape(b, m, r)
    return dist_fn


def make_dedup_int8_dist_fn(metric: str = "l2", tile: int = TILE):
    """Batch-major int8 dedup DistFn ((B, M, R) ids in, (B, M, R) f32 out;
    the batch's distinct code rows are gathered once).  Per-vector scales
    only, like ``rowgather_int8``."""
    from repro.quant.kernels import require_codes

    def dist_fn(graph, active_ids, nbr_ids, queries):
        codes, scales = require_codes(graph, "int8")
        if scales.shape[0] == 1:
            raise NotImplementedError(
                "dedup_gather_int8 implements the per-vector-scale integer "
                "path; per-dimension scales are served by 'ref_int8'")
        b, m, r = nbr_ids.shape
        d = dedupdist_int8(codes, scales, nbr_ids.reshape(b, m * r),
                           queries, metric=metric, tile=tile)
        return d.reshape(b, m, r)
    return dist_fn


def _cfg_metric(cfg) -> str:
    return getattr(cfg, "metric", "l2") or "l2"


@register_backend("dedup_gather")
def _dedup_backend(cfg):
    return make_dedup_dist_fn(_cfg_metric(cfg))


@register_backend("dedup_gather_int8")
def _dedup_int8_backend(cfg):
    return make_dedup_int8_dist_fn(_cfg_metric(cfg))
