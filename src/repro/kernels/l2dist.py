"""Fused gather + squared-L2 distance Pallas TPU kernel.

This is the paper's compute hot spot (Challenges II & IV): the neighbor
expansion gathers ≤ B·M·R feature vectors at data-dependent addresses and
reduces each against its query.  On CPU the paper attacks it with neighbor
grouping + prefetch; the TPU-native form is a *fused dynamic-gather +
distance* kernel so gathered rows never round-trip through HBM.  The
batch-major traversal engine launches each kernel ONCE per global step over
the whole (B, C) candidate grid — the query batch rides in the grid's
leading dimension, so B amortizes grid setup and keeps the row-stream
pipeline full:

* ``rowgather`` variant — scalar-prefetched candidate ids drive the
  ``BlockSpec`` index_map of the embedding table, so the pipeline streams
  exactly the needed (1, d) rows HBM→VMEM while the VPU reduces the previous
  row.  This is the canonical Pallas dynamic-gather idiom; Mosaic
  double-buffers the row fetches automatically.
* ``dma`` variant — the table stays unblocked (``pl.ANY`` memory space); the
  kernel issues G explicit row DMAs into a VMEM scratch tile, then computes
  ``‖x‖² − 2 x·q + ‖q‖²`` for the whole tile with an MXU matvec.  G=8 rows
  amortize grid overhead and give the MXU a (G, d)×(d,) contraction; this is
  the layout the §Perf iterations tune.

Distances use the expanded form with f32 accumulation; padded ids (>= N)
return +inf.  Both variants validate against ``ref.l2dist_ref`` in
interpret mode (CPU) — see tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Variant 1: scalar-prefetch row gather
# ---------------------------------------------------------------------------

def _rowgather_kernel(ids_ref, row_ref, q_ref, out_ref, *, n_nodes: int,
                      metric: str):
    b = pl.program_id(0)
    c = pl.program_id(1)
    sid = ids_ref[b, c]
    row = row_ref[0, :].astype(jnp.float32)
    q = q_ref[0, :].astype(jnp.float32)
    if metric == "ip":
        dist = -jnp.sum(row * q)
    else:
        diff = row - q
        dist = jnp.sum(diff * diff)
    out_ref[0, 0] = jnp.where(sid < n_nodes, dist, jnp.float32(jnp.inf))


def l2dist_rowgather(
    table: jax.Array, ids: jax.Array, queries: jax.Array,
    *, interpret: bool = True, metric: str = "l2",
) -> jax.Array:
    """(N,d) table, (B,C) ids, (B,d) queries -> (B,C) f32 distances.

    ``metric="l2"`` -> squared L2; ``"ip"`` -> negative inner product
    (smaller = closer either way; padded ids >= N report +inf).
    """
    n, d = table.shape
    bsz, c = ids.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, c),
        in_specs=[
            # one gathered table row per grid step, addressed by the
            # prefetched candidate id (clamped; padding masked in-kernel)
            pl.BlockSpec(
                (1, d), lambda b, cc, ids_ref: (jnp.minimum(
                    ids_ref[b, cc], n - 1), 0)),
            pl.BlockSpec((1, d), lambda b, cc, ids_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, cc, ids_ref: (b, cc)),
    )
    kernel = functools.partial(_rowgather_kernel, n_nodes=n, metric=metric)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, c), jnp.float32),
        interpret=interpret,
    )(ids, table, queries)


# ---------------------------------------------------------------------------
# Variant 2: explicit-DMA tile gather + MXU reduction
# ---------------------------------------------------------------------------

def _dma_kernel(ids_ref, table_ref, q_ref, out_ref, rows, sem,
                *, n_nodes: int, g: int, metric: str):
    b = pl.program_id(0)
    cb = pl.program_id(1)
    # issue G row DMAs HBM->VMEM (Mosaic overlaps them; interpret mode runs
    # them synchronously)
    for i in range(g):
        sid = jnp.minimum(ids_ref[b, cb * g + i], n_nodes - 1)
        pltpu.make_async_copy(
            table_ref.at[pl.ds(sid, 1), :], rows.at[pl.ds(i, 1), :], sem
        ).start()
    for i in range(g):
        pltpu.make_async_copy(
            table_ref.at[pl.ds(0, 1), :], rows.at[pl.ds(i, 1), :], sem
        ).wait()
    x = rows[...].astype(jnp.float32)                      # (G, d)
    q = q_ref[0, :].astype(jnp.float32)                    # (d,)
    xq = jax.lax.dot_general(                              # MXU (G,d)x(d,1)
        x, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    if metric == "ip":
        dist = -xq
    else:
        x2 = jnp.sum(x * x, axis=1)
        q2 = jnp.sum(q * q)
        dist = jnp.maximum(x2 - 2.0 * xq + q2, 0.0)
    valid = jnp.stack([ids_ref[b, cb * g + i] < n_nodes for i in range(g)])
    out_ref[0, :] = jnp.where(valid, dist, jnp.float32(jnp.inf))


def l2dist_dma(
    table: jax.Array, ids: jax.Array, queries: jax.Array,
    *, g: int = 8, interpret: bool = True, metric: str = "l2",
) -> jax.Array:
    """DMA-tile variant; requires C % g == 0 (pad ids with N to align).

    ``metric="ip"`` keeps the same MXU matvec and skips the norm terms."""
    n, d = table.shape
    bsz, c = ids.shape
    assert c % g == 0, f"candidate count {c} not divisible by tile {g}"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, c // g),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),   # table stays in HBM
            pl.BlockSpec((1, d), lambda b, cb, ids_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, g), lambda b, cb, ids_ref: (b, cb)),
        scratch_shapes=[
            pltpu.VMEM((g, d), table.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    kernel = functools.partial(_dma_kernel, n_nodes=n, g=g, metric=metric)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, c), jnp.float32),
        interpret=interpret,
    )(ids, table, queries)
