"""Bitonic co-sort Pallas TPU kernel — the frontier-merge hot spot.

The paper's Challenge III is the cost of keeping the candidate queue in
strict order.  Our queue ops (core/queue.py) spend their time in two
``lax.sort`` passes of length L+C per step per walker.  This kernel performs
the (key, payload, payload) co-sort entirely inside VMEM with a bitonic
network, so a frontier merge is a single fused kernel invocation rather than
an XLA variadic-sort (which lowers to a serial sort per row on TPU).

Bitonic networks map beautifully onto the TPU vector unit because the
partner exchange ``i ↔ i^j`` for a power-of-two ``j`` is a static reshape +
flip — no gathers:

    (n,) -> (n / 2j, 2, j) -> flip middle axis -> (n,)

All log²(n)/2 passes run on (8, n/8)-shaped VMEM-resident registers; keys
are f32 distances, payloads int32 ids / meta bits.  Ties break on payload0
(id) for determinism, matching ``jax.lax.sort(num_keys=2)`` semantics.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_perm(x: jax.Array, j: int) -> jax.Array:
    """x[i ^ j] for power-of-two j, as reshape + flip (no gather)."""
    n = x.shape[-1]
    y = x.reshape(x.shape[:-1] + (n // (2 * j), 2, j))
    y = jnp.flip(y, axis=-2)
    return y.reshape(x.shape)


def _bitonic_pass(keys, p0, p1, k: int, j: int, n: int):
    idx = jax.lax.iota(jnp.int32, n)
    pk = _xor_perm(keys, j)
    pp0 = _xor_perm(p0, j)
    pp1 = _xor_perm(p1, j)
    asc = (idx & k) == 0           # ascending block?
    lower = (idx & j) == 0         # lane is the lower partner?
    take_min = asc == lower
    # partner is smaller when (key, payload0, payload1) orders it first;
    # p1 participates as the final tiebreak so the comparison is TOTAL —
    # otherwise a full (key, p0) tie with distinct p1 would duplicate one
    # lane's payload instead of exchanging (the classic bitonic tie bug)
    partner_first = (pk < keys) | (
        (pk == keys) & ((pp0 < p0) | ((pp0 == p0) & (pp1 < p1))))
    take_partner = jnp.where(take_min, partner_first, ~partner_first)
    keys = jnp.where(take_partner, pk, keys)
    p0 = jnp.where(take_partner, pp0, p0)
    p1 = jnp.where(take_partner, pp1, p1)
    return keys, p0, p1


def _sort_kernel(k_ref, p0_ref, p1_ref, ko_ref, p0o_ref, p1o_ref, *, n: int):
    keys = k_ref[0, :]
    p0 = p0_ref[0, :]
    p1 = p1_ref[0, :]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            keys, p0, p1 = _bitonic_pass(keys, p0, p1, k, j, n)
            j //= 2
        k *= 2
    ko_ref[0, :] = keys
    p0o_ref[0, :] = p0
    p1o_ref[0, :] = p1


def sort_pairs(
    keys: jax.Array, p0: jax.Array, p1: jax.Array, *, interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Row-wise ascending co-sort by (key, p0).  Shapes (B, n), n = 2**k.

    keys f32; p0/p1 int32 payloads.  Returns sorted (keys, p0, p1).
    """
    bsz, n = keys.shape
    assert n & (n - 1) == 0, f"bitonic length {n} must be a power of two"
    kernel = functools.partial(_sort_kernel, n=n)
    specs = [pl.BlockSpec((1, n), lambda b: (b, 0)) for _ in range(3)]
    return pl.pallas_call(
        kernel,
        grid=(bsz,),
        in_specs=specs,
        out_specs=tuple(specs),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, n), jnp.int32),
            jax.ShapeDtypeStruct((bsz, n), jnp.int32),
        ),
        interpret=interpret,
    )(keys.astype(jnp.float32), p0.astype(jnp.int32), p1.astype(jnp.int32))
