"""Elastic scaling: re-shard a training state onto a different mesh.

The checkpoint format is mesh-agnostic (host numpy per leaf), so elasticity
is: load -> device_put against the new mesh's shardings.  This module adds
the in-memory path (no disk round-trip) for live resizes, plus a helper to
re-plan batch sharding when the data-parallel width changes.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding import param_specs


def reshard_state(state, new_mesh: Mesh, rules=None):
    """Re-shard every leaf of a TrainState/pytree onto ``new_mesh``.

    Parameter-like leaves follow the path-convention specs; everything else
    (scalars, steps) replicates.
    """
    specs = param_specs(state, new_mesh, rules)

    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))

    return jax.tree.map(put, state, specs)
