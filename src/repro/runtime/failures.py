"""Failure injection for fault-tolerance tests.

On real clusters failures arrive as XLA device errors / preemption signals;
here they are raised deterministically at chosen steps so the Trainer's
recovery path is exercised end-to-end (checkpoint -> crash -> restore ->
bit-exact continuation)."""
from __future__ import annotations

from typing import Iterable, Set


class SimulatedWorkerFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps: Iterable[int]):
        self.fail_at: Set[int] = set(fail_at_steps)
        self.fired: Set[int] = set()

    def __call__(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedWorkerFailure(
                f"simulated device loss at step {step}")
