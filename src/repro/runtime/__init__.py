from repro.runtime.failures import FailureInjector  # noqa: F401
from repro.runtime.elastic import reshard_state  # noqa: F401
