"""Admission control: queue-depth watermarks + two priority classes.

Deadline shedding (``repro.serve.coalescer``) rejects requests that are
ALREADY late — it bounds wasted work, not the tail.  Under sustained
overload every request queues behind the backlog, so p99 blows up for
everyone.  The fix (cf. "Low Latency Without Throughput Loss", PAPERS.md)
is to decouple traffic classes BEFORE the queue fills:

* ``"critical"`` — latency-critical, interactive traffic.  Admitted until
  the queue reaches ``critical_watermark``.
* ``"throughput"`` — batch/offline traffic that tolerates rejection and
  retry.  Admitted only while the queue is below
  ``throughput_watermark``.

Because ``throughput_watermark <= critical_watermark`` is enforced at
construction, the throughput class is ALWAYS shed first: overload squeezes
batch traffic out while the critical class keeps a short queue — its p99
stays bounded by (watermark x service time) instead of growing with the
backlog.  Admission decisions are pure threshold comparisons, so they are
monotone in queue depth (admitted at depth d ⇒ admitted at every depth
< d) — both invariants are pinned by Hypothesis property tests in
``tests/test_serve_tier.py``.

The priority class also feeds EDF batch formation in the coalescer:
critical requests sort ahead of throughput requests, earliest deadline
first within each class.

Decisions are counted per ``(class, decision)`` locally (``stats()``) and,
with an :class:`~repro.obs.Observability` bundle with ``metrics`` on,
mirrored into the registry as
``admission_decisions_total{priority=..., decision=...}``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional

from repro.obs import NULL_OBS, Observability

__all__ = ["PRIORITIES", "AdmissionPolicy", "AdmissionRejected",
           "AdmissionController"]

#: The two traffic classes, in shed order: "throughput" is always shed
#: first, "critical" last.
PRIORITIES = ("critical", "throughput")


class AdmissionRejected(Exception):
    """The request was shed at admission (queue depth over its class's
    watermark); its future receives this exception instead of a result.
    Callers in the throughput class are expected to back off and retry."""


@dataclass(frozen=True)
class AdmissionPolicy:
    """Queue-depth watermarks per priority class.

    A request of class c is admitted iff the current queue depth is
    strictly below its class watermark.  ``throughput_watermark <=
    critical_watermark`` is enforced, so shedding always starts with the
    throughput class — the "critical is never shed before throughput"
    invariant holds by construction.
    """
    throughput_watermark: int = 32   # shed throughput-class at this depth
    critical_watermark: int = 128    # shed EVERYTHING at this depth

    def __post_init__(self):
        if self.throughput_watermark < 1:
            raise ValueError("throughput_watermark must be >= 1")
        if self.critical_watermark < self.throughput_watermark:
            raise ValueError(
                "critical_watermark must be >= throughput_watermark — the "
                "critical class is never shed before the throughput class")

    def watermark(self, priority: str) -> int:
        if priority not in PRIORITIES:
            raise ValueError(
                f"unknown priority {priority!r}; one of {PRIORITIES}")
        return (self.critical_watermark if priority == "critical"
                else self.throughput_watermark)

    def admits(self, queue_depth: int, priority: str) -> bool:
        """Pure decision: admit iff ``queue_depth`` is below the class
        watermark.  Monotone in depth by construction."""
        return queue_depth < self.watermark(priority)


class AdmissionController:
    """Stateful wrapper: applies an :class:`AdmissionPolicy` and counts the
    decisions (per class, admitted vs shed), optionally mirroring them into
    the obs registry.  ``clock`` is accepted for symmetry with the other
    serving-tier components (reserved for future rate-based policies)."""

    def __init__(self, policy: AdmissionPolicy = AdmissionPolicy(), *,
                 obs: Optional[Observability] = None, clock=None):
        self.policy = policy
        self.obs = obs if obs is not None else NULL_OBS
        self._clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self.admitted: Dict[str, int] = {p: 0 for p in PRIORITIES}
        self.shed: Dict[str, int] = {p: 0 for p in PRIORITIES}

    def admit(self, queue_depth: int, priority: str) -> bool:
        """Decide and record: True = enqueue, False = shed now."""
        ok = self.policy.admits(queue_depth, priority)
        with self._lock:
            (self.admitted if ok else self.shed)[priority] += 1
        if self.obs.metrics:
            self.obs.registry.counter(
                "admission_decisions_total",
                "admission decisions by priority class and outcome",
            ).inc(1, priority=priority,
                  decision="admitted" if ok else "shed")
        return ok

    def stats(self) -> Dict[str, float]:
        """Per-class decision counters (exact)."""
        with self._lock:
            out: Dict[str, float] = {}
            for p in PRIORITIES:
                out[f"admitted_{p}"] = float(self.admitted[p])
                out[f"shed_{p}"] = float(self.shed[p])
            out["shed_total"] = float(sum(self.shed.values()))
            return out
