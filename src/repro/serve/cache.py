"""Result cache keyed on the query's int8 quantization codes.

Real online traffic is SKEWED: popular items are queried again and again,
and a graph traversal costs the same whether or not the answer was computed
two milliseconds ago.  This module short-circuits repeats before they ever
reach the coalescing queue.

The cache key is the query's symmetric int8 quantization codes plus the
float32 scale bit pattern (``repro.quant.codec.query_cache_key``) — the
same codes AQR-HNSW-style quantized search already materializes at query
time, reused as an EXACT-MATCH key:

* **no false hits by construction** — the key IS the (codes, scale) pair,
  byte for byte; key equality implies quantized-code equality (pinned by a
  Hypothesis property test), so a hit can only come from a query whose
  quantized reconstruction is identical;
* **collision-bounded** — two distinct queries sharing a key differ by at
  most half a quantization step per element; the optional per-entry
  **recall guard** (``guard_eps``) tightens this further by comparing the
  incoming query against the exact query the entry was computed for and
  demoting the lookup to a miss when they differ by more than ``guard_eps``
  in L2 (``guard_eps=0.0``, the default, admits exact repeats only);
* **bit-identical fall-through** — the cache only ever REPLAYS results the
  engine produced; a miss goes through the normal serving path unchanged,
  so cached and uncached serving return identical answers (pinned by
  ``tests/test_serve_tier.py``).

Semantics: exact-key LRU with capacity eviction and optional TTL expiry.
Hit / miss / eviction / expiry / guard-miss counters are kept locally
(``stats()``) and, when an :class:`~repro.obs.Observability` bundle with
``metrics`` enabled is attached, mirrored into the registry as
``serve_cache_events_total{event=...}``.

Typical use is through the coalescer::

    srv = index.serve_async(params, cache=CachePolicy(capacity=4096,
                                                      ttl_s=30.0))
    fut = srv.submit(q)        # hit: resolved immediately; miss: queued

but the cache is also usable standalone around any ``ids/dists`` producer.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np

from repro.obs import NULL_OBS, Observability
from repro.quant.codec import cache_codes, code_key

__all__ = ["CachePolicy", "CacheEntry", "ResultCache"]


class CachePolicy(NamedTuple):
    """Result-cache configuration.

    * ``capacity`` — max entries; least-recently-USED entry evicted first.
    * ``ttl_s`` — entries older than this are expired at lookup time
      (None: entries never age out).
    * ``guard_eps`` — the per-entry recall guard: a hit is served only if
      the incoming query is within this L2 distance of the exact query the
      entry was computed for.  ``0.0`` admits exact repeats only; raise it
      to trade a bounded recall risk for a higher hit rate (the quantized
      key already bounds the gap to half a code step per element).
    """
    capacity: int = 4096
    ttl_s: Optional[float] = None
    guard_eps: float = 0.0


class CacheEntry(NamedTuple):
    """One cached result: the exact query it was computed for (the recall
    guard's reference), the engine's answer, and the insertion time."""
    query: np.ndarray        # (d,) float32 — guard reference
    ids: np.ndarray          # (k,) int32
    dists: np.ndarray        # (k,) float32
    insert_t: float          # clock seconds at insertion (TTL reference)


class ResultCache:
    """Exact-key LRU over quantized-code keys, with TTL and recall guard.

    Thread-safe (one lock around the map — lookups are O(1) plus one
    (d,)-vector guard comparison).  ``clock`` is injectable for the
    deterministic serving test harness; it defaults to
    ``time.perf_counter`` and only relative differences are used.
    """

    def __init__(self, policy: CachePolicy = CachePolicy(), *,
                 clock=None, obs: Optional[Observability] = None):
        if policy.capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        if policy.ttl_s is not None and policy.ttl_s <= 0:
            raise ValueError("ttl_s must be > 0 (or None to disable)")
        if policy.guard_eps < 0:
            raise ValueError("guard_eps must be >= 0")
        self.policy = policy
        self.obs = obs if obs is not None else NULL_OBS
        self._clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self._entries: "OrderedDict[bytes, CacheEntry]" = OrderedDict()
        # event counters (exact; mirrored into the obs registry when on)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.expirations = 0
        self.guard_misses = 0
        self.insertions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -- keys ----------------------------------------------------------------

    @staticmethod
    def key_for(query) -> bytes:
        """The stable quantized-code key for one (d,) query (see
        ``repro.quant.codec.query_cache_key``)."""
        return code_key(*cache_codes(query))

    # -- events --------------------------------------------------------------

    def _count(self, event: str) -> None:
        # caller holds the lock for the local counter; the registry child
        # has its own locking
        if self.obs.metrics:
            self.obs.registry.counter(
                "serve_cache_events_total",
                "result-cache events by kind (hit/miss/eviction/...)",
            ).inc(1, event=event)

    # -- lookup / insert -----------------------------------------------------

    def lookup(self, query, *, key: Optional[bytes] = None,
               now: Optional[float] = None
               ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Probe the cache for ``query``; returns ``(ids, dists)`` on a hit,
        None on a miss (including TTL expiry and recall-guard rejection).

        A hit REPLAYS the stored engine result bit for bit.  ``key`` skips
        recomputing the quantized codes when the caller already has them.
        """
        q = np.asarray(query, np.float32).reshape(-1)
        if key is None:
            key = self.key_for(q)
        if now is None:
            now = self._clock()
        ttl = self.policy.ttl_s
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                self._count("miss")
                return None
            if ttl is not None and now - entry.insert_t > ttl:
                del self._entries[key]
                self.expirations += 1
                self.misses += 1
                self._count("expired")
                self._count("miss")
                return None
            if float(np.linalg.norm(q - entry.query)) > self.policy.guard_eps:
                # recall guard: same quantized codes, but the exact query
                # drifted past the configured bound — do not replay
                self.guard_misses += 1
                self.misses += 1
                self._count("guard_miss")
                self._count("miss")
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            self._count("hit")
            return entry.ids, entry.dists

    def insert(self, query, ids, dists, *, key: Optional[bytes] = None,
               now: Optional[float] = None) -> None:
        """Store one served result under the query's quantized-code key.

        Arrays are copied so cached results are immune to caller-side
        mutation; re-inserting an existing key refreshes entry, guard
        reference, and TTL.
        """
        q = np.asarray(query, np.float32).reshape(-1)
        if key is None:
            key = self.key_for(q)
        if now is None:
            now = self._clock()
        entry = CacheEntry(
            query=np.array(q, np.float32),
            ids=np.array(ids),
            dists=np.array(dists),
            insert_t=now)
        with self._lock:
            if key in self._entries:
                del self._entries[key]
            elif len(self._entries) >= self.policy.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
                self._count("eviction")
            self._entries[key] = entry
            self.insertions += 1
            self._count("insertion")

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Exact event counters + current size and hit rate."""
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "size": float(len(self._entries)),
                "capacity": float(self.policy.capacity),
                "hits": float(self.hits),
                "misses": float(self.misses),
                "hit_rate": self.hits / lookups if lookups else 0.0,
                "evictions": float(self.evictions),
                "expirations": float(self.expirations),
                "guard_misses": float(self.guard_misses),
                "insertions": float(self.insertions),
            }
