from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.ann_engine import AnnEngine, ServeResult  # noqa: F401
from repro.serve.coalescer import AsyncAnnEngine  # noqa: F401
from repro.serve.coalescer import AsyncServeResult  # noqa: F401
from repro.serve.coalescer import CoalescePolicy  # noqa: F401
from repro.serve.coalescer import DeadlineExceeded  # noqa: F401
from repro.serve.cache import CachePolicy, ResultCache  # noqa: F401
from repro.serve.admission import AdmissionController  # noqa: F401
from repro.serve.admission import AdmissionPolicy  # noqa: F401
from repro.serve.admission import AdmissionRejected  # noqa: F401
from repro.serve.admission import PRIORITIES  # noqa: F401
from repro.serve.router import ReplicaRouter, RouterPolicy  # noqa: F401
from repro.serve.router import RouterResult  # noqa: F401
from repro.serve.knnlm import KNNLMDatastore, knnlm_logits  # noqa: F401
from repro.obs import Observability, NULL_OBS  # noqa: F401
