"""kNN-LM decoding with Speed-ANN retrieval (the paper's technique as a
first-class serving feature).

A datastore maps LM hidden states -> next tokens (Khandelwal et al., 2020
formulation).  At each decode step the current hidden state queries the
Speed-ANN index; retrieval probabilities p_knn(w) ∝ Σ_{(h,w') : w'=w}
exp(-d(h, q)/τ) are interpolated with the LM softmax:

    p(w) = λ · p_knn(w) + (1 − λ) · p_lm(w)

Building the datastore runs the model over a corpus and records
(final-hidden-state, next-token) pairs; the index is a standard
``repro.ann.AnnIndex``, so every optimization in core/ (staged parallel
expansion, adaptive sync, walker sharding) accelerates kNN-LM serving
directly — and the retrieval metric is a build-time choice: ``"l2"``
(Khandelwal et al.'s distance), ``"ip"``/``"cosine"`` for dot-product
retrieval over hidden states (the natural metric when the LM head itself
is an inner product).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.core.config import SearchConfig


class KNNLMDatastore(NamedTuple):
    index: AnnIndex           # AnnIndex over hidden states
    values: jax.Array         # (N,) int32 next-token per datastore entry
    vocab_size: int

    @property
    def graph(self):
        """The index's PaddedCSR (back-compat accessor)."""
        return self.index.graph


def build_datastore(model, params, token_batches, vocab_size: int,
                    degree: int = 16, metric: str = "l2") -> KNNLMDatastore:
    """Run the model over batches, collect (hidden, next-token) pairs."""
    keys, vals = [], []
    hidden_fn = jax.jit(lambda p, t: _final_hidden(model, p, t))
    for tokens in token_batches:
        h = hidden_fn(params, tokens)              # (B, S, d)
        b, s, d = h.shape
        keys.append(np.asarray(h[:, :-1].reshape(-1, d), np.float32))
        vals.append(np.asarray(tokens[:, 1:].reshape(-1), np.int32))
    keys = np.concatenate(keys)
    vals = np.concatenate(vals)
    index = AnnIndex.build(keys, IndexSpec(
        builder="nsg", metric=metric, degree=degree, knn_k=degree,
        ef_construction=2 * degree, passes=1))
    return KNNLMDatastore(index=index, values=jnp.asarray(vals),
                          vocab_size=vocab_size)


def _final_hidden(model, params, tokens):
    """Final pre-logits hidden states (works for CausalLM/MambaLM)."""
    from repro.models.common import rmsnorm
    cfg = model.cfg
    x = params["embedding"][tokens].astype(jnp.bfloat16)
    if hasattr(model, "_rope"):   # CausalLM
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        rope = model._rope(positions)

        def body(carry, lp):
            h, _ = carry
            h2, _, _ = model._layer_apply(lp, h, rope, "train", None, None)
            return (h2, jnp.float32(0)), None
        (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                                 params["layers"])
        return rmsnorm(params["final_norm"], x, cfg.norm_eps)
    raise NotImplementedError(type(model))


def knnlm_logits(
    ds: KNNLMDatastore, hidden: jax.Array, lm_logits: jax.Array,
    cfg: Union[SearchConfig, SearchParams], lam: float = 0.25,
    tau: float = 10.0,
) -> Tuple[jax.Array, jax.Array]:
    """Interpolate LM logits with Speed-ANN retrieval through the facade.

    hidden (B, d); lm_logits (B, V); ``cfg`` is a ``SearchParams`` (or a
    legacy ``SearchConfig``, whose per-query fields are lifted onto one).
    Returns (mixed log-probs (B, V), retrieved ids (B, k)).
    """
    if isinstance(cfg, SearchConfig):
        cfg = SearchParams.from_search_config(cfg)
    ids, dists, _ = ds.index.search(hidden.astype(jnp.float32), cfg)
    n = ds.graph.n_nodes
    safe = jnp.minimum(ids, n - 1)
    toks = ds.values[safe]                               # (B, k)
    valid = ids < n
    w = jnp.where(valid, jax.nn.softmax(
        jnp.where(valid, -dists / tau, -jnp.inf), axis=-1), 0.0)
    p_knn = jax.vmap(
        lambda t, ww: jnp.zeros((ds.vocab_size,), jnp.float32)
        .at[t].add(ww))(toks, w)
    p_lm = jax.nn.softmax(lm_logits.astype(jnp.float32), axis=-1)
    mixed = lam * p_knn + (1.0 - lam) * p_lm
    return jnp.log(jnp.maximum(mixed, 1e-20)), ids
