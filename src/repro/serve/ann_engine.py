"""Batched ANN serving engine: bucketed shapes + jit-cache reuse + sharding.

Online vector-search traffic arrives as variable-size query batches, but jit
compiles one executable per input shape — naive serving recompiles on every
new batch size.  The engine quantizes incoming batches to a fixed ladder of
*buckets* (powers of two by default), pads the batch up to the bucket, and
reuses one compiled searcher per bucket, so steady-state traffic runs with a
bounded, warmed jit cache no matter how sizes fluctuate.  Batches larger than
the top bucket are served in top-bucket chunks.

The searcher itself is the full Speed-ANN stack (staged parallel expansion,
adaptive synchronization, bounded step budgets) with the distance backend
resolved once from ``SearchConfig.dist_backend`` — kernel selection is a
config knob, not a code path.  Each bucket's compiled executable is ONE
batch-major traversal program (``core.bfis``/``core.speedann``): the whole
padded batch advances through a single while_loop with one distance-kernel
launch per global step, instead of B vmapped per-query lanes — so the
bucket ladder directly trades padding waste against per-step launch
amortization.

The engine is a stage of the ``repro.ann`` facade lifecycle: pass an
:class:`repro.ann.AnnIndex` + :class:`repro.ann.SearchParams` (or call
``index.serve(params)``) and the engine serves through the index's own
cached searchers — inheriting the metric handling (query normalization for
cosine), neighbor-grouping id remap, quantized distance backends
(``backend="ref_int8" | "rowgather_int8" | "ref_bf16"`` on an index built
with ``IndexSpec(quant=...)``), and the two-stage re-ranked search
(``SearchParams.rerank_k``).  The legacy ``(PaddedCSR, SearchConfig)`` form
keeps working.

Three dispatch modes (``engine.mode``), one ``search()`` API:

* ``"single"`` — single-host algorithms (bfis | topm | speedann), the
  default.
* ``"sharded"`` — ``SearchParams(algorithm="sharded")`` on the facade path
  routes every bucket through ``core/distributed.walker_sharded_search``:
  one Speed-ANN walker per device along the mesh's ``model`` axis (the
  paper's intra-query parallelism, cross-device).  Pass ``mesh=`` or get
  the default (1, n_devices) search mesh.
* ``"corpus"`` — construct with a ``core/distributed.ShardedIndex`` (see
  ``build_partitioned_index``) + SearchParams + mesh: each ``model`` device
  searches its own corpus partition and the global top-K is merged.

The async request-coalescing front-end (single queries + deadlines in,
bucketed batches out) lives in :mod:`repro.serve.coalescer`; construct it in
one step with ``index.serve_async(params)``.

Typical use::

    engine = AnnIndex.build(data, spec).serve(params)
    engine.warmup(dim)                  # compile every bucket up front
    res = engine.search(queries)        # (B, d) for any B
    print(engine.stats())               # recall / latency / cache counters
"""
from __future__ import annotations

import time
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.index import (AnnIndex, normalize_queries, remap_result_ids)
from repro.ann.spec import SearchParams
from repro.core.config import SearchConfig
from repro.core.bfis import (DistFn, bfis_search_batch, hnsw_search_batch,
                             resolve_dist_fn, search_topm_batch)
from repro.core.distributed import ShardedIndex, corpus_engine_searcher
from repro.core.metrics import SearchStats, recall_at_k, telemetry_per_lane
from repro.core.speedann import search_speedann_batch
from repro.obs import NULL_OBS, LogHistogram, Observability, device_annotation

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64)

#: Relative error of every latency percentile the engine reports: latency
#: samples land in a bounded log-bucketed sketch (``repro.obs.LogHistogram``)
#: instead of an unbounded list, so ``p50/p90/p95/p99`` are exact to within
#: ±1% while ``mean``/``max`` stay exact.  See docs/observability.md.
LATENCY_REL_ERR = 0.01

_ALGORITHMS = {
    "speedann": search_speedann_batch,
    "topm": search_topm_batch,
    "bfis": bfis_search_batch,
}


class ServeResult(NamedTuple):
    """One served request: results sliced back to the request's true size."""
    ids: np.ndarray          # (B, k) int32
    dists: np.ndarray        # (B, k) float32
    stats: SearchStats       # per-query counters, leaves shaped (B,)
    latency_ms: float        # wall clock for this request (all chunks)
    buckets: Tuple[int, ...]  # bucket(s) the request was quantized to


def _mesh_data_size(mesh) -> int:
    """Size of the mesh's query-sharding axis (1 when absent)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get("data", 1))


class AnnEngine:
    """Bucketed, jit-cached batched ANN serving on a fixed index."""

    def __init__(
        self,
        graph,
        cfg: SearchConfig,
        *,
        algorithm: Optional[str] = None,
        bucket_sizes: Sequence[int] = DEFAULT_BUCKETS,
        dist_fn: Optional[DistFn] = None,
        mesh=None,
        metric: Optional[str] = None,
        obs: Optional[Observability] = None,
    ):
        self.obs = obs if obs is not None else NULL_OBS
        self.index: Optional[AnnIndex] = None
        self.mesh = mesh
        self.mode = "single"
        self._normalize = False
        self._old_from_new = None
        self._corpus_fn = None

        if isinstance(graph, ShardedIndex):
            # corpus-sharded mode: one partition per device on the mesh's
            # model axis, global top-K merge across shards
            if not isinstance(cfg, SearchParams):
                raise ValueError(
                    "corpus-sharded serving takes SearchParams (the "
                    "ShardedIndex has no legacy SearchConfig path)")
            if mesh is None:
                raise ValueError(
                    "corpus-sharded serving needs an explicit mesh whose "
                    "'model' axis size equals index.num_shards "
                    "(see core.distributed.make_search_mesh)")
            if algorithm not in (None, "sharded"):
                raise ValueError(
                    "a ShardedIndex serves only the sharded dispatch; drop "
                    f"algorithm={algorithm!r}")
            self.mode = "corpus"
            self.params = cfg
            self.algorithm = "sharded"
            self.cfg = cfg.to_search_config(metric or "l2")
            self.graph = graph
            self._corpus_fn = corpus_engine_searcher(
                graph, cfg, mesh, metric=metric or "l2")
            self._finish_init(bucket_sizes)
            return

        if isinstance(graph, AnnIndex):
            self.index = graph
            graph = self.index.graph
            self._normalize = self.index.spec.metric == "cosine"
            self._old_from_new = self.index.old_from_new
        metric = self.index.spec.metric if self.index is not None else metric
        self.params: Optional[SearchParams] = None
        if isinstance(cfg, SearchParams):
            if algorithm is None:
                algorithm = cfg.algorithm
            if self.index is not None and dist_fn is None:
                # facade path: serve through the index's own searchers, so
                # the engine inherits everything the facade wires — metric
                # normalization, grouping remap, quantized distance
                # backends, and the two-stage re-ranked search (rerank_k)
                self.params = cfg.with_(algorithm=algorithm)
            elif cfg.rerank_k > 0:
                # the two-stage re-rank lives in the facade searcher;
                # silently serving single-stage results would hand the
                # caller lower recall than the identical params via
                # AnnIndex.search
                raise ValueError(
                    "rerank_k needs the facade serving path: construct the "
                    "engine as AnnEngine(AnnIndex, SearchParams) / "
                    "index.serve(params) without a custom dist_fn")
            cfg = cfg.to_search_config(metric or "l2")
        elif metric is not None and cfg.metric != metric:
            # the index's metric is authoritative over a hand-built config
            cfg = cfg.with_(metric=metric)
        if algorithm is None:
            algorithm = "speedann"
        if algorithm == "sharded":
            if self.params is None:
                raise ValueError(
                    "the legacy (graph, SearchConfig) engine serves the "
                    f"single-host algorithms {tuple(_ALGORITHMS)}; the "
                    "shard_map walker path serves through the facade — "
                    "index.serve(SearchParams(algorithm='sharded'), "
                    "mesh=...)")
            # walker-sharded mode: every bucket dispatches through the
            # facade's sharded searcher (core/distributed.py shard_map)
            self.mode = "sharded"
        elif algorithm not in _ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {algorithm!r}; one of "
                f"{tuple(_ALGORITHMS)}")
        self.graph = graph
        self.cfg = cfg
        self.algorithm = algorithm
        self._dist_fn = self._search = None
        if self.params is None:
            # legacy pipeline only — the facade path serves through
            # index.searcher and never touches these
            self._dist_fn = resolve_dist_fn(cfg, dist_fn)
            self._search = _ALGORITHMS[algorithm]
            if (algorithm == "bfis" and self.index is not None
                    and self.index.hnsw is not None):
                # match AnnIndex.search: bfis on an hnsw-built index enters
                # via the greedy upper-level descent, not the base medoid
                hnsw = self.index.hnsw

                def _hnsw_bfis(g, q, c, dist_fn=None):
                    return hnsw_search_batch(hnsw._replace(base=g), q, c,
                                             dist_fn=dist_fn)
                self._search = _hnsw_bfis
        # device-resident remap table, uploaded ONCE per engine (it enters
        # every bucket's executable as a jit argument, like the graph)
        self._ofn = (jnp.asarray(self._old_from_new, jnp.int32)
                     if self._old_from_new is not None
                     else jnp.zeros((0,), jnp.int32))
        self._finish_init(bucket_sizes)

    def _finish_init(self, bucket_sizes: Sequence[int]):
        if not bucket_sizes:
            raise ValueError("bucket_sizes must be non-empty")
        self.bucket_sizes = tuple(sorted(set(int(b) for b in bucket_sizes)))
        if self.mode in ("sharded", "corpus"):
            # sharded dispatch splits the padded batch over the mesh's
            # data axis, so every bucket (every compiled shape) must divide
            data = _mesh_data_size(self.mesh)
            bad = [b for b in self.bucket_sizes if b % max(data, 1)]
            if bad:
                raise ValueError(
                    f"bucket sizes {bad} are not divisible by the mesh's "
                    f"data axis ({data}); sharded serving pads every batch "
                    "to a bucket, so each bucket must split evenly over "
                    "the query-sharding axis")
        self._jit_cache: Dict[int, object] = {}
        # serving counters
        self.queries_served = 0
        self.requests_served = 0
        self.padded_queries = 0
        self.cache_hits = 0
        self.cache_misses = 0
        # latency distributions live in bounded log-bucketed sketches (one
        # global, one per bucket): constant memory under sustained traffic,
        # mergeable across replicas, percentiles within LATENCY_REL_ERR
        self._latency_hist = LogHistogram(rel_err=LATENCY_REL_ERR)
        # per-chunk latency keyed by the bucket it ran in — how the
        # coalescing policy's batch-size choices show up in the tail
        self._bucket_hists: Dict[int, LogHistogram] = {}
        # convergence-telemetry label: which distance kernel served this
        # engine (per-backend registry histograms key on it)
        self._backend_label = str(
            getattr(self.cfg, "dist_backend", None) or "ref")
        self._recall_sum = 0.0
        self._recall_n = 0
        # traversal work totals over served (non-padding) lanes; the
        # uniq/dup split is SearchStats' first-toucher attribution — the
        # dup share is the gather traffic a dedup_gather backend saves
        self.dist_comps_total = 0
        self.uniq_comps_total = 0
        self.batch_dup_comps_total = 0

    # -- jit cache ---------------------------------------------------------

    @property
    def jit_cache_size(self) -> int:
        """Number of compiled entries — bounded by ``len(bucket_sizes)``."""
        return len(self._jit_cache)

    def _compiled(self, bucket: int):
        fn = self._jit_cache.get(bucket)
        if fn is None:
            self.cache_misses += 1
            if self.mode == "corpus":
                # one shard_map searcher; its inner jax.jit keys on the
                # padded batch shape, so cache accounting stays exact
                fn = self._corpus_fn
                self._jit_cache[bucket] = fn
                return fn
            if self.params is not None:
                # every bucket shares the index's ONE cached searcher (in
                # sharded mode the mesh rides along as part of the
                # searcher-cache key); its inner jax.jit keys on the padded
                # batch shape, so cache accounting per bucket stays exact
                fn = self.index.searcher(self.params, mesh=self.mesh)
                self._jit_cache[bucket] = fn
                return fn
            # the graph's arrays enter as jit ARGUMENTS, not closure
            # constants, so every bucket's executable shares the one
            # device-resident embedding table instead of baking its own copy
            search, cfg, dist_fn = self._search, self.cfg, self._dist_fn
            n_top, graph_cls = self.graph.n_top, type(self.graph)
            normalize = self._normalize
            has_remap = self._old_from_new is not None
            n_nodes = self.graph.n_nodes

            @jax.jit
            def jitted(nbrs, vectors, medoid, flat, codes, scales, ofn_arr,
                       q):
                g = graph_cls(nbrs=nbrs, vectors=vectors, medoid=medoid,
                              n_top=n_top, flat=flat, codes=codes,
                              scales=scales)
                q = q.astype(jnp.float32)
                if normalize:
                    q = normalize_queries(q)
                ids, dists, stats = search(g, q, cfg, dist_fn=dist_fn)
                if has_remap:
                    ids = remap_result_ids(ids, ofn_arr, n_nodes)
                return ids, dists, stats

            def fn(q, _j=jitted):
                gr = self.graph
                return _j(gr.nbrs, gr.vectors, gr.medoid, gr.flat,
                          gr.codes, gr.scales, self._ofn, q)
            self._jit_cache[bucket] = fn
        else:
            self.cache_hits += 1
        return fn

    def bucket_for(self, batch: int) -> int:
        """Smallest bucket >= batch (top bucket for oversize chunks)."""
        for b in self.bucket_sizes:
            if b >= batch:
                return b
        return self.bucket_sizes[-1]

    def warmup(self, dim: Optional[int] = None) -> Dict[int, float]:
        """Compile every bucket up front; returns per-bucket compile seconds.

        Warmup does not touch the serving counters, so post-warmup metrics
        reflect real traffic only.
        """
        dim = dim if dim is not None else self.graph.dim
        hits, misses = self.cache_hits, self.cache_misses
        out = {}
        for b in self.bucket_sizes:
            q = jnp.zeros((b, dim), jnp.float32)
            t0 = time.perf_counter()
            jax.block_until_ready(self._compiled(b)(q)[0])
            out[b] = time.perf_counter() - t0
        self.cache_hits, self.cache_misses = hits, misses
        self._bucket_hists = {}
        return out

    # -- serving -----------------------------------------------------------

    def _run_chunk(self, queries: jax.Array, record: bool
                   ) -> Tuple[tuple, int]:
        """Pad one chunk (chunk size <= top bucket) to its bucket and run.

        With ``record`` the chunk is synced (block_until_ready) and its wall
        time lands in the per-bucket latency distribution.  Multi-chunk
        requests pass ``record=False``: blocking between chunks would
        serialize their dispatch, so they stay pipelined and contribute to
        the request-level distribution only.
        """
        b = queries.shape[0]
        bucket = self.bucket_for(b)
        pad = bucket - b
        if pad:
            # pad with replicas of the first query: real topology, no risk
            # of a degenerate all-zeros search dominating the vmapped loop
            queries = jnp.concatenate(
                [queries, jnp.broadcast_to(queries[:1],
                                           (pad, queries.shape[1]))])
            self.padded_queries += pad
        obs = self.obs
        rerank_k = self.params.rerank_k if self.params is not None else 0
        # the rerank pass (params.rerank_k > 0) runs INSIDE this compiled
        # program, so it is part of the device_compute span, not a separate
        # host span — the span args record it for the trace reader
        with obs.tracer.span("device_compute", cat="engine",
                             args={"bucket": bucket, "pad": pad,
                                   "rerank_k": rerank_k}):
            with device_annotation(
                    f"ann_dispatch/bucket{bucket}", enabled=obs.profile):
                t0 = time.perf_counter()
                ids, dists, stats = self._compiled(bucket)(queries)
                if record:
                    jax.block_until_ready(ids)
                    hist = self._bucket_hists.get(bucket)
                    if hist is None:
                        hist = self._bucket_hists.setdefault(
                            bucket, LogHistogram(rel_err=LATENCY_REL_ERR))
                    hist.observe((time.perf_counter() - t0) * 1e3)
        out = (ids[:b], dists[:b],
               jax.tree.map(lambda t: t[:b], stats))
        return out, bucket

    def search(self, queries, gt_ids: Optional[np.ndarray] = None
               ) -> ServeResult:
        """Serve one request of (B, d) queries, any B >= 1.

        With ``gt_ids`` (B, >=k) the engine also folds recall@k into its
        running quality counters.
        """
        queries = jnp.asarray(queries)
        if queries.ndim != 2 or queries.shape[0] == 0:
            raise ValueError(
                f"queries must be (B, d) with B >= 1, got {queries.shape}")
        bsz = queries.shape[0]
        top = self.bucket_sizes[-1]
        obs = self.obs

        with obs.tracer.span("engine.search", cat="engine",
                             args={"batch": bsz}) as sp:
            t0 = time.perf_counter()
            chunks, buckets = [], []
            single_chunk = bsz <= top
            for lo in range(0, bsz, top):
                out, bucket = self._run_chunk(queries[lo:lo + top],
                                              record=single_chunk)
                chunks.append(out)
                buckets.append(bucket)
            if not single_chunk:
                jax.block_until_ready(chunks[-1][0])
            ms = (time.perf_counter() - t0) * 1e3
            sp.add_args(buckets=list(buckets), latency_ms=round(ms, 3))

            with obs.tracer.span("postprocess", cat="engine"):
                if len(chunks) == 1:
                    ids, dists, stats = chunks[0]
                else:
                    ids = jnp.concatenate([c[0] for c in chunks])
                    dists = jnp.concatenate([c[1] for c in chunks])
                    stats = jax.tree.map(
                        lambda *xs: jnp.concatenate(xs), *[c[2] for c in chunks])

                self.queries_served += bsz
                self.requests_served += 1
                self._latency_hist.observe(ms)
                self.dist_comps_total += int(
                    np.sum(np.asarray(stats.dist_comps)))
                self.uniq_comps_total += int(
                    np.sum(np.asarray(stats.uniq_comps)))
                self.batch_dup_comps_total += int(
                    np.sum(np.asarray(stats.batch_dup_comps)))
                if obs.metrics:
                    self._record_telemetry(stats, buckets, ms)
                ids_np = np.asarray(ids)
                if gt_ids is not None:
                    self._recall_sum += (
                        recall_at_k(ids_np, gt_ids, self.cfg.k) * bsz)
                    self._recall_n += bsz
        return ServeResult(ids_np, np.asarray(dists), stats, ms,
                           tuple(buckets))

    # -- observability -----------------------------------------------------

    def _record_telemetry(self, stats: SearchStats, buckets: Sequence[int],
                          request_ms: float) -> None:
        """Convergence telemetry: per-lane ``SearchStats`` leaves into
        registry histograms, labelled ``{backend, bucket}`` — the
        distribution view (steps-to-converge, dup ratios) that totals
        cannot give.  Only called when ``obs.metrics`` is on."""
        reg = self.obs.registry
        bucket = str(buckets[0]) if len(buckets) == 1 else "chunked"
        for field, values in telemetry_per_lane(stats).items():
            child = reg.histogram(
                f"ann_{field}",
                f"per-lane SearchStats.{field} over served queries",
            ).labels(backend=self._backend_label, bucket=bucket)
            for v in values:
                child.observe(v)
        reg.histogram(
            "serve_request_latency_ms",
            "engine wall-clock per request (all chunks)",
        ).labels(backend=self._backend_label).observe(request_ms)

    @staticmethod
    def _hist_summary(h: LogHistogram, prefix: str) -> Dict[str, float]:
        """mean/max exact; p50/p90/p95/p99 within ``LATENCY_REL_ERR``."""
        return {
            f"{prefix}mean_ms": h.mean,
            f"{prefix}p50_ms": h.quantile(0.50),
            f"{prefix}p90_ms": h.quantile(0.90),
            f"{prefix}p95_ms": h.quantile(0.95),
            f"{prefix}p99_ms": h.quantile(0.99),
            f"{prefix}max_ms": h.max,
        }

    def stats(self) -> Dict[str, float]:
        """Serving observability: traffic/jit-cache counters AND the
        latency distribution (mean, p50/p90/p95/p99, max) — globally per
        request AND per bucket size (``bucket{b}_*`` keys), so the effect
        of batch coalescing on the tail is visible from the stats alone.
        Per-bucket rows cover single-chunk requests only (oversize chunked
        requests stay pipelined, see ``_run_chunk``).

        Memory is bounded: latency samples land in log-bucketed sketches,
        so percentile keys are bucket-resolved (exact within
        ``LATENCY_REL_ERR`` = ±1%) while ``*_mean_ms``/``*_max_ms`` and
        every counter stay exact.

        Key order is stable and documented (docs/serving.md): global
        counters in the order below, then the global ``latency_*`` block,
        then per-bucket blocks in ascending bucket size
        (``bucket{b}_chunks`` first within each block), then
        ``recall_at_k`` last when ground truth was supplied."""
        out = {
            "queries_served": float(self.queries_served),
            "requests_served": float(self.requests_served),
            "padded_queries": float(self.padded_queries),
            "jit_cache_size": float(self.jit_cache_size),
            "cache_hits": float(self.cache_hits),
            "cache_misses": float(self.cache_misses),
            "dist_comps_total": float(self.dist_comps_total),
            "uniq_comps_total": float(self.uniq_comps_total),
            "batch_dup_comps_total": float(self.batch_dup_comps_total),
            # share of distance computations whose row gather a batch-dedup
            # backend skips (cross-lane frontier overlap of served traffic)
            "batch_dup_ratio": (
                self.batch_dup_comps_total / self.dist_comps_total
                if self.dist_comps_total else 0.0),
        }
        if self._latency_hist.count:
            out.update(self._hist_summary(self._latency_hist, "latency_"))
        for b in sorted(self._bucket_hists):
            bh = self._bucket_hists[b]
            out[f"bucket{b}_chunks"] = float(bh.count)
            out.update(self._hist_summary(bh, f"bucket{b}_"))
        if self._recall_n:
            out["recall_at_k"] = self._recall_sum / self._recall_n
        return out

    def metrics(self) -> Dict[str, float]:
        """Back-compat alias of :meth:`stats`."""
        return self.stats()

    def latency_histograms(self) -> Dict[str, LogHistogram]:
        """The live sketches behind :meth:`stats` — ``"request"`` plus one
        ``"bucket{b}"`` per served bucket.  Merge across replicas with
        ``LogHistogram.merge`` for fleet-wide percentiles."""
        out: Dict[str, LogHistogram] = {"request": self._latency_hist}
        for b in sorted(self._bucket_hists):
            out[f"bucket{b}"] = self._bucket_hists[b]
        return out
