"""Async request coalescing for ANN serving: single queries in, buckets out.

The batched :class:`~repro.serve.ann_engine.AnnEngine` already amortizes jit
compilation across fluctuating *batch* traffic; real online traffic, though,
arrives as SINGLE queries, each with its own latency budget.  This module is
the layer between the two: an async request queue that

* accepts one query at a time (``submit`` returns a
  :class:`concurrent.futures.Future` immediately — callers never block the
  dispatcher),
* coalesces pending requests into batches under a **max-batch / max-wait**
  policy (:class:`CoalescePolicy`): a batch is flushed as soon as
  ``max_batch`` requests are pending OR the oldest pending request has
  waited ``max_wait_ms``, whichever comes first,
* forms batches in **earliest-deadline-first** order and rejects requests
  whose deadline has already expired at dispatch time
  (:class:`DeadlineExceeded` — cheaper than serving an answer nobody is
  waiting for),
* dispatches through the engine's bucketed jit cache
  (``AnnEngine.search``), so a coalesced batch of any size hits an
  already-compiled executable, and
* slices the batched result back into per-request futures.

Coalescing is *transparent*: the per-query lanes of the batched searcher are
independent (vmap), so a query served in a coalesced batch returns results
bit-identical to the same query through ``AnnIndex.search`` — pinned by
``tests/test_coalescer.py``.

Typical use::

    engine = index.serve(params)                 # batched AnnEngine
    with AsyncAnnEngine(engine, CoalescePolicy(max_batch=16,
                                               max_wait_ms=2.0)) as srv:
        futs = [srv.submit(q, deadline_ms=50.0) for q in queries]
        for f in futs:
            res = f.result()                     # AsyncServeResult
            print(res.ids, res.queue_wait_ms, res.batch_size)
    print(srv.stats())                           # coalescing observability

Or in one step from the facade: ``index.serve_async(params, max_batch=16)``.

The serving tier composes here.  ``cache=`` probes a
:class:`~repro.serve.cache.ResultCache` BEFORE anything queues (a hit
resolves the future immediately, for free); ``admission=`` applies
:class:`~repro.serve.admission.AdmissionPolicy` queue-depth watermarks per
priority class (a shed request's future gets
:class:`~repro.serve.admission.AdmissionRejected`); ``submit(...,
priority=...)`` ranks the two classes in batch formation — critical before
throughput, earliest deadline first within each class.  ``clock=`` injects
a virtual clock (with ``start=False`` plus :meth:`due_at`/:meth:`pump`)
so every timing test in ``tests/serving_harness.py`` runs without sleeping.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, NamedTuple, Optional, Union

import numpy as np

from repro.obs import NULL_OBS, LogHistogram, Observability
from repro.serve.admission import (PRIORITIES, AdmissionController,
                                   AdmissionPolicy, AdmissionRejected)
from repro.serve.cache import CachePolicy, ResultCache

__all__ = ["CoalescePolicy", "DeadlineExceeded", "AsyncServeResult",
           "AsyncAnnEngine"]


class CoalescePolicy(NamedTuple):
    """Batch-formation policy: flush on size OR age, whichever first.

    * ``max_batch`` — flush as soon as this many requests are pending.
      Usually set to the engine's top bucket so a full flush hits the
      biggest compiled executable exactly.
    * ``max_wait_ms`` — flush when the OLDEST pending request has waited
      this long, even if the batch is not full.  This bounds the queueing
      delay added by coalescing: a lone request is served at most
      ``max_wait_ms`` after arrival.
    * ``default_deadline_ms`` — deadline applied to requests submitted
      without one (None = no deadline: the request never expires).
    """
    max_batch: int = 32
    max_wait_ms: float = 2.0
    default_deadline_ms: Optional[float] = None


class DeadlineExceeded(Exception):
    """The request's deadline expired before dispatch; its future receives
    this exception instead of a result."""


class AsyncServeResult(NamedTuple):
    """Per-request result, sliced out of the coalesced batch."""
    ids: np.ndarray          # (k,) int32
    dists: np.ndarray        # (k,) float32
    queue_wait_ms: float     # time spent queued before dispatch
    batch_size: float        # true size of the coalesced batch served with
    latency_ms: float        # engine wall clock for the whole batch
    done_t: float            # perf_counter seconds when the result was
    #                          resolved — client-observed latency is
    #                          ``done_t - submit-side perf_counter`` (do NOT
    #                          clock future callbacks: waiters wake BEFORE
    #                          done-callbacks run)


class _Pending(NamedTuple):
    """One queued request.  Sort key = (priority, deadline, seq): critical
    class before throughput class, earliest deadline first within a class,
    FIFO among equal deadlines (seq is the admission counter).  With a
    single traffic class (priority defaults to 0) this is pure EDF."""
    seq: int
    query: np.ndarray        # (d,)
    enqueue_t: float         # clock seconds
    deadline_t: Optional[float]   # absolute clock seconds, or None
    future: Future
    priority: int = 0        # PRIORITIES rank: 0 = critical, 1 = throughput
    cache_key: Optional[bytes] = None   # set when a result cache is attached

    @property
    def sort_key(self):
        d = self.deadline_t if self.deadline_t is not None else float("inf")
        return (self.priority, d, self.seq)


def select_batch(pending: List[_Pending], now: float, max_batch: int
                 ) -> tuple:
    """Pure batch-formation step (unit-testable without threads).

    Splits ``pending`` into (batch, expired, rest): the up-to-``max_batch``
    most urgent live requests in (priority, deadline, arrival) order —
    critical class before throughput, earliest deadline first within a
    class — the requests whose deadline has already passed at ``now``, and
    the remainder (still queued, in arrival order).
    """
    expired = [p for p in pending
               if p.deadline_t is not None and p.deadline_t < now]
    live = sorted((p for p in pending
                   if p.deadline_t is None or p.deadline_t >= now),
                  key=lambda p: p.sort_key)
    batch, rest = live[:max_batch], live[max_batch:]
    rest.sort(key=lambda p: p.seq)
    return batch, expired, rest


class AsyncAnnEngine:
    """Async coalescing front-end over a batched serving engine.

    ``engine`` is anything with ``search(queries (B, d)) -> ServeResult``
    and a ``cfg.k`` — in practice an :class:`~repro.serve.AnnEngine` in any
    of its modes (single-host, walker-sharded, corpus-sharded), so the
    coalescer composes with sharding for free.

    With ``start=False`` no dispatcher thread runs and batches are formed
    only by explicit :meth:`flush` / :meth:`pump` calls — deterministic, for
    tests and for callers that drive their own event loop.

    ``cache`` / ``admission`` accept either a policy (a
    :class:`~repro.serve.cache.CachePolicy` /
    :class:`~repro.serve.admission.AdmissionPolicy`, wrapped here sharing
    this engine's obs and clock) or a ready-made
    :class:`~repro.serve.cache.ResultCache` /
    :class:`~repro.serve.admission.AdmissionController` (e.g. one cache
    shared across several engines).  ``clock`` is any zero-arg callable
    returning seconds; injecting a virtual clock is only deterministic with
    ``start=False`` (the dispatcher thread's condition waits are real time).
    """

    def __init__(self, engine, policy: CoalescePolicy = CoalescePolicy(), *,
                 start: bool = True, obs: Optional[Observability] = None,
                 cache: Optional[Union[CachePolicy, ResultCache]] = None,
                 admission: Optional[Union[AdmissionPolicy,
                                           AdmissionController]] = None,
                 clock=None):
        if policy.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if policy.max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        self.engine = engine
        self.policy = policy
        # the tracing/metrics bundle: explicit obs wins, else inherit the
        # engine's so one handle covers the whole serving stack
        self.obs = obs if obs is not None \
            else getattr(engine, "obs", None) or NULL_OBS
        self._clock = clock if clock is not None else time.perf_counter
        if isinstance(cache, CachePolicy):
            cache = ResultCache(cache, clock=self._clock, obs=self.obs)
        self.cache: Optional[ResultCache] = cache
        if isinstance(admission, AdmissionPolicy):
            admission = AdmissionController(admission, obs=self.obs,
                                            clock=self._clock)
        self.admission: Optional[AdmissionController] = admission
        self._pending: List[_Pending] = []
        self._lock = threading.Condition()
        self._seq = itertools.count()
        self._closed = False
        self._inflight = 0       # flushes past batch pick-up, pre-resolve
        # observability — distributions live in bounded log-bucketed
        # sketches (constant memory under sustained traffic, mergeable)
        self.submitted = 0
        self.served = 0
        self.served_cache = 0
        self.rejected_deadline = 0
        self.rejected_admission = 0
        self.cancelled = 0
        self.batches_dispatched = 0
        self._batch_size_hist = LogHistogram()
        self._queue_wait_hist = LogHistogram()
        self._thread: Optional[threading.Thread] = None
        if start:
            self._thread = threading.Thread(
                target=self._dispatch_loop, name="ann-coalescer", daemon=True)
            self._thread.start()

    # -- client side ---------------------------------------------------------

    def submit(self, query, *, deadline_ms: Optional[float] = None,
               priority: str = "critical") -> Future:
        """Enqueue one query ``(d,)`` (or ``(1, d)``); returns a Future that
        resolves to an :class:`AsyncServeResult` — or raises
        :class:`DeadlineExceeded` if the deadline expires before dispatch,
        or :class:`~repro.serve.admission.AdmissionRejected` if the request
        is shed at admission.

        ``deadline_ms`` is relative to NOW (submission time); it bounds
        QUEUE time, not total time — a request dispatched just inside its
        deadline still runs to completion.  ``priority`` is one of
        ``repro.serve.admission.PRIORITIES``; it selects the admission
        watermark and the request's rank in batch formation.  With a result
        cache attached, a hit resolves the future before any of that — a
        replay is never queued, never shed, and costs no engine work.
        """
        q = np.asarray(query, np.float32)
        if q.ndim == 2 and q.shape[0] == 1:
            q = q[0]
        if q.ndim != 1:
            raise ValueError(
                f"submit takes ONE query (d,); got shape {q.shape} — "
                "for ready-made batches call engine.search directly")
        if priority not in PRIORITIES:
            raise ValueError(
                f"unknown priority {priority!r}; one of {PRIORITIES}")
        if deadline_ms is None:
            deadline_ms = self.policy.default_deadline_ms
        now = self._clock()
        fut: Future = Future()
        key: Optional[bytes] = None
        if self.cache is not None:
            key = self.cache.key_for(q)
            hit = self.cache.lookup(q, key=key, now=now)
            if hit is not None:
                seq = next(self._seq)
                with self._lock:
                    if self._closed:
                        raise RuntimeError("AsyncAnnEngine is closed")
                    self.submitted += 1
                    self.served_cache += 1
                # replay: zero queue time, no batch, no engine latency —
                # counted as served_cache, NOT served (engine batches only)
                self.obs.tracer.async_begin(
                    "request", seq, cat="request",
                    args={"deadline_ms": deadline_ms, "cache": "hit"})
                fut.set_result(AsyncServeResult(
                    ids=hit[0], dists=hit[1], queue_wait_ms=0.0,
                    batch_size=0.0, latency_ms=0.0, done_t=now))
                self.obs.tracer.async_end("request", seq,
                                          args={"outcome": "cache_hit"})
                if self.obs.metrics:
                    self.obs.registry.counter(
                        "coalescer_requests_total",
                        "requests by final outcome",
                    ).inc(1, outcome="cache_hit")
                return fut
        item = _Pending(
            seq=next(self._seq), query=q, enqueue_t=now,
            deadline_t=None if deadline_ms is None
            else now + deadline_ms / 1e3,
            future=fut, priority=PRIORITIES.index(priority), cache_key=key)
        with self._lock:
            if self._closed:
                raise RuntimeError("AsyncAnnEngine is closed")
            self.submitted += 1
            # admission looks at the queue depth under the SAME lock that
            # guards the queue, so the watermark comparison is exact
            if (self.admission is not None
                    and not self.admission.admit(len(self._pending),
                                                 priority)):
                self.rejected_admission += 1
                shed = True
            else:
                shed = False
                self._pending.append(item)
                # async ("b"/"e") request lifeline: opened here INSIDE the
                # lock — before notify_all can wake a dispatcher that would
                # otherwise resolve (async_end) the request first — closed
                # on the dispatcher thread at resolve time.  This is the
                # cross-thread view Perfetto draws above the span stacks.
                self.obs.tracer.async_begin(
                    "request", item.seq, cat="request",
                    args={"deadline_ms": deadline_ms, "priority": priority})
                self._lock.notify_all()
        if shed:
            if self.obs.metrics:
                self.obs.registry.counter(
                    "coalescer_requests_total", "requests by final outcome",
                ).inc(1, outcome="rejected_admission")
            fut.set_exception(AdmissionRejected(
                f"queue depth at {priority!r} watermark "
                f"({self.admission.policy.watermark(priority)}) — request "
                "shed at admission"))
        return fut

    # -- dispatch ------------------------------------------------------------

    def _oldest_age_s(self, now: float) -> float:
        return now - min(p.enqueue_t for p in self._pending)

    def _dispatch_loop(self):
        self.obs.tracer.name_thread("coalescer-dispatch")
        max_wait_s = self.policy.max_wait_ms / 1e3
        while True:
            with self._lock:
                while not self._pending and not self._closed:
                    self._lock.wait()
                if self._closed and not self._pending:
                    return
                # flush when full, else sleep out the oldest request's
                # remaining wait budget (new arrivals re-notify)
                now = self._clock()
                if (len(self._pending) < self.policy.max_batch
                        and self._oldest_age_s(now) < max_wait_s
                        and not self._closed):
                    self._lock.wait(max_wait_s - self._oldest_age_s(now))
                    continue
            self._flush_once()

    def flush(self) -> int:
        """Synchronously dispatch pending requests (one batch per call
        until the queue is empty); returns the number of requests resolved.
        The deterministic path for ``start=False`` engines and tests."""
        n = 0
        while True:
            served = self._flush_once()
            if served == 0:
                return n
            n += served

    def _due_locked(self, now: float) -> bool:
        """True when the policy calls for a flush at ``now`` (lock held):
        the queue is full, the oldest request has aged out its wait budget,
        or the engine is closing — EXACTLY the dispatcher thread's wake
        conditions, so a pump-driven test sees the same batch boundaries a
        live engine would.  (Expired deadlines are shed at the next policy
        flush, not eagerly: a deadline alone never forces a partial batch.)
        """
        if not self._pending:
            return False
        if self._closed or len(self._pending) >= self.policy.max_batch:
            return True
        return self._oldest_age_s(now) >= self.policy.max_wait_ms / 1e3

    def due_at(self) -> Optional[float]:
        """Earliest clock time at which a flush becomes due, or None with
        an empty queue.  Returns ``now`` when one is due already.  This is
        the scheduling signal the deterministic serving harness
        (``tests/serving_harness.py``) advances its virtual clock to —
        batch formation follows the policy exactly, without sleeping."""
        with self._lock:
            now = self._clock()
            if not self._pending:
                return None
            if self._due_locked(now):
                return now
            return (min(p.enqueue_t for p in self._pending)
                    + self.policy.max_wait_ms / 1e3)

    def pump(self, max_batches: Optional[int] = None) -> int:
        """Dispatch batches only while the policy says one is DUE (contrast
        :meth:`flush`, which force-drains).  Returns requests resolved.
        With ``start=False`` and an injected clock this is the event-loop
        step: advance the clock to :meth:`due_at`, then ``pump()``."""
        resolved = 0
        batches = 0
        while max_batches is None or batches < max_batches:
            with self._lock:
                if not self._due_locked(self._clock()):
                    break
            n = self._flush_once()
            if n == 0:      # drained by a concurrent flush
                break
            resolved += n
            batches += 1
        return resolved

    def _flush_once(self) -> int:
        with self._lock:
            if not self._pending:
                return 0
            # committed: from here until the finally, close(drain=True)
            # must wait — the batch leaves _pending BEFORE its futures
            # resolve, so "queue empty" alone does not mean "drained"
            self._inflight += 1
        try:
            return self._flush_committed()
        finally:
            with self._lock:
                self._inflight -= 1
                self._lock.notify_all()

    def _flush_committed(self) -> int:
        tracer = self.obs.tracer
        resolved = 0
        n_shed = n_cancelled = 0
        live: List[_Pending] = []
        with tracer.span("batch_formation", cat="coalescer") as sp:
            with self._lock:
                if not self._pending:
                    return 0   # drained by a concurrent flush
                now = self._clock()
                n_pending = len(self._pending)
                batch, expired, rest = select_batch(
                    self._pending, now, self.policy.max_batch)
                self._pending = rest
            # the EDF decision, as the trace records it: who was picked, in
            # what order, who was shed, who stays queued
            sp.add_args(pending=n_pending, batch=len(batch),
                        shed=len(expired), deferred=len(rest),
                        edf_order=[p.seq for p in batch])
            # set_running_or_notify_cancel guards every resolution: a future
            # the CLIENT cancelled while it was queued must be dropped, not
            # written to — set_result on a cancelled future raises
            # InvalidStateError, which would kill the dispatcher thread and
            # hang every later caller
            for p in expired:
                resolved += 1
                if p.future.set_running_or_notify_cancel():
                    with self._lock:
                        self.rejected_deadline += 1
                    n_shed += 1
                    late_ms = 1e3 * (now - p.deadline_t)
                    sp.event("deadline_shed",
                             {"req": p.seq, "late_ms": round(late_ms, 3)})
                    tracer.async_end("request", p.seq,
                                     args={"outcome": "shed"})
                    p.future.set_exception(DeadlineExceeded(
                        f"deadline expired {late_ms:.2f} ms before dispatch"))
                else:
                    with self._lock:
                        self.cancelled += 1
                    n_cancelled += 1
                    tracer.async_end("request", p.seq,
                                     args={"outcome": "cancelled"})
            for p in batch:
                if p.future.set_running_or_notify_cancel():
                    live.append(p)   # now RUNNING: cancel() can no longer win
                else:
                    resolved += 1
                    with self._lock:
                        self.cancelled += 1
                    n_cancelled += 1
                    tracer.async_end("request", p.seq,
                                     args={"outcome": "cancelled"})
        if self.obs.metrics and (n_shed or n_cancelled):
            out = self.obs.registry.counter(
                "coalescer_requests_total", "requests by final outcome")
            if n_shed:
                out.inc(n_shed, outcome="shed")
            if n_cancelled:
                out.inc(n_cancelled, outcome="cancelled")
        if not live:
            return resolved
        queries = np.stack([p.query for p in live])
        # engine.search runs inside this span on the same thread, so its
        # engine.search/device_compute spans nest under dispatch by
        # containment
        with tracer.span("dispatch", cat="coalescer",
                         args={"batch": len(live)}):
            try:
                res = self.engine.search(queries)
            except Exception as e:  # noqa: BLE001 - failure goes to callers
                for p in live:
                    tracer.async_end("request", p.seq,
                                     args={"outcome": "error"})
                    p.future.set_exception(e)
                return resolved + len(live)
        done_t = self._clock()
        with tracer.span("resolve", cat="coalescer",
                         args={"batch": len(live)}):
            with self._lock:
                self.batches_dispatched += 1
                self._batch_size_hist.observe(len(live))
                self.served += len(live)
                waits = [(now - p.enqueue_t) * 1e3 for p in live]
                for w in waits:
                    self._queue_wait_hist.observe(w)
            if self.obs.metrics:
                reg = self.obs.registry
                reg.counter("coalescer_requests_total",
                            "requests by final outcome"
                            ).inc(len(live), outcome="served")
                qw = reg.histogram("coalescer_queue_wait_ms",
                                   "queue time before dispatch")
                for w in waits:
                    qw.observe(w)
                reg.histogram("coalescer_batch_size",
                              "true size of dispatched batches"
                              ).observe(len(live))
            for i, p in enumerate(live):
                if self.cache is not None and p.cache_key is not None:
                    # populate BEFORE resolving so a client that re-submits
                    # the moment its future completes already hits
                    self.cache.insert(p.query, res.ids[i], res.dists[i],
                                      key=p.cache_key, now=done_t)
                p.future.set_result(AsyncServeResult(
                    ids=res.ids[i], dists=res.dists[i],
                    queue_wait_ms=waits[i], batch_size=float(len(live)),
                    latency_ms=res.latency_ms, done_t=done_t))
                tracer.async_end(
                    "request", p.seq,
                    args={"outcome": "served",
                          "queue_wait_ms": round(waits[i], 3)})
        return resolved + len(live)

    # -- lifecycle -----------------------------------------------------------

    def close(self, drain: bool = True):
        """Stop accepting requests; by default drain the queue first.  With
        ``drain=False`` still-queued futures are cancelled.

        Draining waits for IN-FLIGHT batches too: a flush that has popped
        its batch but not yet resolved the futures leaves the queue empty
        while work is outstanding, so close loops (flush + wait) until the
        queue is empty AND no flush is mid-dispatch — only then is every
        accepted future settled (the drain-under-load regression test in
        ``tests/test_serve_tier.py`` pins this)."""
        with self._lock:
            self._closed = True
            if not drain:
                for p in self._pending:
                    p.future.cancel()
                self._pending = []
            self._lock.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        if drain:
            while True:
                self.flush()
                with self._lock:
                    if not self._pending and not self._inflight:
                        return
                    if self._inflight:
                        # the 1 s timeout only guards a lost wakeup; the
                        # finally-block notify fires as each flush lands
                        self._lock.wait(timeout=1.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Coalescing-level counters + queue-wait distribution.  The wrapped
        engine's own ``stats()`` (per-bucket latency percentiles, jit-cache
        counters) stays separate under ``self.engine.stats()``.

        Distributions come from bounded log-bucketed sketches
        (``repro.obs.LogHistogram``): memory is constant under sustained
        traffic; ``*_mean``/``*_max`` are exact, percentile keys are
        bucket-resolved within ±1% (see docs/observability.md)."""
        with self._lock:
            out = {
                "submitted": float(self.submitted),
                "served": float(self.served),
                "served_cache": float(self.served_cache),
                "rejected_deadline": float(self.rejected_deadline),
                "rejected_admission": float(self.rejected_admission),
                "cancelled": float(self.cancelled),
                "pending": float(len(self._pending)),
                "batches_dispatched": float(self.batches_dispatched),
            }
        if self._batch_size_hist.count:
            out.update(batch_size_mean=self._batch_size_hist.mean,
                       batch_size_max=self._batch_size_hist.max)
        qw = self._queue_wait_hist
        if qw.count:
            out.update(
                queue_wait_mean_ms=qw.mean,
                queue_wait_p50_ms=qw.quantile(0.50),
                queue_wait_p95_ms=qw.quantile(0.95),
                queue_wait_p99_ms=qw.quantile(0.99),
            )
        return out
