"""Replica routing: spread queries over N engines, hedge the stragglers.

One engine is one host's worth of serving.  Millions of users need N of
them, and the tier that picks which replica answers which request decides
the fleet's tail latency.  Two layouts, one ``search()`` API:

* ``mode="replicated"`` (data-parallel) — every replica serves the FULL
  index (e.g. ``[index.serve(params) for _ in range(n)]``).  Each request
  is routed to ONE replica chosen from per-replica latency sketches
  (``repro.obs.LogHistogram`` — the same bounded ±1% sketches the engines
  keep) and health state; results are bit-identical to a single engine
  because every replica runs the same compiled search.
* ``mode="sharded"`` (corpus-parallel) — each replica serves one corpus
  shard; a request fans out to ALL shards and the per-shard top-k lists
  merge into a global top-k (deterministic: distance then id order).
  ``shard_offsets`` maps shard-local result ids back to global ids.

**Hedged retry** (replicated mode): when the chosen replica has not
answered within ``hedge_after_ms`` — the deadline-risk signal — the same
request is dispatched to the next-best replica and the FIRST successful
answer wins.  The duplicate answer is deduplicated: the request resolves
exactly once, the loser's (still useful) latency sample is recorded when
it lands, and ``hedge_discarded`` counts the redundant work.  A replica
that fails fast fails over to the hedge immediately.

**Health**: ``max_failures`` consecutive errors mark a replica unhealthy
for ``cooldown_s`` (clock-injectable); unhealthy replicas are skipped by
selection until the cooldown lapses, then re-probed.  With every replica
unhealthy the router degrades to best-effort (least-recently-failed).

The router quacks like an engine (``search(queries)`` returning an object
with ``ids`` / ``dists`` / ``latency_ms``), so the coalescer composes with
it unchanged: ``AsyncAnnEngine(ReplicaRouter([...]), policy)`` gives
coalescing + admission + caching over a replica fleet.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from concurrent.futures import wait as futures_wait
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.obs import NULL_OBS, LogHistogram, Observability

__all__ = ["RouterPolicy", "RouterResult", "ReplicaRouter"]

ROUTER_MODES = ("replicated", "sharded")
STRATEGIES = ("latency", "round_robin")


class RouterPolicy(NamedTuple):
    """Routing configuration.

    * ``strategy`` — ``"latency"`` picks the healthy replica with the
      lowest sketched p50 (cold replicas score 0, so they get probed
      first); ``"round_robin"`` rotates over healthy replicas.
    * ``hedge_after_ms`` — deadline-risk threshold: if the primary has not
      answered in this long, dispatch a hedge to the next-best replica
      (None disables hedging).
    * ``max_failures`` — consecutive errors before a replica is marked
      unhealthy.
    * ``cooldown_s`` — how long an unhealthy replica is skipped before
      being re-probed.
    """
    strategy: str = "latency"
    hedge_after_ms: Optional[float] = None
    max_failures: int = 3
    cooldown_s: float = 5.0


class RouterResult(NamedTuple):
    """One routed request (engine-shaped: the coalescer slices ids/dists)."""
    ids: np.ndarray          # (B, k) int32
    dists: np.ndarray        # (B, k) float32
    latency_ms: float        # router wall clock (incl. hedge wait)
    replica: int             # replica that produced the answer (-1: merged)
    hedged: bool             # True if a hedge request was dispatched


class _ReplicaState:
    """Per-replica serving state: latency sketch + health + counters."""

    __slots__ = ("engine", "sketch", "served", "errors",
                 "consecutive_failures", "unhealthy_until", "last_failure_t")

    def __init__(self, engine):
        self.engine = engine
        self.sketch = LogHistogram()
        self.served = 0
        self.errors = 0
        self.consecutive_failures = 0
        self.unhealthy_until = -float("inf")
        self.last_failure_t = -float("inf")

    def healthy(self, now: float) -> bool:
        return now >= self.unhealthy_until

    def score(self) -> float:
        """Routing score (lower = better): sketched p50 latency; a replica
        with no samples yet scores 0 so it gets probed first."""
        return self.sketch.quantile(0.5) if self.sketch.count else 0.0


def merge_topk(ids: np.ndarray, dists: np.ndarray, k: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Merge concatenated per-shard candidate lists into a global top-k.

    ids/dists: (B, S*k).  Deterministic: ascending distance, ties broken
    on id — the same order ``exact_rerank`` uses, so shard layout never
    changes result order.
    """
    order = np.lexsort((ids, dists), axis=-1)
    ids = np.take_along_axis(ids, order, axis=-1)[:, :k]
    dists = np.take_along_axis(dists, order, axis=-1)[:, :k]
    return ids, dists


class ReplicaRouter:
    """Latency/health-aware routing over N engine replicas or shards."""

    def __init__(self, replicas: Sequence, *,
                 policy: RouterPolicy = RouterPolicy(),
                 mode: str = "replicated",
                 shard_offsets: Optional[Sequence[int]] = None,
                 obs: Optional[Observability] = None, clock=None):
        if not replicas:
            raise ValueError("need at least one replica")
        if mode not in ROUTER_MODES:
            raise ValueError(
                f"unknown router mode {mode!r}; one of {ROUTER_MODES}")
        if policy.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {policy.strategy!r}; one of {STRATEGIES}")
        if policy.hedge_after_ms is not None and policy.hedge_after_ms < 0:
            raise ValueError("hedge_after_ms must be >= 0")
        if mode == "replicated" and shard_offsets is not None:
            raise ValueError("shard_offsets applies to mode='sharded' only")
        if shard_offsets is not None and len(shard_offsets) != len(replicas):
            raise ValueError("need one shard offset per replica")
        self.mode = mode
        self.policy = policy
        self.obs = obs if obs is not None else NULL_OBS
        self._clock = clock if clock is not None else time.perf_counter
        self._replicas = [_ReplicaState(r) for r in replicas]
        self._shard_offsets = (None if shard_offsets is None
                               else [int(o) for o in shard_offsets])
        self._lock = threading.Lock()
        self._rr = 0                      # round-robin cursor
        self._pool: Optional[ThreadPoolExecutor] = None
        self._outstanding: set = set()    # hedge losers still in flight
        # router-level counters
        self.requests = 0
        self.hedges = 0
        self.hedge_wins = 0
        self.hedge_discarded = 0
        self.failovers = 0

    def __len__(self) -> int:
        return len(self._replicas)

    # -- replica selection ---------------------------------------------------

    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=max(2, 2 * len(self._replicas)),
                    thread_name_prefix="ann-router")
            return self._pool

    def _pick(self, now: float) -> Tuple[int, Optional[int]]:
        """(primary, hedge) replica indices.  Healthy replicas ranked by
        strategy; hedge is the next-best healthy replica (None when only
        one candidate).  All-unhealthy degrades to least-recently-failed."""
        with self._lock:
            healthy = [i for i, r in enumerate(self._replicas)
                       if r.healthy(now)]
            if not healthy:
                healthy = sorted(
                    range(len(self._replicas)),
                    key=lambda i: self._replicas[i].last_failure_t)
            elif self.policy.strategy == "round_robin":
                rot = self._rr % len(healthy)
                healthy = healthy[rot:] + healthy[:rot]
                self._rr += 1
            else:
                healthy.sort(key=lambda i: (self._replicas[i].score(), i))
            primary = healthy[0]
            hedge = healthy[1] if len(healthy) > 1 else None
            return primary, hedge

    # -- per-replica execution (runs on pool threads) -------------------------

    def _run_replica(self, idx: int, queries):
        rep = self._replicas[idx]
        t0 = self._clock()
        try:
            res = rep.engine.search(queries)
        except Exception:
            now = self._clock()
            with self._lock:
                rep.errors += 1
                rep.consecutive_failures += 1
                rep.last_failure_t = now
                if rep.consecutive_failures >= self.policy.max_failures:
                    rep.unhealthy_until = now + self.policy.cooldown_s
            if self.obs.metrics:
                self.obs.registry.counter(
                    "router_requests_total",
                    "routed dispatches by replica and outcome",
                ).inc(1, replica=str(idx), outcome="error")
            raise
        ms = (self._clock() - t0) * 1e3
        with self._lock:
            rep.served += 1
            rep.consecutive_failures = 0
            rep.sketch.observe(ms)
        if self.obs.metrics:
            reg = self.obs.registry
            reg.histogram(
                "router_replica_latency_ms",
                "per-replica engine latency as routed",
            ).labels(replica=str(idx)).observe(ms)
            reg.counter(
                "router_requests_total",
                "routed dispatches by replica and outcome",
            ).inc(1, replica=str(idx), outcome="served")
        return res

    # -- hedging --------------------------------------------------------------

    def _discard_loser(self, fut: Future, idx: int) -> None:
        """Dedup the redundant answer of a hedged pair: count it, drop it.
        The loser's latency/health was already recorded by _run_replica."""
        def _done(f: Future, idx=idx):
            with self._lock:
                self.hedge_discarded += 1
                self._outstanding.discard(f)
            if self.obs.metrics:
                self.obs.registry.counter(
                    "router_hedges_total", "hedge lifecycle events",
                ).inc(1, event="discarded")
            f.exception()        # consume, never propagate to a caller
        with self._lock:
            self._outstanding.add(fut)
        fut.add_done_callback(_done)

    def _race(self, pairs: List[Tuple[int, Future]]):
        """First SUCCESSFUL completion wins; the other future is
        deduplicated via :meth:`_discard_loser`.  Raises the primary's
        error only if every leg fails."""
        pending = {f: i for i, f in pairs}  # future -> replica idx
        errors: List[BaseException] = []
        futs = [f for _, f in pairs]
        while pending:
            done, _ = futures_wait(list(pending), return_when=FIRST_COMPLETED)
            for f in done:
                idx = pending.pop(f)
                err = f.exception()
                if err is None:
                    for loser in pending:
                        self._discard_loser(loser, pending[loser])
                    if f is not futs[0]:
                        with self._lock:
                            self.hedge_wins += 1
                        if self.obs.metrics:
                            self.obs.registry.counter(
                                "router_hedges_total",
                                "hedge lifecycle events",
                            ).inc(1, event="won")
                    return idx, f.result()
                errors.append(err)
        raise errors[0]

    def drain_hedges(self, timeout: Optional[float] = 10.0) -> None:
        """Block until every discarded hedge leg has landed (tests and
        clean shutdown — a live router never needs to call this)."""
        with self._lock:
            outstanding = list(self._outstanding)
        if outstanding:
            futures_wait(outstanding, timeout=timeout)

    # -- serving ---------------------------------------------------------------

    def search(self, queries) -> RouterResult:
        """Route one (B, d) request; returns the winning replica's answer
        (replicated) or the merged global top-k (sharded)."""
        if self.mode == "sharded":
            return self._search_sharded(queries)
        t0 = self._clock()
        self.requests += 1
        primary, hedge = self._pick(t0)
        hedge_s = (None if self.policy.hedge_after_ms is None
                   else self.policy.hedge_after_ms / 1e3)
        if hedge_s is None or hedge is None:
            # no hedging possible: run inline, skip the pool entirely
            res = self._run_replica(primary, queries)
            return RouterResult(np.asarray(res.ids), np.asarray(res.dists),
                                (self._clock() - t0) * 1e3, primary, False)
        pool = self._ensure_pool()
        fut = pool.submit(self._run_replica, primary, queries)
        hedged = False
        try:
            res, winner = fut.result(timeout=hedge_s), primary
        except FuturesTimeout:
            # deadline risk: race the primary against the next-best replica,
            # first successful answer wins, the loser is deduplicated
            hedged = True
            with self._lock:
                self.hedges += 1
            if self.obs.metrics:
                self.obs.registry.counter(
                    "router_hedges_total", "hedge lifecycle events",
                ).inc(1, event="fired")
            hfut = pool.submit(self._run_replica, hedge, queries)
            winner, res = self._race([(primary, fut), (hedge, hfut)])
        except Exception:
            # primary failed fast: fail over to the hedge immediately
            hedged = True
            with self._lock:
                self.failovers += 1
            res, winner = self._run_replica(hedge, queries), hedge
        return RouterResult(np.asarray(res.ids), np.asarray(res.dists),
                            (self._clock() - t0) * 1e3, winner, hedged)

    def _search_sharded(self, queries) -> RouterResult:
        t0 = self._clock()
        self.requests += 1
        n = len(self._replicas)
        if n == 1:
            res = self._run_replica(0, queries)
            ids = np.asarray(res.ids)
            if self._shard_offsets:
                ids = ids + self._shard_offsets[0]
            return RouterResult(ids, np.asarray(res.dists),
                                (self._clock() - t0) * 1e3, -1, False)
        pool = self._ensure_pool()
        futs = [pool.submit(self._run_replica, i, queries) for i in range(n)]
        results = [f.result() for f in futs]     # every shard is required
        k = np.asarray(results[0].ids).shape[1]
        all_ids, all_dists = [], []
        for i, res in enumerate(results):
            ids = np.asarray(res.ids)
            if self._shard_offsets:
                ids = ids + self._shard_offsets[i]
            all_ids.append(ids)
            all_dists.append(np.asarray(res.dists))
        ids, dists = merge_topk(np.concatenate(all_ids, axis=1),
                                np.concatenate(all_dists, axis=1), k)
        return RouterResult(ids, dists, (self._clock() - t0) * 1e3, -1,
                            False)

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        self.drain_hedges()
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- observability ---------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Router counters + per-replica health/latency summaries (sketch
        percentiles within ±1%, counters exact)."""
        now = self._clock()
        with self._lock:
            out: Dict[str, float] = {
                "replicas": float(len(self._replicas)),
                "requests": float(self.requests),
                "hedges": float(self.hedges),
                "hedge_wins": float(self.hedge_wins),
                "hedge_discarded": float(self.hedge_discarded),
                "failovers": float(self.failovers),
            }
            for i, r in enumerate(self._replicas):
                out[f"replica{i}_served"] = float(r.served)
                out[f"replica{i}_errors"] = float(r.errors)
                out[f"replica{i}_healthy"] = float(r.healthy(now))
                if r.sketch.count:
                    out[f"replica{i}_p50_ms"] = r.sketch.quantile(0.5)
                    out[f"replica{i}_p99_ms"] = r.sketch.quantile(0.99)
            return out
