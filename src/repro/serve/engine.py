"""Minimal batched serving engine: prefill + greedy/sampled decode.

Jitted prefill and decode steps with static batch/sequence buckets; the
decode loop runs on-device via ``lax.scan`` when generating many tokens
(one dispatch per sequence, not per token).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


class ServeEngine:
    def __init__(self, model, params, s_max: int = 256):
        self.model = model
        self.params = params
        self.s_max = s_max
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, self.s_max))
        self._decode = jax.jit(model.decode_step)

    def generate(self, tokens: jax.Array, steps: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Tuple[jax.Array, jax.Array]:
        """tokens (B, S) prompt -> (generated (B, steps), last logits)."""
        logits, state = self._prefill(self.params, tokens)
        key = jax.random.PRNGKey(seed)

        def pick(lg, k):
            if temperature <= 0.0:
                return jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                k, lg[:, -1, :].astype(jnp.float32) / temperature, axis=-1
            ).astype(jnp.int32)

        def step(carry, k):
            state, logits = carry
            nxt = pick(logits, k)[:, None]
            logits2, state2 = self._decode(self.params, state, nxt)
            return (state2, logits2), nxt[:, 0]

        (_, last), toks = jax.lax.scan(
            step, (state, logits), jax.random.split(key, steps))
        return jnp.moveaxis(toks, 0, 1), last
