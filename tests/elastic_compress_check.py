"""Subprocess body (8 devices): elastic resharding + int8 grad all-reduce.

1. elastic: a TrainState sharded on a (4,2) mesh restores onto (2,4) and
   onto a single device with bit-identical leaves (checkpoints are
   mesh-agnostic; reshard = device_put against the new shardings).
2. compression: the explicit-DP train step with int8 gradient all-reduce +
   error feedback stays within quantization tolerance of the exact step,
   and its error-feedback residuals carry the quantization remainder.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import dataclasses                     # noqa: E402
import jax                             # noqa: E402
import jax.numpy as jnp                # noqa: E402
import numpy as np                     # noqa: E402

from repro.config import TrainConfig   # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.data.tokens import TokenStream, _batch_at  # noqa: E402
from repro.models import build_model   # noqa: E402
from repro.runtime.elastic import reshard_state  # noqa: E402
from repro.sharding import DEFAULT_RULES, param_shardings, use_rules  # noqa: E402
from repro.train.train_step import (init_train_state,  # noqa: E402
                                    make_compressed_dp_train_step,
                                    make_train_step)


def check_elastic():
    cfg = get_smoke_config("yi-9b")
    model = build_model(cfg)
    tcfg = TrainConfig()
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    host = jax.tree.map(np.asarray, state.params)

    mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh_a = param_shardings(state.params, mesh_a)
    on_a = jax.tree.map(jax.device_put, state.params, sh_a)
    # reshard A -> B
    on_b = reshard_state(on_a, mesh_b)
    for w, h in zip(jax.tree.leaves(on_b), jax.tree.leaves(host)):
        np.testing.assert_array_equal(np.asarray(w), h)
    # reshard B -> single device (shrink)
    single = jax.tree.map(
        lambda x: jax.device_put(np.asarray(x), jax.devices()[0]), on_b)
    for w, h in zip(jax.tree.leaves(single), jax.tree.leaves(host)):
        np.testing.assert_array_equal(np.asarray(w), h)
    print("OK elastic_reshard 4x2 -> 2x4 -> 1dev bit-identical")


def check_compression():
    cfg = get_smoke_config("llama3.2-3b")
    model = build_model(cfg)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=16, batch=16,
                         seed=0, shard=0, num_shards=1)
    batch = jax.tree.map(jnp.asarray, _batch_at(stream, 0))

    tcfg = TrainConfig(grad_compression="int8", learning_rate=1e-3,
                       warmup_steps=1, total_steps=10)
    with use_rules(DEFAULT_RULES, mesh):
        state_c = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        step_c = make_compressed_dp_train_step(model, tcfg, mesh)
        state_e = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        step_e = jax.jit(make_train_step(model, tcfg))

        sc, mc = step_c(state_c, batch)
        se, me = step_e(state_e, batch)
    # loss identical (computed pre-update); params within int8 tolerance
    assert abs(float(mc["loss"]) - float(me["loss"])) < 1e-3
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(sc.params),
                             jax.tree.leaves(se.params))]
    assert max(diffs) < 5e-3, max(diffs)
    # error feedback carries nonzero residuals
    resid = sum(float(jnp.sum(jnp.abs(e)))
                for e in jax.tree.leaves(sc.err))
    assert resid > 0
    print(f"OK int8_compressed_dp maxdiff={max(diffs):.2e} "
          f"loss={float(mc['loss']):.4f}")


def main():
    assert len(jax.devices()) == 8
    check_elastic()
    check_compression()
    print("ALL_ELASTIC_COMPRESS_OK")


if __name__ == "__main__":
    main()
