"""AnnIndex facade lifecycle: build → save/load → search → serve.

The acceptance bar for the unified API:
  * ``save``/``load`` round-trips bit-identically — a loaded index returns
    ids IDENTICAL to the pre-save index for every algorithm;
  * every registered distance backend serves every metric (l2 | ip |
    cosine) with recall@10 >= 0.9 against the metric-aware ``exact_knn``
    and with cross-backend id parity;
  * the serving engine inherits the index's metric handling;
  * the §5.3 ablation variants are distinguishable configurations.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.config import SearchConfig
from repro.core import recall_at_k, variant
from repro.core.build import exact_knn
from repro.data import make_vector_dataset
from repro.kernels import available_backends
from repro.quant import QuantSpec, required_quant_dtype

METRICS = ("l2", "ip", "cosine")
ALGOS = ("bfis", "topm", "speedann")

PARAMS = SearchParams(k=10, queue_len=48, m_max=4, num_walkers=4,
                      max_steps=192, local_steps=4)


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("sift", n=1500, n_queries=12, k=10, dim=24,
                               n_clusters=24, seed=3)


@pytest.fixture(scope="module")
def indices(ds):
    return {m: AnnIndex.build(ds, IndexSpec(metric=m, degree=16, passes=1))
            for m in METRICS}


@pytest.fixture(scope="module")
def gts(ds, indices):
    return {m: indices[m].exact(ds.queries, 10)[0] for m in METRICS}


# -- spec validation ---------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="unknown builder"):
        IndexSpec(builder="faiss")
    with pytest.raises(ValueError, match="unknown metric"):
        IndexSpec(metric="hamming")
    with pytest.raises(ValueError, match="nsg builder only"):
        IndexSpec(builder="hnsw", n_top_fraction=0.1)
    with pytest.raises(ValueError, match="unknown algorithm"):
        SearchParams(algorithm="annoy")


def test_params_split_from_search_config():
    """SearchParams carries per-query knobs; the metric is index-owned."""
    cfg = SearchConfig(k=7, queue_len=32, m_max=3, dist_backend="dma",
                       metric="ip")
    p = SearchParams.from_search_config(cfg, algorithm="topm")
    assert (p.k, p.queue_len, p.m_max, p.backend) == (7, 32, 3, "dma")
    assert "metric" not in {f.name for f in dataclasses.fields(p)}
    # lowering re-attaches the metric from the index's spec
    assert p.to_search_config("cosine").metric == "cosine"


# -- metric-aware exact_knn --------------------------------------------------

def test_exact_knn_metric_semantics(ds):
    """ip = negative inner product; cosine = ip on normalized vectors."""
    q = ds.queries[:4]
    ids_ip, d_ip = exact_knn(ds.base, q, 5, metric="ip")
    brute = -(q @ ds.base.T)
    np.testing.assert_array_equal(ids_ip, np.argsort(brute, axis=1,
                                                     kind="stable")[:, :5])
    np.testing.assert_allclose(d_ip, np.sort(brute, axis=1)[:, :5],
                               rtol=1e-5, atol=1e-5)
    norm = lambda x: x / np.linalg.norm(x, axis=1, keepdims=True)  # noqa: E731
    ids_cos, _ = exact_knn(ds.base, q, 5, metric="cosine")
    ids_cos2, _ = exact_knn(norm(ds.base), norm(q), 5, metric="ip")
    np.testing.assert_array_equal(ids_cos, ids_cos2)


# -- recall + backend parity over the full metric matrix ---------------------

@pytest.mark.parametrize("metric", METRICS)
def test_recall_and_backend_parity(ds, indices, gts, metric):
    """Every registered fp32 backend serves every metric: recall@10 >= 0.9
    against metric-aware exact_knn, and all backends agree on result ids
    (the Pallas kernels retrace the ref search).  Quantized backends read a
    codes table a fp32 index does not have — they get their own matrix
    below."""
    index = indices[metric]
    gt = gts[metric]
    ids_by_backend = {}
    fp32_backends = [b for b in available_backends()
                     if required_quant_dtype(b) == "none"]
    for backend in ("ref",) + tuple(
            b for b in fp32_backends if b != "ref"):
        res = index.search(ds.queries,
                           PARAMS.with_(algorithm="speedann",
                                        backend=backend))
        ids = np.asarray(res.ids)
        r = recall_at_k(ids, gt, 10)
        assert r >= 0.9, f"{metric}/{backend} recall {r}"
        ids_by_backend[backend] = ids
    ref = ids_by_backend.pop("ref")
    for backend, ids in ids_by_backend.items():
        np.testing.assert_array_equal(
            ids, ref, err_msg=f"{metric}/{backend} diverged from ref")


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("algo", ALGOS)
def test_recall_every_algorithm(ds, indices, gts, metric, algo):
    res = indices[metric].search(ds.queries, PARAMS.with_(algorithm=algo))
    r = recall_at_k(np.asarray(res.ids), gts[metric], 10)
    assert r >= 0.9, f"{metric}/{algo} recall {r}"


# -- save/load round-trip ----------------------------------------------------

@pytest.mark.parametrize("metric", ("l2", "cosine"))
def test_save_load_ids_bit_identical(ds, indices, tmp_path, metric):
    index = indices[metric]
    path = index.save(str(tmp_path / f"idx_{metric}"))
    assert path.endswith(".npz")
    loaded = AnnIndex.load(path)
    assert loaded.spec == index.spec
    assert loaded.n_nodes == index.n_nodes and loaded.dim == index.dim
    for algo in ALGOS:
        p = PARAMS.with_(algorithm=algo)
        before = np.asarray(index.search(ds.queries, p).ids)
        after = np.asarray(loaded.search(ds.queries, p).ids)
        np.testing.assert_array_equal(after, before,
                                      err_msg=f"{metric}/{algo}")


def test_save_load_grouped_index_remaps_ids(ds, tmp_path):
    """Neighbor grouping relabels vertices internally; the facade maps ids
    back to the caller's original space and persists the permutation."""
    spec = IndexSpec(metric="l2", degree=16, passes=1, n_top_fraction=0.02)
    index = AnnIndex.build(ds, spec)
    assert index.graph.n_top == max(1, round(0.02 * ds.base.shape[0]))
    gt, _ = index.exact(ds.queries, 10)
    res = index.search(ds.queries, PARAMS.with_(algorithm="topm"))
    ids = np.asarray(res.ids)
    assert recall_at_k(ids, gt, 10) >= 0.9
    # returned ids live in the ORIGINAL id space: distances must match the
    # original vectors exactly
    d = np.asarray(res.dists)
    b, j = 0, 0
    exact = ((ds.base[ids[b, j]] - ds.queries[b]) ** 2).sum()
    assert abs(float(d[b, j]) - float(exact)) < 1e-2 * max(exact, 1.0)
    loaded = AnnIndex.load(index.save(str(tmp_path / "grouped")))
    after = np.asarray(loaded.search(ds.queries,
                                     PARAMS.with_(algorithm="topm")).ids)
    np.testing.assert_array_equal(after, ids)


@pytest.fixture(scope="module")
def hnsw_idx(ds):
    return AnnIndex.build(ds, IndexSpec(builder="hnsw", degree=16))


def test_save_load_hnsw(ds, hnsw_idx, tmp_path):
    gt, _ = hnsw_idx.exact(ds.queries, 10)
    p = PARAMS.with_(algorithm="bfis", max_steps=256)
    before = np.asarray(hnsw_idx.search(ds.queries, p).ids)
    assert recall_at_k(before, gt, 10) >= 0.9
    loaded = AnnIndex.load(hnsw_idx.save(str(tmp_path / "hnsw")))
    assert loaded.hnsw is not None
    assert len(loaded.hnsw.level_nbrs) == len(hnsw_idx.hnsw.level_nbrs)
    after = np.asarray(loaded.search(ds.queries, p).ids)
    np.testing.assert_array_equal(after, before)


# -- serving through the facade ----------------------------------------------

def test_serve_hnsw_routes_through_descent(ds, hnsw_idx):
    """serve() on an hnsw index runs the same algorithm as search(): bfis
    entered via the greedy upper-level descent, not from the base medoid."""
    p = PARAMS.with_(algorithm="bfis", max_steps=256)
    engine = hnsw_idx.serve(p, bucket_sizes=(4, 8))
    res = engine.search(ds.queries[:4])
    direct = hnsw_idx.search(ds.queries[:4], p)
    np.testing.assert_array_equal(res.ids, np.asarray(direct.ids))


def test_serve_sharded_goes_through_walker_path(ds, indices, gts):
    """serve(algorithm="sharded") dispatches through the shard_map walker
    path (engine mode "sharded") and matches direct sharded search bit for
    bit; tests/test_coalescer.py pins the recall parity vs single-host."""
    p = PARAMS.with_(algorithm="sharded", global_rounds=16)
    engine = indices["l2"].serve(p, bucket_sizes=(4, 8))
    assert engine.mode == "sharded"
    res = engine.search(ds.queries[:4])
    direct = indices["l2"].search(ds.queries[:4], p)
    np.testing.assert_array_equal(res.ids, np.asarray(direct.ids))


def test_serve_inherits_metric(ds, indices, gts):
    """index.serve() returns an engine whose results match direct facade
    search bit for bit (cosine: query normalization happens in the engine)."""
    index = indices["cosine"]
    engine = index.serve(PARAMS, bucket_sizes=(1, 4, 8))
    res = engine.search(ds.queries[:6], gt_ids=gts["cosine"][:6])
    direct = index.search(ds.queries[:6], PARAMS)
    np.testing.assert_array_equal(res.ids, np.asarray(direct.ids))
    assert engine.metrics()["recall_at_k"] >= 0.9


# -- quantized storage + two-stage re-ranked search --------------------------

# the quantized arm turns its own recall knobs: a widened re-rank pool AND a
# deeper stage-1 traversal (quantized distance noise can derail one query's
# descent at the fp32 queue depth; the paper's L is exactly this knob)
QPARAMS = PARAMS.with_(algorithm="speedann", rerank_k=30, queue_len=128)


@pytest.fixture(scope="module")
def int8_indices(ds):
    return {m: AnnIndex.build(ds, IndexSpec(metric=m, degree=16, passes=1,
                                            quant="int8"))
            for m in METRICS}


@pytest.fixture(scope="module")
def bf16_index(ds):
    return AnnIndex.build(ds, IndexSpec(metric="l2", degree=16, passes=1,
                                        quant="bf16"))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("backend", ("ref_int8", "rowgather_int8"))
def test_two_stage_recall_matches_fp32(ds, indices, int8_indices, gts,
                                       metric, backend):
    """The acceptance bar for the two-stage path: int8 traversal + exact
    re-ranking loses at most 0.02 recall vs the fp32 search, on every
    metric, with the backend selected purely via SearchParams."""
    gt = gts[metric]
    r_fp32 = recall_at_k(np.asarray(
        indices[metric].search(ds.queries, PARAMS).ids), gt, 10)
    r_q = recall_at_k(np.asarray(
        int8_indices[metric].search(
            ds.queries, QPARAMS.with_(backend=backend)).ids), gt, 10)
    assert r_q >= r_fp32 - 0.02, f"{metric}/{backend}: {r_q} vs {r_fp32}"


def test_bf16_backend_recall(ds, indices, gts, bf16_index):
    r_fp32 = recall_at_k(np.asarray(
        indices["l2"].search(ds.queries, PARAMS).ids), gts["l2"], 10)
    r_bf = recall_at_k(np.asarray(
        bf16_index.search(ds.queries,
                          QPARAMS.with_(backend="ref_bf16")).ids),
        gts["l2"], 10)
    assert r_bf >= r_fp32 - 0.02


def test_quant_roundtrip_codes_bit_identical(ds, int8_indices, tmp_path):
    """npz round-trip preserves codes + scales exactly and search results
    bit for bit."""
    index = int8_indices["l2"]
    loaded = AnnIndex.load(index.save(str(tmp_path / "q8")))
    assert loaded.spec == index.spec
    assert loaded.spec.quant == QuantSpec(dtype="int8")
    np.testing.assert_array_equal(np.asarray(loaded.graph.codes),
                                  np.asarray(index.graph.codes))
    np.testing.assert_array_equal(np.asarray(loaded.graph.scales),
                                  np.asarray(index.graph.scales))
    for backend in ("ref_int8", "rowgather_int8"):
        p = QPARAMS.with_(backend=backend)
        np.testing.assert_array_equal(
            np.asarray(loaded.search(ds.queries, p).ids),
            np.asarray(index.search(ds.queries, p).ids),
            err_msg=backend)


def test_bf16_roundtrip_codes_bit_identical(ds, bf16_index, tmp_path):
    """bf16 codes persist as uint16 bit patterns; the round-trip restores
    the exact bfloat16 table (also with keep_float=False, where load
    rebuilds the f32 vectors by dequantizing)."""
    loaded = AnnIndex.load(bf16_index.save(str(tmp_path / "bf16")))
    assert str(loaded.graph.codes.dtype) == "bfloat16"
    np.testing.assert_array_equal(
        np.asarray(loaded.graph.codes).view(np.uint16),
        np.asarray(bf16_index.graph.codes).view(np.uint16))
    p = QPARAMS.with_(backend="ref_bf16")
    np.testing.assert_array_equal(
        np.asarray(loaded.search(ds.queries, p).ids),
        np.asarray(bf16_index.search(ds.queries, p).ids))
    # keep_float=False: vectors are not persisted, load dequantizes
    small = AnnIndex.build(ds.base[:500], IndexSpec(
        metric="l2", degree=12, passes=1,
        quant=QuantSpec(dtype="bf16", keep_float=False)))
    path = small.save(str(tmp_path / "bf16_small"))
    assert "vectors" not in np.load(path).files
    loaded = AnnIndex.load(path)
    np.testing.assert_array_equal(
        np.asarray(loaded.graph.vectors),
        np.asarray(small.graph.vectors))
    np.testing.assert_array_equal(
        np.asarray(loaded.search(ds.queries[:4], p).ids),
        np.asarray(small.search(ds.queries[:4], p).ids))


def test_int8_artifact_is_smaller(ds, indices, tmp_path):
    """With keep_float=False the persisted vector payload shrinks ~4x and
    the two-stage search still works (re-ranking against the dequantized
    table)."""
    fp_path = indices["l2"].save(str(tmp_path / "fp32"))
    small = AnnIndex.build(ds, IndexSpec(
        metric="l2", degree=16, passes=1,
        quant=QuantSpec(dtype="int8", keep_float=False)))
    small_path = small.save(str(tmp_path / "q8small"))
    zf, zq = np.load(fp_path), np.load(small_path)
    assert "vectors" not in zq.files
    assert zf["vectors"].nbytes == 4 * zq["codes"].nbytes
    assert os.path.getsize(small_path) < os.path.getsize(fp_path)
    loaded = AnnIndex.load(small_path)
    gt, _ = loaded.exact(ds.queries, 10)
    ids = np.asarray(loaded.search(
        ds.queries, QPARAMS.with_(backend="ref_int8")).ids)
    assert recall_at_k(ids, gt, 10) >= 0.9


def test_quant_backend_requires_matching_index(ds, indices, int8_indices):
    with pytest.raises(ValueError, match="codes table"):
        indices["l2"].search(ds.queries, PARAMS.with_(backend="ref_int8"))
    with pytest.raises(ValueError, match="codes table"):
        int8_indices["l2"].search(ds.queries,
                                  PARAMS.with_(backend="ref_bf16"))
    with pytest.raises(ValueError, match="sharded"):
        int8_indices["l2"].searcher(PARAMS.with_(algorithm="sharded",
                                                 backend="ref_int8"))


def test_serve_inherits_quantized_two_stage(ds, int8_indices):
    """index.serve() on a quantized index runs the identical two-stage
    searcher: engine results match direct facade search bit for bit, and
    stats() exposes the per-request latency percentiles."""
    index = int8_indices["cosine"]
    p = QPARAMS.with_(backend="ref_int8")
    engine = index.serve(p, bucket_sizes=(4, 8))
    res = engine.search(ds.queries[:6])
    direct = index.search(ds.queries[:6], p)
    np.testing.assert_array_equal(res.ids, np.asarray(direct.ids))
    s = engine.stats()
    for key in ("latency_p50_ms", "latency_p95_ms", "latency_p99_ms"):
        assert key in s and s[key] >= 0.0


# -- §5.3 ablation variants --------------------------------------------------

def test_edge_parallel_variant_keeps_walker_pool():
    """edge_parallel models NSG-32T (M=1, many walkers); it must differ
    from the bfis variant, which collapses to one sequential walker."""
    cfg = SearchConfig(m_max=8, num_walkers=8, staged=True)
    ep = variant(cfg, "edge_parallel")
    bf = variant(cfg, "bfis")
    assert ep.m_max == 1 and not ep.staged
    assert ep.num_walkers == cfg.num_walkers
    assert bf.num_walkers == 1
    assert ep != bf
