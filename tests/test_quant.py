"""Properties of the quantization subsystem (repro.quant).

Two layers of guarantees:

* codec: ``dequantize(quantize(x))`` reconstruction error is bounded by the
  scheme's own ``max_error_bound`` (half a quantization step for int8, 2^-8
  relative for bf16) for random vectors — the hypothesis sweep;
* kernels: the quantized distance backends agree with exact f32 arithmetic
  ON THE DEQUANTIZED values to float tolerance (the int32-accumulate +
  rescale path is exact, not an approximation of its own), and with the true
  f32 distances within the analytic error bound, across all three metrics.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # the randomized codec sweeps want hypothesis (requirements-dev, like
    # tests/test_property.py); the kernel parity tests below run without it
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare installs
    class _NoStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _NoStrategy()

    def given(**kw):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(**kw):
        return lambda f: f

from repro.core.graph import make_padded_csr  # noqa: E402
from repro.kernels import resolve_backend  # noqa: E402
from repro.config import SearchConfig  # noqa: E402
from repro.quant import (QuantSpec, dequantize, fit_scales,  # noqa: E402
                         max_error_bound, quantize, quantize_query)
from repro.quant.kernels import int8dist_rowgather  # noqa: E402

METRICS = ("l2", "ip", "cosine")


def random_vectors(seed, n=64, d=16, scale=3.0):
    rng = np.random.RandomState(seed)
    return (rng.randn(n, d) * scale).astype(np.float32)


# -- codec: reconstruction error bounded by the scheme -----------------------

@given(seed=st.integers(0, 10_000), per_dim=st.booleans(),
       scale=st.sampled_from([1e-3, 1.0, 50.0]))
@settings(max_examples=15, deadline=None)
def test_int8_roundtrip_error_bounded(seed, per_dim, scale):
    x = random_vectors(seed, scale=scale)
    spec = QuantSpec(dtype="int8", per_dim=per_dim)
    scales = fit_scales(x, spec)
    x_hat = np.asarray(dequantize(quantize(x, spec, scales), spec, scales))
    bound = np.asarray(max_error_bound(spec, scales))
    assert np.all(np.abs(x_hat - x) <= bound + 1e-6 * np.abs(x))
    # scales have the documented granularity
    assert scales.shape == ((1, x.shape[1]) if per_dim else (x.shape[0], 1))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_bf16_roundtrip_relative_error_bounded(seed):
    x = random_vectors(seed)
    spec = QuantSpec(dtype="bf16")
    x_hat = np.asarray(dequantize(quantize(x, spec), spec))
    rel = float(np.asarray(max_error_bound(spec, None)))
    assert np.all(np.abs(x_hat - x) <= rel * np.abs(x) + 1e-12)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_query_quantization_error_bounded(seed):
    q = random_vectors(seed, n=3)
    codes, scale = quantize_query(q)
    q_hat = np.asarray(codes, np.float32) * np.asarray(scale)
    assert np.all(np.abs(q_hat - q) <= 0.5 * np.asarray(scale) + 1e-7)


def test_zero_vectors_quantize_cleanly():
    x = np.zeros((4, 8), np.float32)
    spec = QuantSpec(dtype="int8")
    scales = fit_scales(x, spec)
    assert np.all(np.isfinite(np.asarray(scales)))
    assert np.array_equal(np.asarray(quantize(x, spec, scales)),
                          np.zeros((4, 8), np.int8))


# -- kernels: quantized distances vs exact -----------------------------------

def quantized_graph(x, spec):
    n = x.shape[0]
    nbrs = np.tile(np.arange(n, dtype=np.int32)[None, :8], (n, 1))
    g = make_padded_csr(nbrs, x)
    scales = fit_scales(x, spec)
    return g._replace(codes=quantize(x, spec, scales),
                      scales=jnp.asarray(scales, jnp.float32))


def exact_dist(x, q, metric):
    if metric in ("ip", "cosine"):
        return -(x @ q)
    return ((x - q[None, :]) ** 2).sum(axis=1)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("per_dim", (False, True))
def test_int8_distances_within_scheme_tolerance(metric, per_dim):
    """ref_int8 == exact f32 math on the dequantized table/query (tight),
    and within the analytic quantization bound of the TRUE distances."""
    x = random_vectors(11, n=40, d=16)
    q = random_vectors(12, n=1, d=16)[0]
    spec = QuantSpec(dtype="int8", per_dim=per_dim)
    g = quantized_graph(x, spec)
    dist_fn = resolve_backend(SearchConfig(metric=metric,
                                           dist_backend="ref_int8"))
    # batch-major DistFn contract: (B, M, R) ids, (B, d) queries
    nbr_ids = jnp.arange(40, dtype=jnp.int32).reshape(1, 4, 10)
    got = np.asarray(dist_fn(g, jnp.zeros((1, 4), jnp.int32), nbr_ids,
                             jnp.asarray(q)[None, :])).reshape(-1)

    x_hat = np.asarray(dequantize(g.codes, spec, g.scales))
    if per_dim:
        q_hat = q  # per-dim path keeps the query exact
    else:
        qc, qs = quantize_query(jnp.asarray(q))
        q_hat = np.asarray(qc, np.float32) * float(np.asarray(qs)[0])
    if metric == "l2" and not per_dim:
        # the kernel uses the EXACT ||q||^2 term
        want = (x_hat ** 2).sum(1) - 2 * (x_hat @ q_hat) + (q ** 2).sum()
        want = np.maximum(want, 0.0)
    else:
        want = exact_dist(x_hat, q_hat, metric)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    # analytic bound vs TRUE distances: elementwise errors <= s/2 propagate
    # linearly through the dot/norm terms
    true = exact_dist(x, q, metric)
    ex = np.abs(x_hat - x).max()
    eq = np.abs(q_hat - q).max()
    d = x.shape[1]
    big = np.abs(x).max() + np.abs(q).max() + ex + eq
    bound = d * big * (ex + eq) * 4 + 1e-3
    assert np.all(np.abs(got - true) <= bound)


@pytest.mark.parametrize("metric", METRICS)
def test_rowgather_int8_matches_ref_int8(metric):
    """The Pallas scalar-prefetch kernel computes the identical int32
    accumulation + rescale as the jnp reference backend."""
    x = random_vectors(21, n=40, d=16)
    q = random_vectors(22, n=2, d=16)
    spec = QuantSpec(dtype="int8")
    g = quantized_graph(x, spec)
    ids = jnp.asarray(
        np.random.RandomState(5).randint(0, 44, size=(2, 12)), jnp.int32)
    got = np.asarray(int8dist_rowgather(g.codes, g.scales, ids,
                                        jnp.asarray(q), metric=metric))
    ref_fn = resolve_backend(SearchConfig(metric=metric,
                                          dist_backend="ref_int8"))
    for b in range(2):
        want = np.asarray(ref_fn(g, jnp.zeros((1, 1), jnp.int32),
                                 ids[b].reshape(1, 1, -1),
                                 jnp.asarray(q[b])[None, :])).reshape(-1)
        np.testing.assert_allclose(got[b], want, rtol=1e-5, atol=1e-5)
    # padded ids (>= N) are +inf in both
    assert np.all(np.isinf(got[np.asarray(ids) >= 40]))


def test_bf16_distances_close_to_exact():
    x = random_vectors(31, n=40, d=16)
    q = random_vectors(32, n=1, d=16)[0]
    spec = QuantSpec(dtype="bf16")
    g = quantized_graph(x, spec)
    dist_fn = resolve_backend(SearchConfig(metric="l2",
                                           dist_backend="ref_bf16"))
    got = np.asarray(dist_fn(
        g, jnp.zeros((1, 4), jnp.int32),
        jnp.arange(40, dtype=jnp.int32).reshape(1, 4, 10),
        jnp.asarray(q)[None, :])).reshape(-1)
    np.testing.assert_allclose(got, exact_dist(x, q, "l2"), rtol=2e-2,
                               atol=2e-2)
