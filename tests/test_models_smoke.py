"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and no NaNs; plus prefill/decode
consistency for every family (the serve path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FAMILY_ENCDEC
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == FAMILY_ENCDEC:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, remat=True))(params)
    assert np.isfinite(float(loss)), f"{arch} loss={loss}"
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch
    # at least one grad is nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_matches_forward(arch):
    """Teacher-forced forward logits == prefill+decode logits stepwise."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    s_max = S + 4

    if cfg.family == FAMILY_ENCDEC:
        frames = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, cfg.encoder_ctx, cfg.d_model))
        full = model.forward(params, frames, tokens, remat=False)
        logits_p, state = model.prefill(params, frames, tokens[:, :S - 1],
                                        s_max)
        logits_d, state = model.decode_step(params, state,
                                            tokens[:, S - 1:S])
        want = full[:, S - 1]
    else:
        fwd = model.forward(params, tokens, remat=False)
        full = fwd[0] if isinstance(fwd, tuple) else fwd
        logits_p, state = model.prefill(params, tokens[:, :S - 1], s_max)
        # prefill's last logits == forward at position S-2
        np.testing.assert_allclose(
            np.asarray(logits_p[:, 0], np.float32),
            np.asarray(full[:, S - 2], np.float32), rtol=2e-2, atol=2e-2)
        logits_d, state = model.decode_step(params, state,
                                            tokens[:, S - 1:S])
        want = full[:, S - 1]

    got = np.asarray(logits_d[:, 0], np.float32)
    want = np.asarray(want, np.float32)
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates_and_counts(arch):
    """FULL configs are only exercised structurally (no allocation)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    # sanity vs the advertised scales (loose: configs are from public lit)
    expected = {
        "whisper-large-v3": (1.2e9, 2.5e9),
        "yi-9b": (7e9, 11e9),
        "qwen2.5-3b": (2.2e9, 4e9),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "mistral-large-123b": (1.1e11, 1.35e11),
        "qwen3-moe-30b-a3b": (2.4e10, 3.6e10),
        "grok-1-314b": (2.8e11, 3.6e11),
        "qwen2-vl-7b": (6e9, 9e9),
        "mamba2-2.7b": (2.2e9, 3.3e9),
        "zamba2-7b": (5.5e9, 9e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n:.3e}"
