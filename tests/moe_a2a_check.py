"""Subprocess body: shard_map MoE (a2a + tp paths) vs the einsum reference.

With generous capacity both paths must match moe.moe_ffn numerically.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import dataclasses                     # noqa: E402
import jax                             # noqa: E402
import jax.numpy as jnp                # noqa: E402
import numpy as np                     # noqa: E402

from repro.config import ModelConfig, MoEConfig, FAMILY_MOE  # noqa: E402
from repro.models import moe as moe_mod                      # noqa: E402
from repro.models import moe_a2a                             # noqa: E402
from repro.sharding import DEFAULT_RULES, use_rules          # noqa: E402


def check(num_experts: int, label: str):
    cfg = ModelConfig(
        name="t", family=FAMILY_MOE, num_layers=1, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=16, vocab_size=64,
        moe=MoEConfig(num_experts=num_experts, top_k=2,
                      capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

    y_ref, aux_ref = moe_mod.moe_ffn(p, x, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with use_rules(DEFAULT_RULES, mesh):
        y_sh, aux_sh = jax.jit(
            lambda pp, xx: moe_a2a.moe_ffn_sharded(pp, xx, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_sh), float(aux_ref),
                               rtol=1e-3, atol=1e-4)
    print(f"OK {label} experts={num_experts} "
          f"maxdiff={np.abs(np.asarray(y_sh) - np.asarray(y_ref)).max():.2e}")


def main():
    assert len(jax.devices()) == 8
    check(8, "a2a")    # 8 experts / 4-wide model axis -> 2 local experts
    check(2, "tp")     # 2 experts < 4 devices -> tensor-parallel path
    print("ALL_MOE_A2A_OK")


if __name__ == "__main__":
    main()
