"""Deterministic serving test harness: virtual clock + scripted arrivals.

Every timing-sensitive serving test (coalescer flush policy, deadline
shedding, admission watermarks, cache TTL, overload tails) runs on this
harness instead of wall-clock sleeps:

* :class:`VirtualClock` — a zero-arg callable (drop-in for
  ``time.perf_counter``) that only moves when a test advances it, injected
  into :class:`~repro.serve.AsyncAnnEngine` (and the cache / admission
  controller it wraps) via their ``clock=`` parameter;
* :class:`Arrival` — one scripted request: arrival time, query, deadline,
  priority class, and a tag to find its future again;
* :class:`ServingHarness` — the event loop: replays an arrival schedule
  against a ``start=False`` engine, interleaving submissions with
  policy-due batch dispatch (``due_at()`` → advance clock → ``pump()``),
  exactly as the real dispatcher thread would — minus the thread, the
  sleeps, and the flakes.

Optionally the harness models SERVICE TIME as a single busy server: with
``service_time_s`` set (a float, or a callable of batch size), each
dispatched batch occupies the server for that long and the next flush
cannot start before the server frees — while arrivals land at their
true times and keep queueing.  That makes queueing feedback real:
arrivals faster than the modeled service rate build a backlog, queue
depth grows, admission watermarks engage — which is how the
admission-control tests create a deterministic overload and measure
class-separated tail latency without touching real time.

The engine under test still runs REAL searches (or a test double); only
TIME is virtual.  Results therefore stay bit-identical to direct calls —
the harness changes when work happens, never what it computes.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np


class VirtualClock:
    """A monotone test clock: callable like ``time.perf_counter`` but only
    advanced explicitly.  Going backwards is a test bug and raises."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("virtual time cannot go backwards")
        self.t += dt
        return self.t

    def advance_to(self, t: float) -> float:
        if t < self.t:
            raise ValueError(
                f"virtual time cannot go backwards ({t} < {self.t})")
        self.t = float(t)
        return self.t


@dataclass(frozen=True)
class Arrival:
    """One scripted request in a serving schedule."""
    t: float                         # virtual arrival time (seconds)
    query: np.ndarray                # (d,) — what to submit
    deadline_ms: Optional[float] = None
    priority: str = "critical"
    tag: Optional[str] = None        # key into ServingHarness.futures


@dataclass
class HarnessResult:
    """What a replayed schedule produced, in arrival order."""
    futures: List[object]                  # one Future per arrival
    by_tag: Dict[str, object] = field(default_factory=dict)
    dispatched: int = 0                    # requests resolved via pump()

    def outcomes(self) -> List[str]:
        """Per-arrival outcome: ``served`` / exception class name."""
        out = []
        for f in self.futures:
            err = f.exception(timeout=0)
            out.append("served" if err is None else type(err).__name__)
        return out


def poisson_schedule(rng: np.random.Generator, queries: np.ndarray,
                     qps: float, duration_s: float, *,
                     deadline_ms: Optional[float] = None,
                     critical_fraction: float = 1.0) -> List[Arrival]:
    """A reproducible open-loop Poisson arrival script: exponential gaps at
    ``qps``, queries drawn round-robin from ``queries``, a ``rng``-drawn
    ``critical_fraction`` of arrivals in the critical class and the rest in
    the throughput class."""
    arrivals: List[Arrival] = []
    t = 0.0
    i = 0
    while True:
        t += float(rng.exponential(1.0 / qps))
        if t >= duration_s:
            return arrivals
        crit = bool(rng.random() < critical_fraction)
        arrivals.append(Arrival(
            t=t, query=queries[i % len(queries)], deadline_ms=deadline_ms,
            priority="critical" if crit else "throughput"))
        i += 1


class ServingHarness:
    """Replay scripted arrivals against a ``start=False`` AsyncAnnEngine.

    The engine MUST have been built with ``start=False`` and
    ``clock=harness_clock`` (the same :class:`VirtualClock` passed here) —
    the harness takes the dispatcher thread's place.  ``run()`` merges the
    arrival schedule with the engine's own :meth:`due_at` signal into one
    deterministic event loop:

    1. next event = min(next arrival, next policy-due flush time);
    2. advance the virtual clock to it;
    3. submit the arrival, or ``pump()`` the due batches (advancing the
       clock by the modeled service time per dispatched batch).

    Everything — batch boundaries, shed decisions, cache TTL expiry —
    follows from the schedule and policies alone, so runs are repeatable
    bit for bit.
    """

    def __init__(self, srv, clock: VirtualClock, *,
                 service_time_s: Union[float, Callable[[int], float],
                                       None] = None):
        if srv._thread is not None:
            raise ValueError("harness drives start=False engines only")
        if srv._clock is not clock:
            raise ValueError("engine must share the harness clock "
                             "(serve_async(..., clock=clock))")
        self.srv = srv
        self.clock = clock
        self._service_time = service_time_s
        self._busy_until = clock()      # modeled server free from here

    def _service_s(self, batch: int) -> float:
        if self._service_time is None:
            return 0.0
        if callable(self._service_time):
            return float(self._service_time(batch))
        return float(self._service_time)

    def _flush_time(self) -> Optional[float]:
        """When the next flush can START: the policy's due time, delayed
        until the modeled server is free.  None with an empty queue."""
        due = self.srv.due_at()
        if due is None:
            return None
        return max(due, self._busy_until)

    def _flush_one(self, result: HarnessResult) -> int:
        """Dispatch ONE due batch at the current virtual time and occupy
        the server for its modeled service time."""
        before = self.srv.batches_dispatched
        n = self.srv.pump(max_batches=1)
        result.dispatched += n
        if self.srv.batches_dispatched > before:
            # expired-only pumps shed without touching the engine: free
            self._busy_until = self.clock() + self._service_s(n)
        return n

    def run(self, arrivals: Sequence[Arrival], *,
            drain: bool = True) -> HarnessResult:
        """Replay ``arrivals`` (any order; sorted by time, FIFO on ties).
        Arrivals always enqueue at their scheduled times — a busy server
        delays DISPATCH, not admission, so backlogs build exactly as they
        would under a real overload.  With ``drain=True`` the queue is
        pumped policy-due to empty after the last arrival, so every future
        is settled on return."""
        order = sorted(range(len(arrivals)),
                       key=lambda i: (arrivals[i].t, i))
        result = HarnessResult(futures=[None] * len(arrivals))
        heap = [(arrivals[i].t, i) for i in order]
        heapq.heapify(heap)
        while heap:
            t_arr, i = heap[0]
            flush_t = self._flush_time()
            if flush_t is not None and flush_t <= t_arr:
                self.clock.advance_to(max(flush_t, self.clock()))
                if self._flush_one(result) == 0:
                    break   # defensive: due signal without a dispatch
                continue
            heapq.heappop(heap)
            self.clock.advance_to(max(t_arr, self.clock()))
            a = arrivals[i]
            fut = self.srv.submit(a.query, deadline_ms=a.deadline_ms,
                                  priority=a.priority)
            result.futures[i] = fut
            if a.tag is not None:
                result.by_tag[a.tag] = fut
        if drain:
            while True:
                flush_t = self._flush_time()
                if flush_t is None:
                    break
                self.clock.advance_to(max(flush_t, self.clock()))
                if self._flush_one(result) == 0:
                    break
        return result

    def client_latencies_ms(self, arrivals: Sequence[Arrival],
                            result: HarnessResult,
                            priority: Optional[str] = None) -> List[float]:
        """Client-observed latency (virtual ms from arrival to resolution)
        of every SERVED request, optionally one priority class only."""
        out = []
        for a, f in zip(arrivals, result.futures):
            if priority is not None and a.priority != priority:
                continue
            if f.exception(timeout=0) is None:
                out.append((f.result(timeout=0).done_t - a.t) * 1e3)
        return out
