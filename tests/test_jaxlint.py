"""jaxlint analyzer tests: seeded violations per rule family, suppression
comments, config handling, and the CLI JSON contract.

Every positive fixture plants exactly one violation and asserts the rule id,
file, and line of the finding; every negative fixture is the minimal legal
variant of the same code.  Fixtures live under a ``src/`` root inside
``tmp_path`` so module names resolve the same way they do in the real tree
(``fx.core.engine`` for ``src/fx/core/engine.py``).
"""
import json
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.jaxlint import cli                       # noqa: E402
from tools.jaxlint.config import Config             # noqa: E402
from tools.jaxlint.model import selected_rules      # noqa: E402
from tools.jaxlint.project import Project           # noqa: E402


def sweep(tmp_path, sources, select=None, static_attributes=()):
    """Write fixture sources under ``tmp_path/src`` and run the analyzer.

    ``sources`` maps ``src``-relative paths to (dedented) module text.
    Returns the finding list, sorted by (path, line, rule).
    """
    for rel, text in sources.items():
        p = tmp_path / "src" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    cfg = Config(static_attributes=list(static_attributes))
    project = Project(cfg, root=tmp_path)
    errors = project.add_paths([tmp_path / "src"])
    assert not errors, errors
    findings = []
    for rule in selected_rules(select):
        findings.extend(rule.check(project))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def only(findings, rule):
    hits = [f for f in findings if f.rule == rule]
    assert hits, f"expected a {rule} finding, got {findings}"
    return hits


# ---------------------------------------------------------------------------
# JL1 — tracer purity
# ---------------------------------------------------------------------------

def test_jl101_branch_on_traced_param_in_jit(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """}, select=["JL1"])
    (f,) = only(findings, "JL101")
    assert f.path == "src/fx/mod.py"
    assert f.line == 5
    assert not f.suppressed


def test_jl101_reaches_through_cross_module_calls(tmp_path):
    # the violation sits two calls away from the jit root, in another module
    findings = sweep(tmp_path, {
        "fx/helper.py": """\
            def inner(v):
                if v.sum() > 0:
                    return v
                return -v

            def step(v):
                return inner(v)
        """,
        "fx/mod.py": """\
            import jax
            from fx.helper import step

            @jax.jit
            def f(x):
                return step(x)
        """,
    }, select=["JL1"])
    (f,) = only(findings, "JL101")
    assert f.path == "src/fx/helper.py"
    assert f.line == 2


def test_jl101_negative_static_contexts(tmp_path):
    # shape reads, None checks, and plain Python functions are all legal
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax

        @jax.jit
        def f(x, mask=None):
            if x.shape[0] > 4:
                x = x[:4]
            if mask is not None:
                x = x * mask
            return x

        def not_jitted(x):
            if x > 0:
                return x
            return -x
    """}, select=["JL1"])
    assert findings == []


def test_jl101_configured_static_attribute(tmp_path):
    src = {"fx/mod.py": """\
        import jax

        @jax.jit
        def f(g, x):
            if g.n_nodes > 100:
                return x
            return -x
    """}
    assert only(sweep(tmp_path, dict(src), select=["JL1"]), "JL101")
    assert sweep(tmp_path / "b", dict(src), select=["JL1"],
                 static_attributes=["n_nodes"]) == []


def test_jl101_while_loop_body_is_a_traced_root(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax

        def run(x):
            def body(s):
                while s > 0:
                    s = s - 1
                return s
            return jax.lax.while_loop(lambda s: s < 9, body, x)
    """}, select=["JL1"])
    (f,) = only(findings, "JL101")
    assert f.line == 5


def test_jl102_assert_on_traced(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax

        @jax.jit
        def f(x):
            assert x > 0
            return x
    """}, select=["JL1"])
    (f,) = only(findings, "JL102")
    assert (f.path, f.line) == ("src/fx/mod.py", 5)


def test_jl103_concretization(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax

        @jax.jit
        def f(x):
            n = int(x[0])
            return x[:1] * n
    """}, select=["JL1"])
    (f,) = only(findings, "JL103")
    assert f.line == 5


def test_jl104_numpy_on_traced(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x).sum()
    """}, select=["JL1"])
    (f,) = only(findings, "JL104")
    assert f.line == 6


def test_jl104_negative_numpy_on_concrete_closure(tmp_path):
    # np.* on values that never carry taint (module constants, untraced
    # args) is ordinary host-side code
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import numpy as np

        TABLE = np.arange(16)

        def host_prep(ids):
            return np.asarray(ids, dtype=np.int32)
    """}, select=["JL1"])
    assert findings == []


# ---------------------------------------------------------------------------
# JL2 — backend contract
# ---------------------------------------------------------------------------

def test_jl201_factory_arity(tmp_path):
    findings = sweep(tmp_path, {"fx/backends.py": """\
        from fx.registry import register_backend

        @register_backend("twoarg")
        def make(cfg, extra):
            def dist_fn(graph, active_ids, nbr_ids, queries):
                '''(B, M, R) batch-major.'''
                return nbr_ids
            return dist_fn
    """}, select=["JL2"])
    (f,) = only(findings, "JL201")
    assert (f.path, f.line) == ("src/fx/backends.py", 3)


def test_jl202_distfn_signature(tmp_path):
    findings = sweep(tmp_path, {"fx/backends.py": """\
        from fx.registry import register_backend

        @register_backend("perquery")
        def make(cfg):
            def dist_fn(graph, node_id, query):
                return node_id
            return dist_fn
    """}, select=["JL2"])
    (f,) = only(findings, "JL202")
    assert f.line == 3
    assert "3 positional parameter(s)" in f.message


def test_jl202_negative_through_maker_chain(tmp_path):
    # factory delegates to a maker in another module; terminal is legal
    findings = sweep(tmp_path, {
        "fx/makers.py": """\
            def make_l2(metric):
                '''Batch-major (B, M, R) distances.'''
                def dist_fn(graph, active_ids, nbr_ids, queries):
                    return nbr_ids
                return dist_fn
        """,
        "fx/backends.py": """\
            from fx.registry import register_backend
            from fx.makers import make_l2

            @register_backend("l2")
            def make(cfg):
                return make_l2("l2")
        """,
    }, select=["JL2"])
    assert findings == []


def test_jl203_manual_sentinel_padding(tmp_path):
    findings = sweep(tmp_path, {"fx/pad.py": """\
        import jax.numpy as jnp

        def hand_pad(ids, tile, g):
            pad = tile - ids.shape[0]
            return jnp.concatenate([ids, jnp.full((pad,), g.n_nodes)])

        def pad_ids_to_tile(ids, tile, g):
            pad = tile - ids.shape[0]
            return jnp.concatenate([ids, jnp.full((pad,), g.n_nodes)])
    """}, select=["JL2"])
    hits = only(findings, "JL203")
    # the audited helper itself is exempt; only hand_pad is flagged
    assert [f.line for f in hits] == [5]


def test_jl204_quant_suffix_mismatch_both_directions(tmp_path):
    findings = sweep(tmp_path, {"fx/backends.py": """\
        from fx.registry import register_backend
        from fx.quant import require_codes

        @register_backend("fast_int8")
        def make_noint8(cfg):
            def dist_fn(graph, active_ids, nbr_ids, queries):
                '''(B, M, R) batch-major.'''
                return nbr_ids
            return dist_fn

        @register_backend("plain")
        def make_hidden_quant(cfg):
            def dist_fn(graph, active_ids, nbr_ids, queries):
                '''(B, M, R) batch-major.'''
                require_codes(graph, "int8")
                return nbr_ids
            return dist_fn

        @register_backend("good_int8")
        def make_good(cfg):
            def dist_fn(graph, active_ids, nbr_ids, queries):
                '''(B, M, R) batch-major.'''
                require_codes(graph, "int8")
                return nbr_ids
            return dist_fn
    """}, select=["JL2"])
    hits = only(findings, "JL204")
    assert [f.line for f in hits] == [4, 11]


# ---------------------------------------------------------------------------
# JL3 — recompile hygiene
# ---------------------------------------------------------------------------

def test_jl301_unhashable_static_annotation(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        from functools import partial
        import jax

        @partial(jax.jit, static_argnames="opts")
        def f(x, opts: dict):
            return x
    """}, select=["JL3"])
    (f,) = only(findings, "JL301")
    assert (f.path, f.line) == ("src/fx/mod.py", 4)


def test_jl302_nonfrozen_dataclass_static(tmp_path):
    src = """\
        import dataclasses
        from functools import partial
        import jax

        @dataclasses.dataclass{frozen}
        class Cfg:
            k: int = 8

        @partial(jax.jit, static_argnames="cfg")
        def f(x, cfg: Cfg):
            return x
    """
    findings = sweep(tmp_path, {
        "fx/mod.py": textwrap.dedent(src).format(frozen="")},
        select=["JL3"])
    (f,) = only(findings, "JL302")
    assert f.line == 9
    clean = sweep(tmp_path / "b", {
        "fx/mod.py": textwrap.dedent(src).format(frozen="(frozen=True)")},
        select=["JL3"])
    assert clean == []


def test_jl303_jit_inside_loop(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax

        def f(x):
            return x

        g = jax.jit(f)

        def retrace(xs):
            out = []
            for x in xs:
                out.append(jax.jit(f)(x))
            return out
    """}, select=["JL3"])
    (f,) = only(findings, "JL303")
    assert f.line == 11


# ---------------------------------------------------------------------------
# JL4 — shape convention
# ---------------------------------------------------------------------------

def test_jl401_batch_function_needs_doc(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        def score_batch(x):
            return x * 2

        def rank_batch(x):
            '''Ranks (B, n) scores along the trailing axis.'''
            return x
    """}, select=["JL4"])
    (f,) = only(findings, "JL401")
    assert f.line == 1
    assert "score_batch" in f.message


def test_jl401_backend_chain_doc(tmp_path):
    findings = sweep(tmp_path, {"fx/backends.py": """\
        from fx.registry import register_backend

        @register_backend("undoc")
        def make(cfg):
            def dist_fn(graph, active_ids, nbr_ids, queries):
                return nbr_ids
            return dist_fn
    """}, select=["JL4"])
    (f,) = only(findings, "JL401")
    assert f.line == 3


def test_jl402_flatten_in_core_batch_function(tmp_path):
    src = """\
        def fuse_batch(x):
            '''Sums (B, n) rows.'''
            return x.reshape(-1).sum()

        def keep_batch(x):
            '''Sums (B, n) rows per query.'''
            return x.reshape(x.shape[0], -1).sum(axis=-1)
    """
    findings = sweep(tmp_path, {"fx/core/engine.py": src}, select=["JL4"])
    (f,) = only(findings, "JL402")
    assert (f.path, f.line) == ("src/fx/core/engine.py", 3)
    # the same flatten outside core/ is not JL402's business
    assert sweep(tmp_path / "b", {"fx/serve/engine.py": src},
                 select=["JL4"]) == []


# ---------------------------------------------------------------------------
# JL5 — observability boundary
# ---------------------------------------------------------------------------

def test_jl501_io_callback_in_jit(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax
        from jax.experimental import io_callback

        @jax.jit
        def step(x):
            io_callback(print, None, x)
            return x + 1
    """}, select=["JL5"])
    (f,) = only(findings, "JL501")
    assert (f.path, f.line) == ("src/fx/mod.py", 6)
    assert not f.suppressed


def test_jl501_debug_callback_dotted_and_from_import(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax
        from jax import debug

        @jax.jit
        def a(x):
            jax.debug.callback(print, x)
            return x

        @jax.jit
        def b(x):
            debug.callback(print, x)
            return x
    """}, select=["JL5"])
    hits = only(findings, "JL501")
    assert [f.line for f in hits] == [6, 11]


def test_jl501_reaches_traced_helpers_not_host_code(tmp_path):
    # the callback sits in a helper the jit root calls — still traced;
    # the identical call in an untraced function is not JL5's business
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax
        from jax.experimental import io_callback

        def helper(x):
            io_callback(print, None, x)
            return x

        @jax.jit
        def root(x):
            return helper(x)

        def host_driver(x):
            io_callback(print, None, x)
            return x
    """}, select=["JL5"])
    (f,) = only(findings, "JL501")
    assert f.line == 5


def test_jl502_host_clock_in_jit(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import time
        from time import perf_counter
        import jax

        @jax.jit
        def f(x):
            t0 = time.perf_counter()
            t1 = perf_counter()
            return x + (t1 - t0)
    """}, select=["JL5"])
    hits = only(findings, "JL502")
    assert [f.line for f in hits] == [7, 8]
    assert "trace time" in hits[0].message


def test_jl502_datetime_now_in_jit(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import datetime
        import jax

        @jax.jit
        def f(x):
            stamp = datetime.datetime.now()
            return x
    """}, select=["JL5"])
    (f,) = only(findings, "JL502")
    assert f.line == 6


def test_jl5_obs_modules_are_exempt(tmp_path):
    src = """\
        import time
        import jax

        @jax.jit
        def f(x):
            t0 = time.perf_counter()
            return x + t0
    """
    assert sweep(tmp_path, {"fx/obs/bridge.py": src}, select=["JL5"]) == []
    # same code outside the obs package fires
    hits = sweep(tmp_path / "b", {"fx/serve/mod.py": src}, select=["JL5"])
    assert only(hits, "JL502")


def test_jl5_untraced_timing_is_fine(tmp_path):
    # host-side timing around a dispatch is exactly what the engine does
    assert sweep(tmp_path, {"fx/mod.py": """\
        import time
        import jax

        @jax.jit
        def compute(x):
            return x * 2

        def timed_dispatch(x):
            t0 = time.perf_counter()
            out = jax.block_until_ready(compute(x))
            return out, time.perf_counter() - t0
    """}, select=["JL5"]) == []


def test_jl5_suppression(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        import jax
        from jax.experimental import io_callback

        @jax.jit
        def f(x):
            io_callback(print, None, x)  # jaxlint: ignore[JL501] -- debug tap
            return x
    """}, select=["JL5"])
    (f,) = only(findings, "JL501")
    assert f.suppressed
    assert f.justification == "debug tap"


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_inline_suppression_with_justification(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        def score_batch(x):  # jaxlint: ignore[JL401] -- shapes in caller doc
            return x * 2
    """}, select=["JL4"])
    (f,) = only(findings, "JL401")
    assert f.suppressed
    assert f.justification == "shapes in caller doc"


def test_standalone_comment_suppresses_next_code_line(tmp_path):
    findings = sweep(tmp_path, {"fx/core/mod.py": """\
        def fuse_batch(x):
            '''Sums (B, n) rows.'''
            # jaxlint: ignore[JL402] -- cross-lane sum is intended
            return x.reshape(-1).sum()
    """}, select=["JL4"])
    (f,) = only(findings, "JL402")
    assert f.suppressed
    assert f.justification == "cross-lane sum is intended"


def test_family_suppression_covers_member_rules(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        def score_batch(x):  # jaxlint: ignore[JL4]
            return x * 2
    """}, select=["JL4"])
    (f,) = only(findings, "JL401")
    assert f.suppressed


def test_suppression_does_not_cover_other_rules(tmp_path):
    findings = sweep(tmp_path, {"fx/mod.py": """\
        def score_batch(x):  # jaxlint: ignore[JL402]
            return x * 2
    """}, select=["JL4"])
    (f,) = only(findings, "JL401")
    assert not f.suppressed


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _write_cli_tree(tmp_path):
    p = tmp_path / "src" / "fx" / "mod.py"
    p.parent.mkdir(parents=True)
    p.write_text(textwrap.dedent("""\
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x

        def score_batch(x):  # jaxlint: ignore[JL401] -- doc lives in caller
            return x * 2
    """))


def test_cli_json_schema_and_exit_code(tmp_path, monkeypatch, capsys):
    _write_cli_tree(tmp_path)
    monkeypatch.chdir(tmp_path)
    rc = cli.run(["src", "--no-config", "--format", "json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert set(out) == {"version", "findings", "suppressed", "errors",
                        "counts"}
    assert out["counts"] == {"active": 1, "suppressed": 1, "files": 1}
    (f,) = out["findings"]
    assert {"rule", "family", "path", "line", "col", "message",
            "suppressed"} <= set(f)
    assert (f["rule"], f["family"], f["line"]) == ("JL101", "JL1", 5)
    (s,) = out["suppressed"]
    assert s["rule"] == "JL401" and s["justification"]


def test_cli_select_and_exit_zero(tmp_path, monkeypatch, capsys):
    _write_cli_tree(tmp_path)
    monkeypatch.chdir(tmp_path)
    # JL4's only finding is suppressed -> clean run under --select JL4
    assert cli.run(["src", "--no-config", "--select", "JL4"]) == 0
    capsys.readouterr()
    # --exit-zero downgrades the JL101 failure to report-only
    assert cli.run(["src", "--no-config", "--exit-zero"]) == 0
    assert "JL101" in capsys.readouterr().out


def test_cli_text_format_renders_location(tmp_path, monkeypatch, capsys):
    _write_cli_tree(tmp_path)
    monkeypatch.chdir(tmp_path)
    rc = cli.run(["src", "--no-config", "--select", "JL1"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "src/fx/mod.py:5:" in out and "JL101" in out


def test_cli_unknown_selector_is_usage_error(tmp_path, monkeypatch, capsys):
    _write_cli_tree(tmp_path)
    monkeypatch.chdir(tmp_path)
    assert cli.run(["src", "--no-config", "--select", "JL9"]) == 2


def test_cli_syntax_error_reported_not_fatal(tmp_path, monkeypatch, capsys):
    p = tmp_path / "src" / "bad.py"
    p.parent.mkdir(parents=True)
    p.write_text("def broken(:\n")
    monkeypatch.chdir(tmp_path)
    rc = cli.run(["src", "--no-config", "--format", "json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 2
    assert out["errors"] and "syntax error" in out["errors"][0]


def test_cli_list_rules(capsys):
    assert cli.run(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("JL101", "JL204", "JL303", "JL402"):
        assert rid in out


# ---------------------------------------------------------------------------
# the real tree stays clean (the CI gate, runnable locally)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not (ROOT / "src" / "repro").is_dir(),
                    reason="repo tree not present")
def test_repo_tree_has_no_active_findings(monkeypatch, capsys):
    monkeypatch.chdir(ROOT)
    rc = cli.run(["src/repro", "--format", "json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 0, out["findings"]
    assert out["counts"]["active"] == 0
    assert out["counts"]["files"] > 30
    # every surviving suppression carries a written justification
    assert all(s["justification"].strip() for s in out["suppressed"])
