"""Elastic resharding + int8-compressed DP train step (8-device subprocess,
keeping the main pytest process on 1 device)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_elastic_and_compressed_dp():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "elastic_compress_check.py")],
        capture_output=True, text=True, timeout=540, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL_ELASTIC_COMPRESS_OK" in out.stdout, out.stdout + out.stderr
