"""Sharding rules, cell matrix, roofline parsing, HLO profiling units."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import ALL_SHAPES
from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import cell_matrix, runnable_cells
from repro.launch.roofline import (collective_bytes, model_flops,
                                   roofline_terms, _shape_bytes)
from repro.sharding import DEFAULT_RULES, resolve_spec, spec_for_path


@pytest.fixture(scope="module")
def mesh8():
    # AbstractMesh: axis names/sizes without real devices (1-device CI).
    # Signature differs across jax versions: new is (sizes, names), old
    # (jax<=0.4.x) is a tuple of (name, size) pairs.
    try:
        return jax.sharding.AbstractMesh((2, 4), ("data", "model"))
    except TypeError:
        return jax.sharding.AbstractMesh((("data", 2), ("model", 4)))


def test_resolve_spec_drops_nondivisible(mesh8):
    # 20 heads on a 4-wide model axis: 20 % 4 == 0 -> sharded
    assert resolve_spec((8, 20), ("batch", "heads"), mesh8,
                        DEFAULT_RULES) == P("data", "model")
    # 7 is not divisible by any axis -> replicated
    assert resolve_spec((8, 7), ("batch", "heads"), mesh8,
                        DEFAULT_RULES) == P("data", None)


def test_resolve_spec_no_duplicate_axes(mesh8):
    sp = resolve_spec((4, 32, 8, 16), ("layers", "kv_seq", "kv_heads", None),
                      mesh8, DEFAULT_RULES)
    axes = [a for a in sp if a is not None]
    flat = []
    for a in axes:
        flat += list(a) if isinstance(a, tuple) else [a]
    assert len(set(flat)) == len(flat)


def test_param_path_conventions(mesh8):
    # scanned weight (L, d, h): prepend layers
    sp = spec_for_path("layers/attn/wq", (4, 64, 64), mesh8)
    assert sp == P(None, "data", "model")
    # zamba grouped (G, E, d, f): leading pad
    sp = spec_for_path("grouped/mamba/in_proj", (2, 3, 64, 64), mesh8)
    assert sp[-2:] == P("data", "model")[:2] or sp[-1] in ("model", None)
    # kv cache
    sp = spec_for_path("caches/k", (4, 8, 64, 4, 16), mesh8)
    assert sp == P(None, "data", "model", None, None)


def test_cell_matrix_is_complete():
    cells = cell_matrix()
    assert len(cells) == len(ARCH_IDS) * len(ALL_SHAPES) == 40
    skips = [c for c in cells if c.skip is not None]
    # exactly the 8 pure-full-attention long_500k cells are skipped
    assert len(skips) == 8
    assert all(c.shape.name == "long_500k" for c in skips)
    assert {c.arch for c in cells if c.shape.name == "long_500k"
            and c.skip is None} == {"mamba2-2.7b", "zamba2-7b"}
    assert len(runnable_cells()) == 32


def test_collective_bytes_parsing():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[4,4]{1,0} all-reduce-start(%y), to_apply=%add
  %ar.d = f32[4,4]{1,0} all-reduce-done(%ar.1)
  %a2a = (f32[8]{0}, f32[8]{0}) all-to-all(%a, %b)
  %notacoll = f32[999]{0} add(%p, %q)
"""
    c = collective_bytes(hlo)
    assert c["all-gather"] == 16 * 128 * 2
    assert c["all-reduce"] == 4 * 4 * 4          # start counted, done not
    assert c["all-to-all"] == 2 * 8 * 4
    assert c["collective-permute"] == 0


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12 * 256, bytes_accessed=1.0,
                       coll={"all-reduce": 0}, chips=256)
    assert abs(t["t_compute_s"] - 1.0) < 1e-9
    assert t["dominant"] == "compute"
    t = roofline_terms(flops=0.0, bytes_accessed=0.0,
                       coll={"all-gather": 50e9 * 256}, chips=256)
    assert t["dominant"] == "collective"
    assert abs(t["t_collective_s"] - 1.0) < 1e-9


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_model_flops_positive(arch):
    cfg = get_config(arch)
    for shape in ALL_SHAPES:
        assert model_flops(cfg, shape) > 0


def test_hlo_profile_dot_flops():
    from repro.launch.hlo_profile import dot_flops
    line = ("%dot.1 = f32[4,8]{1,0} dot(%a, %b), "
            "lhs_contracting_dims={1}, rhs_contracting_dims={0} "
            "lhs shape f32[4,16]")
    # fallback path (no lhs shape parse): 2 * numel
    assert dot_flops(line) >= 2 * 4 * 8
