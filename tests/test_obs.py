"""Observability stack: sketch accuracy, exporters, span trees, overhead.

The contracts under test (docs/observability.md):

* ``LogHistogram`` quantiles are within ``rel_err`` of exact NumPy
  quantiles while memory stays bounded; merge is exact on bucket counts
  (associative up to float ``sum`` accumulation order).
* ``MetricsRegistry`` round-trips through JSON, merges across replicas,
  and emits valid Prometheus text (label escaping included).
* ``TraceRecorder`` produces Chrome-trace JSON that the repo's own
  validator (``scripts/check_trace.py``) accepts: spans nest by
  containment, async request lifelines pair up, shed events appear.
* Disabled observability is a true no-op: the engines write nothing into
  the registry and allocate no trace events on the hot path.
"""
import importlib.util
import json
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.data import make_vector_dataset
from repro.obs import (NULL_OBS, NULL_TRACER, LogHistogram, MetricsRegistry,
                       Observability, TraceRecorder, device_annotation)

ROOT = Path(__file__).resolve().parents[1]


def _load_check_trace():
    spec = importlib.util.spec_from_file_location(
        "check_trace", ROOT / "scripts" / "check_trace.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


PARAMS = SearchParams(k=10, queue_len=48, m_max=4, num_walkers=4,
                      max_steps=128, local_steps=4)
BUCKETS = (1, 2, 4, 8)


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=1200, n_queries=16, k=10, dim=24,
                               n_clusters=12, seed=7)


@pytest.fixture(scope="module")
def index(ds):
    return AnnIndex.build(ds, IndexSpec(degree=12, passes=1))


# -- LogHistogram ------------------------------------------------------------

def test_histogram_quantiles_match_numpy_within_rel_err():
    rng = np.random.RandomState(0)
    # lognormal spans ~4 decades — the shape latency streams actually have
    values = rng.lognormal(mean=1.0, sigma=1.5, size=20_000)
    h = LogHistogram(rel_err=0.01)
    h.observe_many(values)
    for q in (0.01, 0.10, 0.50, 0.90, 0.95, 0.99, 0.999):
        exact = float(np.quantile(values, q, method="lower"))
        got = h.quantile(q)
        assert abs(got - exact) <= 0.02 * exact, (q, got, exact)
    assert h.mean == pytest.approx(values.mean())
    assert h.min == values.min() and h.max == values.max()
    assert h.quantile(0.0) == values.min()
    assert h.quantile(1.0) == values.max()


def test_histogram_memory_bounded_and_collapse_keeps_tail():
    h = LogHistogram(rel_err=0.01, max_buckets=64)
    rng = np.random.RandomState(1)
    # 12 decades of values — far more than 64 buckets can hold exactly
    h.observe_many(10.0 ** rng.uniform(-6, 6, size=5000))
    assert h.n_buckets <= 64
    assert h.count == 5000
    # collapse folds the LOW buckets; the tail keeps full resolution
    assert h.quantile(0.5) <= h.quantile(0.99) <= h.max


def test_histogram_zero_and_nonfinite_values():
    h = LogHistogram()
    h.observe(0.0)
    h.observe(-3.0)          # below min-trackable -> zero bucket
    h.observe(float("nan"))  # dropped
    h.observe(float("inf"))  # dropped
    h.observe(5.0)
    assert h.count == 3
    assert h.zero_count == 2
    assert h.quantile(0.0) == -3.0          # exact min envelope
    assert h.quantile(0.99) == 0.0          # nearest-rank lower of 3 values
    assert h.quantile(1.0) == 5.0           # exact max envelope


def test_histogram_merge_is_associative():
    rng = np.random.RandomState(2)
    parts = [rng.lognormal(size=777) for _ in range(3)]

    def sketch(v):
        h = LogHistogram()
        h.observe_many(v)
        return h

    ab_c = sketch(parts[0]).merge(sketch(parts[1])).merge(sketch(parts[2]))
    bc = sketch(parts[1]).merge(sketch(parts[2]))
    a_bc = sketch(parts[0]).merge(bc)
    da, db = ab_c.to_dict(), a_bc.to_dict()
    # bucket counts/count/min/max are exactly associative; float `sum`
    # differs only by accumulation order
    for key in ("buckets", "count", "min", "max", "zero_count"):
        assert da[key] == db[key]
    assert da["sum"] == pytest.approx(db["sum"], rel=1e-9)
    # and the merged sketch matches a single sketch over the concatenation
    allv = np.concatenate(parts)
    whole = sketch(allv)
    assert ab_c.to_dict()["buckets"] == whole.to_dict()["buckets"]
    assert ab_c.quantile(0.95) == whole.quantile(0.95)


def test_histogram_merge_rejects_mixed_resolution_and_roundtrips():
    a, b = LogHistogram(rel_err=0.01), LogHistogram(rel_err=0.05)
    with pytest.raises(ValueError):
        a.merge(b)
    a.observe_many([1.0, 2.0, 4.0])
    back = LogHistogram.from_dict(json.loads(json.dumps(a.to_dict())))
    assert back.to_dict() == a.to_dict()
    assert back.quantile(0.5) == a.quantile(0.5)


# -- MetricsRegistry ---------------------------------------------------------

def test_registry_types_and_kind_conflict():
    reg = MetricsRegistry()
    reg.counter("req_total").inc(3, outcome="served")
    reg.gauge("queue_depth").set(7)
    reg.histogram("lat_ms").observe(12.5, backend="ref")
    with pytest.raises(TypeError):
        reg.gauge("req_total")
    with pytest.raises(ValueError):
        reg.counter("req_total").labels(outcome="served").inc(-1)


def test_registry_merge_and_json_roundtrip():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("req_total").inc(2, outcome="served")
    b.counter("req_total").inc(3, outcome="served")
    b.counter("req_total").inc(1, outcome="shed")
    for v in (1.0, 2.0, 3.0):
        a.histogram("lat_ms").observe(v)
    for v in (4.0, 5.0):
        b.histogram("lat_ms").observe(v)
    a.merge(b)
    d = a.to_dict()
    served = [s for s in d["req_total"]["series"]
              if s["labels"] == {"outcome": "served"}]
    assert served[0]["value"] == 5.0
    hist = d["lat_ms"]["series"][0]
    assert hist["histogram"]["count"] == 5
    assert set(hist["quantiles"]) == {"p50", "p95", "p99"}
    back = MetricsRegistry.from_json(a.to_json())
    assert back.to_dict() == d


def test_prometheus_exposition_format_and_escaping():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests by outcome").inc(
        2, path='a"b\\c\nd')
    for v in (1.0, 2.0, 2.0, 100.0):
        reg.histogram("lat_ms", "latency").observe(v)
    text = reg.to_prometheus()
    assert "# HELP req_total requests by outcome" in text
    assert "# TYPE req_total counter" in text
    # escaping order: backslash, then quote, then newline
    assert 'path="a\\"b\\\\c\\nd"' in text
    assert "# TYPE lat_ms histogram" in text
    assert 'lat_ms_bucket{le="+Inf"} 4' in text
    assert "lat_ms_sum 105" in text
    assert "lat_ms_count 4" in text
    # cumulative bucket counts are monotone and end at the total
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_ms_bucket")]
    assert cums == sorted(cums) and cums[-1] == 4


# -- TraceRecorder -----------------------------------------------------------

def test_span_nesting_and_chrome_trace_schema(tmp_path):
    rec = TraceRecorder()
    rec.name_thread("test-main")
    with rec.span("outer", cat="t", args={"a": 1}) as sp:
        sp.event("marker", {"k": "v"})
        with rec.span("inner", cat="t"):
            pass
        sp.add_args(b=2)
    rec.async_begin("request", 7, args={"deadline_ms": 5})
    rec.async_end("request", 7, args={"outcome": "served"})
    trace = rec.to_chrome_trace()
    ct = _load_check_trace()
    assert ct.validate(trace, require=["outer", "inner", "marker",
                                       "request"]) == []
    byname = {e["name"]: e for e in trace["traceEvents"]}
    outer, inner = byname["outer"], byname["inner"]
    assert outer["ph"] == inner["ph"] == "X"
    assert outer["args"] == {"a": 1, "b": 2}
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 0.5
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "M"}
    assert names == {"process_name", "thread_name"}
    # write() output parses back to the same thing
    p = tmp_path / "t.json"
    rec.write(str(p))
    assert ct.validate(json.loads(p.read_text())) == []


def test_trace_ring_buffer_bounds_memory():
    rec = TraceRecorder(max_events=100)
    for i in range(500):
        rec.instant(f"e{i}")
    assert rec.n_events == 100
    assert rec.dropped_events == 400
    kept = [e["name"] for e in rec.events()]
    assert kept[0] == "e400" and kept[-1] == "e499"  # oldest dropped first


def test_check_trace_rejects_malformed_traces():
    ct = _load_check_trace()
    # partial overlap = malformed nesting
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": 10},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5, "dur": 10},
    ]}
    assert any("partially overlaps" in e for e in ct.validate(bad))
    # async begin without end
    bad = {"traceEvents": [
        {"name": "r", "ph": "b", "cat": "q", "id": 1, "pid": 1, "tid": 1,
         "ts": 0},
    ]}
    assert any("begin without end" in e for e in ct.validate(bad))
    assert ct.validate({"nope": []})  # wrong top level


def test_null_tracer_is_shared_noop():
    assert NULL_TRACER.enabled is False
    s1 = NULL_TRACER.span("x")
    s2 = NULL_TRACER.span("y")
    assert s1 is s2  # shared singleton, zero allocation
    with s1 as sp:
        sp.add_args(a=1)
        sp.event("e")
    NULL_TRACER.instant("i")
    NULL_TRACER.async_begin("r", 1)
    assert NULL_TRACER.n_events == 0


def test_device_annotation_smoke():
    # enabled=False must not even resolve jax.profiler
    with device_annotation("x", enabled=False):
        pass
    with device_annotation("ann_dispatch/bucket8", enabled=True):
        pass  # nullcontext fallback when the profiler is unavailable


# -- engine + coalescer integration ------------------------------------------

def test_engine_search_records_spans_metrics_and_telemetry(ds, index):
    obs = Observability(tracing=True, metrics=True)
    engine = index.serve(PARAMS, bucket_sizes=BUCKETS, obs=obs)
    res = engine.search(ds.queries[:3], gt_ids=ds.gt_ids[:3])
    assert res.ids.shape[0] == 3

    names = [e["name"] for e in obs.tracer.events()]
    assert "engine.search" in names
    assert "device_compute" in names and "postprocess" in names
    ct = _load_check_trace()
    assert ct.validate(obs.tracer.to_chrome_trace(),
                       require=["engine.search", "device_compute"]) == []

    d = obs.registry.to_dict()
    # convergence telemetry: one per-lane histogram per SearchStats leaf
    for field in ("steps", "crit_rounds", "dist_comps", "uniq_comps",
                  "batch_dup_comps"):
        series = d[f"ann_{field}"]["series"]
        assert series[0]["labels"] == {"backend": "ref", "bucket": "4"}
        assert series[0]["histogram"]["count"] == 3  # one obs per lane
    assert d["serve_request_latency_ms"]["series"][0]["histogram"][
        "count"] == 1


def test_engine_stats_schema_bounded_memory_and_key_order(ds, index):
    engine = index.serve(PARAMS, bucket_sizes=BUCKETS)
    for i in range(4):
        engine.search(ds.queries[:1 + i % 2], gt_ids=ds.gt_ids[:1 + i % 2])
    m = engine.stats()
    keys = list(m)
    head = ["queries_served", "requests_served", "padded_queries",
            "jit_cache_size", "cache_hits", "cache_misses",
            "dist_comps_total", "uniq_comps_total", "batch_dup_comps_total",
            "batch_dup_ratio"]
    assert keys[:len(head)] == head
    lat = ["latency_mean_ms", "latency_p50_ms", "latency_p90_ms",
           "latency_p95_ms", "latency_p99_ms", "latency_max_ms"]
    assert keys[len(head):len(head) + len(lat)] == lat
    # per-bucket blocks ascend, each led by its chunks counter
    bucket_keys = [k for k in keys if k.startswith("bucket")]
    served = sorted(int(k[len("bucket"):-len("_chunks")])
                    for k in bucket_keys if k.endswith("_chunks"))
    assert served == [1, 2]
    assert bucket_keys[0] == "bucket1_chunks"
    assert bucket_keys[7] == "bucket2_chunks"
    assert keys[-1] == "recall_at_k"
    assert m["latency_p99_ms"] <= m["latency_max_ms"]
    # metrics() alias and the live-sketch accessor agree
    assert engine.metrics() == engine.stats()
    hists = engine.latency_histograms()
    assert set(hists) == {"request", "bucket1", "bucket2"}
    assert hists["request"].count == 4
    # bounded memory: the sketch, not a sample list, backs the stats
    assert hists["request"].n_buckets <= hists["request"].max_buckets


def test_disabled_obs_writes_nothing(ds, index):
    # default = NULL_OBS: no trace events, no registry series, ever
    engine = index.serve(PARAMS, bucket_sizes=BUCKETS)
    assert engine.obs is NULL_OBS
    engine.search(ds.queries[:2])
    assert NULL_OBS.tracer.n_events == 0
    assert NULL_OBS.registry.to_dict() == {}
    # explicit all-off bundle on the engine's own registry: also untouched
    obs = Observability(tracing=False, metrics=False)
    engine2 = index.serve(PARAMS, bucket_sizes=BUCKETS, obs=obs)
    engine2.search(ds.queries[:2])
    assert obs.tracer.n_events == 0
    assert obs.registry.to_dict() == {}
    assert obs.enabled is False


def test_coalesced_span_tree_under_manual_flush(ds, index):
    obs = Observability(tracing=True, metrics=True)
    srv = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS,
                            obs=obs)
    futs = [srv.submit(q) for q in ds.queries[:3]]
    assert srv.flush() == 3
    ids = np.stack([f.result().ids for f in futs])
    assert ids.shape == (3, 10)
    srv.close()

    trace = obs.tracer.to_chrome_trace()
    ct = _load_check_trace()
    assert ct.validate(trace, require=[
        "batch_formation", "dispatch", "engine.search", "device_compute",
        "resolve", "request"]) == []
    ev = trace["traceEvents"]
    # one coalesced batch: dispatch contains engine.search by containment
    disp = next(e for e in ev if e["name"] == "dispatch")
    srch = next(e for e in ev if e["name"] == "engine.search")
    assert disp["ts"] <= srch["ts"]
    assert srch["ts"] + srch["dur"] <= disp["ts"] + disp["dur"] + 0.5
    form = next(e for e in ev if e["name"] == "batch_formation")
    assert form["args"]["batch"] == 3
    assert sorted(form["args"]["edf_order"]) == [0, 1, 2]
    # every submitted request has a paired b/e lifeline ending "served"
    begins = [e for e in ev if e["ph"] == "b" and e["name"] == "request"]
    ends = [e for e in ev if e["ph"] == "e" and e["name"] == "request"]
    assert len(begins) == len(ends) == 3
    assert all(e["args"]["outcome"] == "served" for e in ends)
    # registry: served outcomes + queue-wait sketch
    d = obs.registry.to_dict()
    served = [s for s in d["coalescer_requests_total"]["series"]
              if s["labels"] == {"outcome": "served"}]
    assert served[0]["value"] == 3.0
    assert d["coalescer_queue_wait_ms"]["series"][0]["histogram"][
        "count"] == 3
    # coalescer stats stay sketch-backed with the same key schema
    st = srv.stats()
    for key in ("batch_size_mean", "queue_wait_p50_ms", "queue_wait_p99_ms"):
        assert key in st


def test_deadline_shed_emits_span_event_and_counter(ds, index):
    obs = Observability(tracing=True, metrics=True)
    srv = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS,
                            obs=obs)
    fut = srv.submit(ds.queries[0], deadline_ms=0.001)
    import time as _t
    _t.sleep(0.01)
    srv.flush()
    with pytest.raises(Exception):
        fut.result(timeout=5)
    srv.close()
    sheds = [e for e in obs.tracer.events() if e["name"] == "deadline_shed"]
    assert sheds and "late_ms" in sheds[0]["args"]
    ends = [e for e in obs.tracer.events()
            if e["ph"] == "e" and e["args"].get("outcome") == "shed"]
    assert len(ends) == 1
    d = obs.registry.to_dict()
    shed = [s for s in d["coalescer_requests_total"]["series"]
            if s["labels"] == {"outcome": "shed"}]
    assert shed[0]["value"] == 1.0
