"""Batched ANN serving engine: bucketing, jit-cache reuse, parity.

The engine must be *transparent*: a mixed-size query stream produces exactly
the results of direct (unbatched/unbucketed) search, while the jit cache
grows with the number of distinct buckets touched — never with the number of
calls.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SearchConfig
from repro.core import build_nsg, search_speedann_batch
from repro.core.speedann import search_speedann
from repro.data import make_vector_dataset
from repro.serve import AnnEngine

BUCKETS = (1, 2, 4, 8)


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=1000, n_queries=16, k=10, dim=24,
                               n_clusters=8, seed=1)


@pytest.fixture(scope="module")
def graph(ds):
    return build_nsg(ds.base, degree=12, knn_k=12, ef_construction=24,
                     passes=1)


CFG = SearchConfig(k=10, queue_len=32, m_max=4, num_walkers=4, max_steps=64,
                   local_steps=4)


def test_mixed_stream_matches_unbatched_search(ds, graph):
    """Bucketed+padded serving returns per-query results identical to the
    plain searcher for every batch size in a fluctuating stream, including
    one larger than the top bucket (served in chunks)."""
    engine = AnnEngine(graph, CFG, bucket_sizes=BUCKETS)
    stream = (1, 3, 7, 4, 2, 8, 11)
    for bsz in stream:
        q = ds.queries[:bsz]
        res = engine.search(q, gt_ids=ds.gt_ids[:bsz])
        assert res.ids.shape == (bsz, CFG.k)
        direct_ids, direct_d, _ = search_speedann_batch(
            graph, jnp.asarray(q), CFG)
        np.testing.assert_array_equal(res.ids, np.asarray(direct_ids))
        np.testing.assert_array_equal(res.dists, np.asarray(direct_d))
        # stats leaves are sliced back to the true batch size too
        assert np.asarray(res.stats.steps).shape == (bsz,)
    m = engine.metrics()
    assert m["queries_served"] == sum(stream)
    assert m["requests_served"] == len(stream)
    assert m["recall_at_k"] >= 0.9


def test_single_query_matches_single_search(ds, graph):
    engine = AnnEngine(graph, CFG, bucket_sizes=BUCKETS)
    res = engine.search(ds.queries[:1])
    ids, dists, _ = search_speedann(graph, jnp.asarray(ds.queries[0]), CFG)
    np.testing.assert_array_equal(res.ids[0], np.asarray(ids))


def test_jit_cache_entries_equal_buckets_not_calls(ds, graph):
    """Many calls, few shapes: cache size == distinct buckets touched."""
    engine = AnnEngine(graph, CFG, bucket_sizes=BUCKETS)
    stream = (3, 3, 4, 3, 4, 1, 3, 4, 1, 3)   # 10 calls, buckets {4, 1}
    for bsz in stream:
        engine.search(ds.queries[:bsz])
    assert engine.jit_cache_size == 2
    assert engine.metrics()["cache_misses"] == 2
    assert engine.metrics()["cache_hits"] == len(stream) - 2
    # oversize batch -> top bucket only (one new entry, chunked serving)
    engine.search(ds.queries[:11])
    assert engine.jit_cache_size == 3


def test_warmup_precompiles_every_bucket(ds, graph):
    engine = AnnEngine(graph, CFG, bucket_sizes=BUCKETS)
    compile_s = engine.warmup(ds.base.shape[1])
    assert set(compile_s) == set(BUCKETS)
    assert engine.jit_cache_size == len(BUCKETS)
    # warmup never counts as served traffic
    m = engine.metrics()
    assert m["queries_served"] == 0 and m["cache_misses"] == 0
    engine.search(ds.queries[:5])
    assert engine.metrics()["cache_misses"] == 0   # all warm


def test_bucket_for_quantization(ds, graph):
    engine = AnnEngine(graph, CFG, bucket_sizes=BUCKETS)
    assert [engine.bucket_for(b) for b in (1, 2, 3, 4, 5, 8, 9, 100)] == \
        [1, 2, 4, 4, 8, 8, 8, 8]


def test_rejects_bad_arguments(ds, graph):
    with pytest.raises(ValueError, match="unknown algorithm"):
        AnnEngine(graph, CFG, algorithm="annoy")
    engine = AnnEngine(graph, CFG, bucket_sizes=BUCKETS)
    with pytest.raises(ValueError, match="queries must be"):
        engine.search(ds.queries[0])
    with pytest.raises(ValueError, match="queries must be"):
        engine.search(np.zeros((0, ds.base.shape[1]), np.float32))


def test_engine_with_kernel_backend(ds, graph):
    """The serving layer composes with the distance-backend seam."""
    cfg = CFG.with_(dist_backend="dma", m_max=3)   # 3*12 % 8 != 0: padded
    ref = AnnEngine(graph, cfg.with_(dist_backend="ref"),
                    bucket_sizes=BUCKETS, algorithm="topm")
    eng = AnnEngine(graph, cfg, bucket_sizes=BUCKETS, algorithm="topm")
    got = eng.search(ds.queries[:6])
    want = ref.search(ds.queries[:6])
    np.testing.assert_array_equal(got.ids, want.ids)
