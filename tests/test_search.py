"""End-to-end search behaviour: BFiS, top-M, Speed-ANN (Algorithm 3).

Validates the paper's core claims at test scale:
  * all searchers reach high recall on an NSG-style index;
  * Speed-ANN converges in far fewer global steps than BFiS (Fig. 5);
  * staged search cuts distance computations vs fixed-M (Fig. 8);
  * adaptive sync computes less than no-sync (Table 2).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SearchConfig
from repro.core import (bfis_search_batch, build_nsg, build_hnsw,
                        hnsw_search_batch, recall_at_k, search_speedann_batch,
                        search_topm_batch, variant)
from repro.data import make_vector_dataset


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("sift", n=3000, n_queries=32, k=10, dim=32,
                               n_clusters=32, seed=0)


@pytest.fixture(scope="module")
def graph(ds):
    return build_nsg(ds.base, degree=24, knn_k=24, ef_construction=48,
                     passes=2)


BASE = SearchConfig(k=10, queue_len=64, m_max=4, num_walkers=4,
                    max_steps=256, local_steps=8, sync_ratio=0.8)


def test_bfis_reaches_high_recall(ds, graph):
    ids, dists, stats = bfis_search_batch(graph, jnp.asarray(ds.queries), BASE)
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert r >= 0.9, f"BFiS recall {r}"
    # distances are sorted and match exact distances for found ids
    d = np.asarray(dists)
    assert (np.diff(d, axis=1) >= -1e-5).all()


def test_topm_matches_bfis_recall_fewer_steps(ds, graph):
    q = jnp.asarray(ds.queries)
    _, _, s1 = bfis_search_batch(graph, q, BASE)
    ids, _, sm = search_topm_batch(graph, q, BASE.with_(m_max=4, staged=False))
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert r >= 0.9
    # Fig. 5: parallel expansion converges in fewer steps
    assert float(np.mean(np.asarray(sm.steps))) < \
        0.6 * float(np.mean(np.asarray(s1.steps)))


def test_staged_reduces_distance_comps(ds, graph):
    q = jnp.asarray(ds.queries)
    cfg = BASE.with_(m_max=8)
    _, _, s_fixed = search_topm_batch(graph, q, cfg.with_(staged=False))
    ids, _, s_staged = search_topm_batch(graph, q, cfg.with_(staged=True))
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert r >= 0.9
    # Fig. 8a: staging avoids over-expansion
    assert float(np.mean(np.asarray(s_staged.dist_comps))) < \
        float(np.mean(np.asarray(s_fixed.dist_comps)))


def test_speedann_recall_and_convergence(ds, graph):
    q = jnp.asarray(ds.queries)
    ids, dists, st = search_speedann_batch(graph, q, BASE)
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert r >= 0.9, f"Speed-ANN recall {r}"
    _, _, s1 = bfis_search_batch(graph, q, BASE)
    # global sync rounds << BFiS sequential steps (Fig. 5b analog)
    assert float(np.mean(np.asarray(st.steps))) < \
        0.5 * float(np.mean(np.asarray(s1.steps)))


def test_adaptive_sync_cheaper_than_nosync(ds, graph):
    q = jnp.asarray(ds.queries)
    cfg = BASE.with_(num_walkers=8, m_max=8)
    _, _, s_no = search_speedann_batch(graph, q, variant(cfg, "nosync"))
    ids, _, s_ad = search_speedann_batch(graph, q, variant(cfg, "adaptive"))
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert r >= 0.9
    # Table 2: adaptive sync does fewer distance computations than no-sync
    assert float(np.mean(np.asarray(s_ad.dist_comps))) <= \
        float(np.mean(np.asarray(s_no.dist_comps)))


def test_hnsw_baseline(ds):
    idx = build_hnsw(ds.base, degree=24)
    ids, _, _ = hnsw_search_batch(idx, jnp.asarray(ds.queries), BASE)
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert r >= 0.9, f"HNSW recall {r}"


@pytest.mark.parametrize("mode", ["bitmap", "hash", "loose"])
def test_visited_modes_agree_on_recall(ds, graph, mode):
    q = jnp.asarray(ds.queries)
    ids, _, st = search_speedann_batch(
        graph, q, BASE.with_(visited_mode=mode))
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    assert r >= 0.85, f"{mode} recall {r}"


def test_results_sorted_and_exact_distances(ds, graph):
    q = jnp.asarray(ds.queries)
    ids, dists, _ = search_speedann_batch(graph, q, BASE)
    ids, dists = np.asarray(ids), np.asarray(dists)
    for b in range(ids.shape[0]):
        for j in range(ids.shape[1]):
            if ids[b, j] >= ds.base.shape[0]:
                continue
            exact = float(((ds.base[ids[b, j]] - ds.queries[b]) ** 2).sum())
            assert abs(exact - float(dists[b, j])) < 1e-2 * max(exact, 1.0)
