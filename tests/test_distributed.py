"""Distributed-search integration (runs in a subprocess with 8 forced host
devices, so the main pytest process keeps the default single device)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_distributed_search_all_modes():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_check.py")],
        capture_output=True, text=True, timeout=540, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL_DISTRIBUTED_OK" in out.stdout, out.stdout + out.stderr
