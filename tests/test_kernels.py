"""Pallas-kernel validation: shape/dtype sweeps + hypothesis properties,
all against the pure-jnp oracles in kernels/ref.py (interpret mode on CPU).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import l2dist, sort_pairs, topl_merge
from repro.kernels import ref as kref
from repro.core import queue as fq


def _mk(n, d, b, c, dtype, seed=0):
    rng = np.random.RandomState(seed)
    table = rng.normal(size=(n, d)).astype(dtype)
    ids = rng.randint(0, n + 1, size=(b, c)).astype(np.int32)  # incl. padding
    q = rng.normal(size=(b, d)).astype(dtype)
    return jnp.asarray(table), jnp.asarray(ids), jnp.asarray(q)


@pytest.mark.parametrize("impl", ["rowgather", "dma"])
@pytest.mark.parametrize("n,d,b,c", [
    (64, 8, 2, 16),
    (128, 128, 1, 32),
    (257, 96, 3, 8),     # non-power-of-two N, DEEP dims
    (50, 960, 1, 8),     # GIST dims
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_l2dist_matches_ref(impl, n, d, b, c, dtype):
    table, ids, q = _mk(n, d, b, c, dtype)
    got = l2dist(table, ids, q, impl=impl)
    want = kref.l2dist_ref(table, ids, q)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@given(
    n=st.integers(4, 300),
    c=st.sampled_from([8, 16, 24]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_l2dist_property_padding_and_nonneg(n, c, seed):
    table, ids, q = _mk(n, 16, 2, c, np.float32, seed=seed % 1000)
    got = np.asarray(l2dist(table, ids, q, impl="rowgather"))
    # padding ids -> +inf; real ids -> finite, non-negative
    assert np.isinf(got[np.asarray(ids) >= n]).all()
    real = got[np.asarray(ids) < n]
    assert (real >= -1e-4).all() and np.isfinite(real).all()


@pytest.mark.parametrize("n", [8, 64, 256, 1024])
def test_bitonic_sort_matches_lax_sort(n):
    rng = np.random.RandomState(n)
    b = 3
    keys = rng.normal(size=(b, n)).astype(np.float32)
    keys[0, :3] = np.inf                      # inf handling
    keys[1, 1] = keys[1, 2] = keys[1, 3]      # ties -> payload order
    p0 = rng.randint(0, 2**30, size=(b, n)).astype(np.int32)
    p1 = rng.randint(0, 4, size=(b, n)).astype(np.int32)
    ks, p0s, p1s = sort_pairs(jnp.asarray(keys), jnp.asarray(p0),
                              jnp.asarray(p1))
    wk, wp0, wp1 = kref.sort_pairs_ref(jnp.asarray(keys), jnp.asarray(p0),
                                       jnp.asarray(p1))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(p0s), np.asarray(wp0))
    # p1 may differ only where (key, p0) has full ties
    tie = np.asarray(wk[:, 1:] == wk[:, :-1]) & np.asarray(
        wp0[:, 1:] == wp0[:, :-1])
    if not tie.any():
        np.testing.assert_array_equal(np.asarray(p1s), np.asarray(wp1))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_bitonic_is_permutation_and_sorted(seed):
    rng = np.random.RandomState(seed)
    keys = rng.normal(size=(2, 128)).astype(np.float32)
    p0 = rng.permutation(128).astype(np.int32)[None, :].repeat(2, 0)
    p1 = np.zeros((2, 128), np.int32)
    ks, p0s, _ = sort_pairs(jnp.asarray(keys), jnp.asarray(p0),
                            jnp.asarray(p1))
    ks = np.asarray(ks)
    assert (np.diff(ks, axis=1) >= 0).all()
    for r in range(2):
        assert sorted(np.asarray(p0s)[r].tolist()) == sorted(p0[r].tolist())


def _random_frontier_batch(rng, b, l):
    """Random sorted frontiers with some empty slots."""
    dists = np.sort(rng.uniform(0.0, 10.0, size=(b, l)).astype(np.float32), 1)
    ids = np.zeros((b, l), np.int32)
    for r in range(b):
        ids[r] = rng.choice(10_000, size=l, replace=False).astype(np.int32)
    meta = rng.randint(0, 2, size=(b, l)).astype(np.int32)
    n_empty = rng.randint(0, l // 2)
    if n_empty:
        dists[:, l - n_empty:] = np.inf
        ids[:, l - n_empty:] = 2**31 - 1
        meta[:, l - n_empty:] = 1
    return dists, ids, meta


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_topl_merge_matches_queue_insert(seed):
    """The bitonic merge is semantically identical to core.queue.insert."""
    rng = np.random.RandomState(seed)
    b, l, c = 2, 16, 12
    qd, qi, qm = _random_frontier_batch(rng, b, l)
    cd = rng.uniform(0.0, 10.0, size=(b, c)).astype(np.float32)
    ci = rng.choice(10_000, size=(b, c)).astype(np.int32)
    # make some candidates duplicates of queue entries (same dist!)
    for r in range(b):
        for j in range(3):
            src = rng.randint(0, l)
            if qi[r, src] != 2**31 - 1:
                ci[r, j] = qi[r, src]
                cd[r, j] = qd[r, src]

    d2, i2, m2, up = topl_merge(
        jnp.asarray(qd), jnp.asarray(qi), jnp.asarray(qm),
        jnp.asarray(cd), jnp.asarray(ci))

    for r in range(b):
        f = fq.Frontier(ids=jnp.asarray(qi[r]), dists=jnp.asarray(qd[r]),
                        checked=jnp.asarray(qm[r] == 1))
        f2, up_ref, _ = fq.insert(f, jnp.asarray(ci[r]), jnp.asarray(cd[r]))
        np.testing.assert_array_equal(np.asarray(i2[r]), np.asarray(f2.ids))
        np.testing.assert_allclose(np.asarray(d2[r]), np.asarray(f2.dists))
        assert int(up[r]) == int(up_ref)
        got_checked = np.asarray(m2[r] == 1) | (np.asarray(i2[r]) == 2**31 - 1)
        np.testing.assert_array_equal(got_checked, np.asarray(f2.checked))


def test_search_with_pallas_dist_fn_matches_default():
    """End-to-end: BFiS with the Pallas distance kernel == jnp reference."""
    from repro.config import SearchConfig
    from repro.core import bfis_search_batch, build_nsg
    from repro.data import make_vector_dataset
    from repro.kernels import make_dist_fn

    ds = make_vector_dataset("deep", n=800, n_queries=8, k=10, dim=24,
                             n_clusters=8, seed=3)
    g = build_nsg(ds.base, degree=12, knn_k=12, ef_construction=24, passes=1)
    cfg = SearchConfig(k=10, queue_len=32, max_steps=128)
    q = jnp.asarray(ds.queries)
    ids_ref, d_ref, _ = bfis_search_batch(g, q, cfg)
    ids_pal, d_pal, _ = bfis_search_batch(
        g, q, cfg, dist_fn=make_dist_fn("rowgather"))
    np.testing.assert_array_equal(np.asarray(ids_ref), np.asarray(ids_pal))
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_pal),
                               rtol=1e-5, atol=1e-5)


# -- pad_ids_to_tile + dedup unique-pass edge cases -------------------------

def test_pad_ids_to_tile_edges():
    from repro.kernels.registry import pad_ids_to_tile

    # exact tile boundary: returned array IS the input (no copy, no pad)
    ids = jnp.arange(16, dtype=jnp.int32).reshape(2, 8)
    assert pad_ids_to_tile(ids, 8, 100) is ids
    # ragged: padded with the n_nodes sentinel on the last axis only
    ids = jnp.arange(10, dtype=jnp.int32).reshape(2, 5)
    out = pad_ids_to_tile(ids, 8, 100)
    assert out.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out)[:, :5], np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(out)[:, 5:], 100)
    # 1D buffers (the dedup unique buffer) pad the same way
    out1 = pad_ids_to_tile(jnp.arange(3, dtype=jnp.int32), 8, 7)
    assert out1.shape == (8,)
    np.testing.assert_array_equal(np.asarray(out1)[3:], 7)


def test_dedup_unique_empty_after_masking():
    """All-padding candidate grids (every id >= n_nodes) leave an EMPTY
    unique set: the buffer is pure sentinel and distances all +inf."""
    from repro.kernels.dedup import dedupdist, unique_ids_inverse

    n, d, b, c = 20, 8, 3, 5
    ids = jnp.full((b, c), n + 2, jnp.int32)
    uniq, inv, n_uniq = unique_ids_inverse(ids, n)
    assert int(n_uniq) == 0
    assert (np.asarray(uniq) >= n).all()
    table = jnp.asarray(np.random.RandomState(0).randn(n, d), jnp.float32)
    q = jnp.asarray(np.random.RandomState(1).randn(b, d), jnp.float32)
    assert np.isinf(np.asarray(dedupdist(table, ids, q))).all()


def test_dedup_unique_count_on_tile_boundary():
    """Unique count exactly at a tile multiple: no sentinel slot is added
    beyond the buffer's fixed size, and the buffer stays tile-aligned."""
    from repro.kernels.dedup import unique_ids_inverse

    ids = jnp.arange(8, dtype=jnp.int32).reshape(1, 8)      # 8 distinct
    uniq, inv, n_uniq = unique_ids_inverse(ids, 100, tile=8)
    assert int(n_uniq) == 8 and uniq.shape == (8,)
    np.testing.assert_array_equal(np.asarray(uniq), np.arange(8))
    np.testing.assert_array_equal(np.asarray(inv), np.arange(8)[None, :])


def test_dedup_n_nodes_smaller_than_tile():
    """n_nodes < tile: clamping in the kernel index_map and sentinel
    padding still agree with the reference."""
    from repro.kernels.dedup import dedupdist
    from repro.kernels.l2dist import l2dist_rowgather

    rng = np.random.RandomState(2)
    n, d, b, c = 3, 8, 2, 5                                # n < TILE=8
    table = jnp.asarray(rng.randn(n, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, d), jnp.float32)
    ids = jnp.asarray(rng.randint(0, n + 2, size=(b, c)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(dedupdist(table, ids, q)),
        np.asarray(l2dist_rowgather(table, ids, q)))
