"""Batched graph construction: parity, determinism, incremental add/delete.

The contract under test (core/build.py): ``build_batch`` and
``build_backend`` are COMPUTE TILES, never semantics — the built graph is
bit-identical to the scalar per-point reference builder for every batch
size, every within-batch permutation, and across repeated runs.  On top of
that, the incremental paths (``AnnIndex.add`` / ``.delete``) must keep the
index consistent end to end: recall within 0.02 of a from-scratch rebuild,
tombstoned ids excluded from every search/exact result, quant codes/scales
and the npz round-trip intact after mutation.
"""
import numpy as np
import pytest

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.core.build import (_upper_level_ids, build_nsg, build_nsg_serial,
                              exact_knn, knn_graph)
from repro.core.graph import remap_sentinels

DEGREE = 8
EF = 16
N = 160
DIM = 12


def _data(n=N, dim=DIM, seed=0):
    return np.random.RandomState(seed).randn(n, dim).astype(np.float32)


def _graph_bytes(g):
    return np.asarray(g.nbrs).tobytes() + np.asarray(g.medoid).tobytes()


def _recall(index, queries, params, k=5):
    res = index.search(queries, params)
    gt, _ = index.exact(queries, k)
    ids = np.asarray(res.ids)
    return sum(len(set(r) & set(g))
               for r, g in zip(ids.tolist(), gt.tolist())) / gt.size


# ---------------------------------------------------------------------------
# bit-parity + determinism of the batched builder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("passes", [1, 2])
def test_batch1_matches_serial_reference(metric, passes):
    data = _data()
    kw = dict(degree=DEGREE, ef_construction=EF, alpha=1.2, seed=0,
              passes=passes, metric=metric)
    serial = build_nsg_serial(data, **kw)
    batched = build_nsg(data, build_batch=1, **kw)
    assert _graph_bytes(batched) == _graph_bytes(serial)


def test_batch_size_and_permutation_invariance():
    data = _data()
    kw = dict(degree=DEGREE, ef_construction=EF, alpha=1.2, seed=0,
              passes=2)
    ref = _graph_bytes(build_nsg(data, build_batch=1, **kw))
    for batch in (7, 64):
        assert _graph_bytes(build_nsg(data, build_batch=batch, **kw)) == ref
    # permuting every search chunk must not change a bit (per-lane
    # independence of the batch-major engine), nor may a re-run
    assert _graph_bytes(build_nsg(data, build_batch=32, batch_perm=3,
                                  **kw)) == ref
    assert _graph_bytes(build_nsg(data, build_batch=32, **kw)) == ref


def test_built_graph_recall():
    data = _data(n=300)
    index = AnnIndex.build(data, IndexSpec(degree=12, ef_construction=24))
    r = _recall(index, data[:32], SearchParams(k=5, queue_len=32,
                                               max_steps=64))
    assert r >= 0.9, f"batched build recall {r}"


# ---------------------------------------------------------------------------
# incremental add
# ---------------------------------------------------------------------------

def test_add_recall_close_to_rebuild():
    rng = np.random.RandomState(1)
    data = rng.randn(320, DIM).astype(np.float32)
    extra = rng.randn(40, DIM).astype(np.float32)
    full = np.concatenate([data, extra])
    spec = IndexSpec(degree=DEGREE, ef_construction=2 * EF)
    params = SearchParams(k=5, queue_len=32, max_steps=64)

    inc = AnnIndex.build(data, spec)
    new_ids = inc.add(extra)
    assert new_ids.tolist() == list(range(320, 360))
    assert inc.n_nodes == 360 and inc.n_alive == 360

    rebuilt = AnnIndex.build(full, spec)
    r_inc = _recall(inc, full[:48], params)
    r_full = _recall(rebuilt, full[:48], params)
    assert r_inc >= r_full - 0.02, (r_inc, r_full)

    # added vectors must be findable as their own nearest neighbor
    res = inc.search(extra[:16], params)
    found = np.asarray(res.ids)[:, 0]
    assert (found == np.arange(320, 336)).mean() >= 0.8


def test_add_cosine_normalizes():
    rng = np.random.RandomState(2)
    data = rng.randn(200, DIM).astype(np.float32)
    extra = 50.0 * rng.randn(10, DIM).astype(np.float32)  # wild norms
    index = AnnIndex.build(data, IndexSpec(degree=DEGREE, metric="cosine",
                                           ef_construction=EF))
    index.add(extra)
    norms = np.linalg.norm(np.asarray(index.graph.vectors)[200:], axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)


def test_add_quant_preserves_existing_codes_and_roundtrips(tmp_path):
    rng = np.random.RandomState(3)
    data = rng.randn(240, DIM).astype(np.float32)
    extra = rng.randn(24, DIM).astype(np.float32)
    spec = IndexSpec(degree=DEGREE, ef_construction=EF, quant="int8")
    index = AnnIndex.build(data, spec)
    before = np.asarray(index.graph.codes).copy()
    index.add(extra)
    # per-vector scales: old rows' codes must be bit-untouched
    np.testing.assert_array_equal(np.asarray(index.graph.codes)[:240],
                                  before)
    assert index.graph.codes.shape == (264, DIM)
    assert index.graph.scales.shape == (264, 1)

    params = SearchParams(k=5, queue_len=32, max_steps=64,
                          backend="ref_int8", rerank_k=16)
    path = index.save(str(tmp_path / "inc_quant"))
    loaded = AnnIndex.load(path)
    q = data[:8]
    np.testing.assert_array_equal(np.asarray(index.search(q, params).ids),
                                  np.asarray(loaded.search(q, params).ids))


def test_add_rejects_hnsw_and_bad_shapes():
    data = _data(n=120)
    hn = AnnIndex.build(data, IndexSpec(builder="hnsw", degree=DEGREE))
    with pytest.raises(NotImplementedError):
        hn.add(data[:2])
    index = AnnIndex.build(data, IndexSpec(degree=DEGREE,
                                           ef_construction=EF))
    with pytest.raises(ValueError):
        index.add(np.zeros((2, DIM + 1), np.float32))
    assert index.add(np.zeros((0, DIM), np.float32)).shape == (0,)


# ---------------------------------------------------------------------------
# incremental delete
# ---------------------------------------------------------------------------

def test_delete_excludes_tombstoned_ids():
    data = _data(n=300, seed=4)
    index = AnnIndex.build(data, IndexSpec(degree=12, ef_construction=24))
    params = SearchParams(k=5, queue_len=48, max_steps=96)
    queries = data[:16]
    dead = np.unique(np.asarray(index.exact(queries, 2)[0]).ravel())
    assert index.delete(dead) == dead.shape[0]
    assert index.n_alive == 300 - dead.shape[0]
    # idempotent: deleting again is a no-op
    assert index.delete(dead) == 0

    ids = np.asarray(index.search(queries, params).ids)
    assert not np.isin(ids, dead).any()
    gt, _ = index.exact(queries, 5)
    assert not np.isin(gt, dead).any()
    # the graph stays navigable around the holes
    r = _recall(index, queries, params)
    assert r >= 0.85, f"post-delete recall {r}"


def test_delete_medoid_reelects_entry(tmp_path):
    data = _data(n=200, seed=5)
    index = AnnIndex.build(data, IndexSpec(degree=DEGREE,
                                           ef_construction=EF))
    params = SearchParams(k=5, queue_len=32, max_steps=64)
    med = int(index.graph.medoid)
    index.delete([med])
    assert int(index.graph.medoid) != med
    ids = np.asarray(index.search(data[:8], params).ids)
    assert not np.isin(ids, [med]).any()

    # tombstones survive the npz round-trip (format 3)
    path = index.save(str(tmp_path / "tomb"))
    loaded = AnnIndex.load(path)
    assert loaded.n_alive == index.n_alive
    np.testing.assert_array_equal(
        np.asarray(loaded.search(data[:8], params).ids), ids)


def test_delete_refuses_everything():
    data = _data(n=50, seed=6)
    index = AnnIndex.build(data, IndexSpec(degree=DEGREE,
                                           ef_construction=EF))
    with pytest.raises(ValueError):
        index.delete(np.arange(50))


# ---------------------------------------------------------------------------
# satellites: knn_graph vectorization, sentinel remapping, hnsw upper ids
# ---------------------------------------------------------------------------

def test_knn_graph_matches_loop_reference():
    data = _data(n=90, seed=7)
    k = 6
    got = knn_graph(data, k)
    ids, _ = exact_knn(data, data, k + 1)
    n = data.shape[0]
    want = np.full((n, k), n, np.int32)
    for i in range(n):
        row = [j for j in ids[i] if j != i][:k]
        want[i, :len(row)] = row
    np.testing.assert_array_equal(got, want)


def test_remap_sentinels():
    nbrs = np.asarray([[0, 5, 3], [2, -1, 9]], np.int32)
    got = remap_sentinels(nbrs, old_n=5, new_n=8)
    np.testing.assert_array_equal(
        got, np.asarray([[0, 8, 3], [2, 8, 8]], np.int32))


def test_upper_level_ids_sentinel_never_aliases():
    members = np.asarray([4, 9, 17], np.int32)
    sub_knn = np.asarray([[1, 2, 3], [0, 3, 3]], np.int32)  # 3 == sub-sentinel
    got = _upper_level_ids(sub_knn, members, n=20)
    np.testing.assert_array_equal(
        got, np.asarray([[9, 17, 20], [4, 20, 20]], np.int32))
