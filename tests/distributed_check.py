"""Subprocess body for distributed-search tests (needs 8 host devices).

Run directly:  XLA must be configured BEFORE jax import, hence this file.
Prints "OK <name>" lines; the pytest wrapper asserts on them.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402
import numpy as np                                # noqa: E402

from repro.ann import AnnIndex, IndexSpec, SearchParams  # noqa: E402
from repro.config import SearchConfig             # noqa: E402
from repro.core import build_nsg, recall_at_k, search_speedann_batch  # noqa: E402
from repro.core.distributed import (build_partitioned,                # noqa: E402
                                    build_partitioned_index,
                                    corpus_sharded_search,
                                    make_search_mesh,
                                    walker_sharded_search)
from repro.data import make_vector_dataset        # noqa: E402
from repro.serve import AnnEngine                 # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_search_mesh((2, 4), ("data", "model"))

    ds = make_vector_dataset("sift", n=2000, n_queries=16, k=10, dim=24,
                             n_clusters=16, seed=1)
    graph = build_nsg(ds.base, degree=16, knn_k=16, ef_construction=32,
                      passes=1)
    cfg = SearchConfig(k=10, queue_len=64, m_max=4, num_walkers=4,
                       max_steps=64, local_steps=8, sync_ratio=0.8,
                       global_rounds=24)
    q = jnp.asarray(ds.queries)

    # --- walker-sharded Speed-ANN over the model axis ---
    ids, dists, stats = walker_sharded_search(graph, q, cfg, mesh)
    ids = np.asarray(ids)
    r = recall_at_k(ids, ds.gt_ids, 10)
    assert r >= 0.9, f"walker-sharded recall {r}"
    # distances ascending per query
    d = np.asarray(dists)
    assert (np.diff(d, axis=1) >= -1e-5).all()
    # sanity: it did parallel work and synchronized lazily
    st = {k: float(np.mean(np.asarray(v))) for k, v in stats._asdict().items()}
    assert st["syncs"] >= 1 and st["dist_comps"] > 10
    print(f"OK walker_sharded recall={r:.3f} stats={st}")

    # cross-check against the single-device (vmapped-walker) implementation
    ids1, _, st1 = search_speedann_batch(graph, q, cfg)
    r1 = recall_at_k(np.asarray(ids1), ds.gt_ids, 10)
    assert abs(r1 - r) < 0.1, (r1, r)
    print(f"OK walker_vs_local r_local={r1:.3f} r_dist={r:.3f}")

    # --- corpus-sharded search over the model axis ---
    idx = build_partitioned(ds.base, num_shards=4, degree=16, knn_k=16,
                            ef_construction=32, passes=1)
    gids, gd = corpus_sharded_search(
        idx, q, cfg.with_(m_max=1, staged=False), mesh)
    r2 = recall_at_k(np.asarray(gids), ds.gt_ids, 10)
    assert r2 >= 0.9, f"corpus-sharded recall {r2}"
    print(f"OK corpus_sharded recall={r2:.3f}")

    # --- multi-pod style 3D mesh lowers & runs: (pod, data, model) ---
    mesh3 = make_search_mesh((2, 2, 2), ("pod", "data", "model"))
    ids3, _, _ = walker_sharded_search(
        graph, q, cfg.with_(num_walkers=2), mesh3,
        data_axis="data", walker_axis="model")
    r3 = recall_at_k(np.asarray(ids3), ds.gt_ids, 10)
    assert r3 >= 0.85, f"3D-mesh recall {r3}"
    print(f"OK mesh3d recall={r3:.3f}")

    # --- engine-shaped serving over the same meshes (facade types in) ---
    # walker-sharded AnnEngine: bucketed serving where every bucket
    # dispatches through walker_sharded_search on a REAL multi-device mesh
    index = AnnIndex.build(ds, IndexSpec(degree=16, knn_k=16,
                                         ef_construction=32, passes=1))
    params = SearchParams(k=10, queue_len=64, m_max=4, num_walkers=4,
                          max_steps=64, local_steps=8, sync_ratio=0.8,
                          global_rounds=24, algorithm="sharded")
    engine = index.serve(params, mesh=mesh, bucket_sizes=(2, 4, 8, 16))
    gt_ids, _ = index.exact(ds.queries, 10)
    res = engine.search(ds.queries, gt_ids=gt_ids)   # 16 queries: bucket 16
    st = engine.stats()
    assert engine.mode == "sharded"
    assert st["recall_at_k"] >= 0.9, st
    assert "bucket16_p50_ms" in st
    print(f"OK walker_engine recall={st['recall_at_k']:.3f} "
          f"buckets={res.buckets}")

    # odd batch: padded to a bucket divisible by the data axis (2)
    res5 = engine.search(ds.queries[:5])
    assert res5.ids.shape == (5, 10) and res5.buckets == (8,)
    print("OK walker_engine_padding")

    # corpus-sharded AnnEngine on the 4-shard partitioned corpus
    sharded = build_partitioned_index(
        ds.base, num_shards=4,
        spec=IndexSpec(degree=16, knn_k=16, ef_construction=32, passes=1))
    ce = AnnEngine(sharded, SearchParams(k=10, queue_len=64, max_steps=384),
                   mesh=mesh, bucket_sizes=(2, 4, 8, 16))
    rc = ce.search(ds.queries)
    r4 = recall_at_k(rc.ids, ds.gt_ids, 10)
    assert ce.mode == "corpus"
    assert r4 >= 0.9, f"corpus-engine recall {r4}"
    print(f"OK corpus_engine recall={r4:.3f}")

    # async coalescer over the sharded engine: single submits, exact parity
    from repro.serve import AsyncAnnEngine, CoalescePolicy
    srv = AsyncAnnEngine(engine, CoalescePolicy(max_batch=16), start=False)
    futs = [srv.submit(q) for q in np.asarray(ds.queries[:4])]
    srv.flush()
    direct = index.search(ds.queries[:4], params, mesh=mesh)
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(f.result().ids,
                                      np.asarray(direct.ids)[i])
    srv.close()
    print("OK coalescer_over_sharded_engine")

    print("ALL_DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
