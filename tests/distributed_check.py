"""Subprocess body for distributed-search tests (needs 8 host devices).

Run directly:  XLA must be configured BEFORE jax import, hence this file.
Prints "OK <name>" lines; the pytest wrapper asserts on them.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402
import numpy as np                                # noqa: E402

from repro.config import SearchConfig             # noqa: E402
from repro.core import build_nsg, recall_at_k, search_speedann_batch  # noqa: E402
from repro.core.distributed import (build_partitioned,                # noqa: E402
                                    corpus_sharded_search,
                                    make_search_mesh,
                                    walker_sharded_search)
from repro.data import make_vector_dataset        # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_search_mesh((2, 4), ("data", "model"))

    ds = make_vector_dataset("sift", n=2000, n_queries=16, k=10, dim=24,
                             n_clusters=16, seed=1)
    graph = build_nsg(ds.base, degree=16, knn_k=16, ef_construction=32,
                      passes=1)
    cfg = SearchConfig(k=10, queue_len=64, m_max=4, num_walkers=4,
                       max_steps=64, local_steps=8, sync_ratio=0.8,
                       global_rounds=24)
    q = jnp.asarray(ds.queries)

    # --- walker-sharded Speed-ANN over the model axis ---
    ids, dists, stats = walker_sharded_search(graph, q, cfg, mesh)
    ids = np.asarray(ids)
    r = recall_at_k(ids, ds.gt_ids, 10)
    assert r >= 0.9, f"walker-sharded recall {r}"
    # distances ascending per query
    d = np.asarray(dists)
    assert (np.diff(d, axis=1) >= -1e-5).all()
    # sanity: it did parallel work and synchronized lazily
    st = {k: float(np.mean(np.asarray(v))) for k, v in stats._asdict().items()}
    assert st["syncs"] >= 1 and st["dist_comps"] > 10
    print(f"OK walker_sharded recall={r:.3f} stats={st}")

    # cross-check against the single-device (vmapped-walker) implementation
    ids1, _, st1 = search_speedann_batch(graph, q, cfg)
    r1 = recall_at_k(np.asarray(ids1), ds.gt_ids, 10)
    assert abs(r1 - r) < 0.1, (r1, r)
    print(f"OK walker_vs_local r_local={r1:.3f} r_dist={r:.3f}")

    # --- corpus-sharded search over the model axis ---
    idx = build_partitioned(ds.base, num_shards=4, degree=16, knn_k=16,
                            ef_construction=32, passes=1)
    gids, gd = corpus_sharded_search(
        idx, q, cfg.with_(m_max=1, staged=False), mesh)
    r2 = recall_at_k(np.asarray(gids), ds.gt_ids, 10)
    assert r2 >= 0.9, f"corpus-sharded recall {r2}"
    print(f"OK corpus_sharded recall={r2:.3f}")

    # --- multi-pod style 3D mesh lowers & runs: (pod, data, model) ---
    mesh3 = make_search_mesh((2, 2, 2), ("pod", "data", "model"))
    ids3, _, _ = walker_sharded_search(
        graph, q, cfg.with_(num_walkers=2), mesh3,
        data_axis="data", walker_axis="model")
    r3 = recall_at_k(np.asarray(ids3), ds.gt_ids, 10)
    assert r3 >= 0.85, f"3D-mesh recall {r3}"
    print(f"OK mesh3d recall={r3:.3f}")

    print("ALL_DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
