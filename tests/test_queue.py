"""Frontier-queue invariants (insert/dedup/select/merge)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import queue as fq


def as_np(f):
    return (np.asarray(f.ids), np.asarray(f.dists), np.asarray(f.checked))


def test_insert_sorts_and_truncates():
    f = fq.make_frontier(4)
    f, up, n = fq.insert(f, jnp.array([5, 3, 9, 7, 1]),
                         jnp.array([0.5, 0.3, 0.9, 0.7, 0.1]))
    ids, dists, checked = as_np(f)
    assert list(ids) == [1, 3, 5, 7]
    assert np.allclose(dists, [0.1, 0.3, 0.5, 0.7])
    assert not checked.any()
    assert int(up) == 0
    assert int(n) == 4


def test_insert_dedup_prefers_existing_checked():
    f = fq.make_frontier(4)
    f, _, _ = fq.insert(f, jnp.array([3]), jnp.array([0.3]))
    f, a, v = fq.select_unchecked(f, 1)          # marks 3 checked
    f, up, n = fq.insert(f, jnp.array([3, 4]), jnp.array([0.3, 0.4]))
    ids, dists, checked = as_np(f)
    assert list(ids[:2]) == [3, 4]
    assert checked[0] and not checked[1]          # 3 stays checked
    assert int(n) == 1                            # only 4 was new


def test_insert_update_position_saturates():
    f = fq.make_frontier(3)
    f, _, _ = fq.insert(f, jnp.array([1, 2, 3]), jnp.array([0.1, 0.2, 0.3]))
    # all new candidates are worse than capacity -> update position == L
    f, up, n = fq.insert(f, jnp.array([9, 8]), jnp.array([9.0, 8.0]))
    assert int(up) == 3
    assert int(n) == 0


def test_select_unchecked_marks_and_orders():
    f = fq.make_frontier(8)
    f, _, _ = fq.insert(f, jnp.arange(5), jnp.array([0.5, 0.1, 0.4, 0.2, 0.3]))
    f, active, valid = fq.select_unchecked(f, 3)
    assert list(np.asarray(active)) == [1, 3, 4]   # by distance order
    assert np.asarray(valid).all()
    assert not bool(fq.top_k_stable(f, 5))
    f, active2, valid2 = fq.select_unchecked(f, 3)
    assert list(np.asarray(active2)[np.asarray(valid2)]) == [2, 0]
    assert bool(fq.top_k_stable(f, 5))
    assert not bool(fq.has_unchecked(f))


def test_select_unchecked_dynamic_m():
    f = fq.make_frontier(8)
    f, _, _ = fq.insert(f, jnp.arange(5), jnp.full((5,), 0.1) * jnp.arange(5))
    f, active, valid = fq.select_unchecked(f, 4, m=jnp.int32(2))
    assert int(np.asarray(valid).sum()) == 2


def test_scatter_and_merge_roundtrip():
    f = fq.make_frontier(6)
    f, _, _ = fq.insert(f, jnp.arange(6),
                        jnp.array([0.1, 0.2, 0.3, 0.4, 0.5, 0.6]))
    f, _, _ = fq.select_unchecked(f, 2)           # 0, 1 checked
    ls = fq.scatter_round_robin(f, 2)
    assert ls.ids.shape == (2, 6)
    # each unchecked candidate appears in exactly one walker queue, unchecked
    unchecked_sets = []
    for w in range(2):
        ids = np.asarray(ls.ids[w])
        ch = np.asarray(ls.checked[w])
        unchecked_sets.append(set(ids[(~ch) & (ids != 2**31 - 1)].tolist()))
    assert unchecked_sets[0] & unchecked_sets[1] == set()
    assert unchecked_sets[0] | unchecked_sets[1] == {2, 3, 4, 5}
    merged, dups = fq.merge_frontiers(ls)
    ids, dists, checked = as_np(merged)
    assert list(ids) == [0, 1, 2, 3, 4, 5]
    assert checked[0] and checked[1] and not checked[2:].any()
    # checked entries were replicated to both walkers -> counted as dups
    assert int(dups) == 2


def test_scatter_active_subset():
    f = fq.make_frontier(6)
    f, _, _ = fq.insert(f, jnp.arange(6), 0.1 * jnp.arange(6, dtype=jnp.float32))
    ls = fq.scatter_round_robin(f, 4, active=jnp.int32(1))
    # only walker 0 has unchecked work
    has = [bool(fq.has_unchecked(jax.tree.map(lambda x: x[w], ls)))
           for w in range(4)]
    assert has == [True, False, False, False]


def test_merge_prefers_checked_on_dup():
    a = fq.make_frontier(4)
    a, _, _ = fq.insert(a, jnp.array([7]), jnp.array([0.7]))
    a, _, _ = fq.select_unchecked(a, 1)
    b = fq.make_frontier(4)
    b, _, _ = fq.insert(b, jnp.array([7]), jnp.array([0.7]))
    stacked = jax.tree.map(lambda x, y: jnp.stack([x, y]), a, b)
    merged, dups = fq.merge_frontiers(stacked)
    assert int(merged.ids[0]) == 7
    assert bool(merged.checked[0])
    assert int(dups) == 1
