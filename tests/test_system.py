"""End-to-end behaviour of the paper's system: index -> serve -> retrieve.

This is the integration test for the serving path a deployment exercises:
build an NSG-style index, answer batched query traffic with Speed-ANN
(staged parallel expansion + adaptive sync + bounded budgets), and plug the
same index into kNN-LM decoding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SearchConfig
from repro.core import build_nsg, recall_at_k, search_speedann_batch
from repro.core.build import exact_knn
from repro.data import make_vector_dataset


@pytest.fixture(scope="module")
def system():
    ds = make_vector_dataset("sift", n=3000, n_queries=24, k=10, dim=32,
                             n_clusters=24, seed=7)
    graph = build_nsg(ds.base, degree=32, knn_k=32, ef_construction=96)
    cfg = SearchConfig(k=10, queue_len=64, m_max=8, num_walkers=8,
                       max_steps=256, local_steps=8, sync_ratio=0.8)
    return ds, graph, cfg


def test_end_to_end_serving(system):
    """Fresh query traffic through the jitted serving path: recall + sane
    work counters + deterministic repeatability."""
    ds, graph, cfg = system
    search = jax.jit(lambda q: search_speedann_batch(graph, q, cfg))
    rng = np.random.RandomState(3)
    recalls = []
    for _ in range(3):
        c = rng.randint(0, ds.centers.shape[0], size=16)
        queries = (ds.centers[c] + rng.normal(size=(16, 32))
                   .astype(np.float32))
        gt, _ = exact_knn(ds.base, queries, 10)
        ids, dists, stats = search(jnp.asarray(queries))
        recalls.append(recall_at_k(np.asarray(ids), gt, 10))
        # bounded critical path (straggler mitigation): every query
        # converged within the round budget
        assert int(np.max(np.asarray(stats.steps))) <= cfg.max_steps
        # results sorted
        d = np.asarray(dists)
        fin = np.isfinite(d)
        assert all((np.diff(row[f]) >= -1e-5).all()
                   for row, f in zip(d, fin))
    assert np.mean(recalls) >= 0.9, recalls
    # determinism: same queries -> identical results
    q = jnp.asarray(ds.queries)
    a = np.asarray(search(q)[0])
    b = np.asarray(search(q)[0])
    np.testing.assert_array_equal(a, b)


def test_end_to_end_knnlm(system):
    """The retrieval layer composes with LM decoding (kNN-LM)."""
    from repro.configs import get_smoke_config
    from repro.data.tokens import TokenStream, _batch_at
    from repro.models import build_model
    from repro.serve.knnlm import build_datastore, knnlm_logits, _final_hidden

    cfg = get_smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=24, batch=4,
                         seed=1, shard=0, num_shards=1)
    corpus = [jnp.asarray(_batch_at(stream, s)["tokens"]) for s in range(3)]
    ds = build_datastore(model, params, corpus, cfg.vocab_size, degree=8)
    # stream tokens are seq_len-1 wide; datastore keys drop one more
    assert ds.graph.n_nodes == 3 * 4 * 22

    prompt = jnp.asarray(_batch_at(stream, 50)["tokens"][:2, :12])
    hidden = _final_hidden(model, params, prompt)[:, -1]
    logits, _ = model.forward(params, prompt, remat=False)
    scfg = SearchConfig(k=4, queue_len=16, m_max=2, num_walkers=2,
                        max_steps=48, local_steps=4)
    mixed, retrieved = knnlm_logits(ds, hidden, logits[:, -1], scfg,
                                    lam=0.3)
    mixed = np.asarray(mixed)
    assert mixed.shape == (2, cfg.vocab_size)
    assert np.isfinite(mixed).all()
    # mixed distribution is a valid log-prob distribution
    np.testing.assert_allclose(np.exp(mixed).sum(axis=-1), 1.0, rtol=1e-3)
    # retrieval found real datastore entries
    r = np.asarray(retrieved)
    assert (r[r < 2**31 - 1] < ds.graph.n_nodes).all()
