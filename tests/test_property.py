"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import queue as fq
from repro.core import visited as vs
from repro.core.metrics import batch_unique_counts, recall_at_k
from repro.kernels.dedup import dedupdist, unique_ids_inverse
from repro.kernels.l2dist import l2dist_rowgather

INVALID = 2**31 - 1


def random_inserts(rng, rounds, cap, idmax=1000):
    f = fq.make_frontier(cap)
    inserted = {}
    for _ in range(rounds):
        n = rng.randint(1, 6)
        ids = rng.choice(idmax, size=n)
        dists = rng.uniform(0, 10, size=n).astype(np.float32)
        for i, d in zip(ids, dists):
            if int(i) not in inserted:
                inserted[int(i)] = float(d)
        # same id must present the same distance (as in real search)
        dists = np.asarray([inserted[int(i)] for i in ids], np.float32)
        f, _, _ = fq.insert(f, jnp.asarray(ids, jnp.int32),
                            jnp.asarray(dists))
    return f, inserted


@given(seed=st.integers(0, 10_000), cap=st.sampled_from([4, 8, 16]))
@settings(max_examples=20, deadline=None)
def test_frontier_always_holds_global_topL(seed, cap):
    """After any insert sequence the frontier == top-L of everything seen."""
    rng = np.random.RandomState(seed)
    f, inserted = random_inserts(rng, rounds=6, cap=cap)
    ids = np.asarray(f.ids)
    dists = np.asarray(f.dists)
    want = sorted(inserted.items(), key=lambda kv: (kv[1], kv[0]))[:cap]
    got = [(int(i), float(d)) for i, d in zip(ids, dists) if i != INVALID]
    assert len(got) == min(len(inserted), cap)
    for (gi, gd), (wi, wd) in zip(got, want):
        assert gi == wi and abs(gd - wd) < 1e-5
    # sorted ascending (finite prefix; inf-padded tail), no duplicate ids
    finite = dists[np.isfinite(dists)]
    assert (np.diff(finite) >= -1e-6).all()
    assert np.isfinite(dists[:len(finite)]).all()
    real = ids[ids != INVALID]
    assert len(set(real.tolist())) == len(real)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_select_never_returns_checked_or_invalid(seed):
    rng = np.random.RandomState(seed)
    f, _ = random_inserts(rng, rounds=4, cap=16)
    for _ in range(5):
        m = rng.randint(1, 4)
        before = ~np.asarray(f.checked)
        f, active, valid = fq.select_unchecked(f, 4, m=jnp.int32(m))
        a, v = np.asarray(active), np.asarray(valid)
        assert v.sum() <= m
        assert (a[~v] == INVALID).all()
        assert (a[v] != INVALID).all()
    # eventually everything is checked
    for _ in range(16):
        f, _, _ = fq.select_unchecked(f, 4)
    assert not bool(fq.has_unchecked(f))


@given(seed=st.integers(0, 10_000), w=st.sampled_from([2, 3, 4]))
@settings(max_examples=15, deadline=None)
def test_scatter_merge_preserves_content(seed, w):
    """scatter -> merge loses nothing and re-checks nothing."""
    rng = np.random.RandomState(seed)
    f, _ = random_inserts(rng, rounds=5, cap=16)
    f, _, _ = fq.select_unchecked(f, 4)
    before_ids = set(np.asarray(f.ids)[np.asarray(f.ids) != INVALID].tolist())
    before_checked = {int(i) for i, c in zip(np.asarray(f.ids),
                                             np.asarray(f.checked))
                      if i != INVALID and c}
    ls = fq.scatter_round_robin(f, w)
    merged, _ = fq.merge_frontiers(ls)
    after_ids = set(np.asarray(merged.ids)[
        np.asarray(merged.ids) != INVALID].tolist())
    after_checked = {int(i) for i, c in zip(np.asarray(merged.ids),
                                            np.asarray(merged.checked))
                     if i != INVALID and c}
    assert after_ids == before_ids
    assert after_checked == before_checked


@given(seed=st.integers(0, 10_000),
       mode=st.sampled_from(["bitmap", "hash"]))
@settings(max_examples=15, deadline=None)
def test_visited_never_false_positive(seed, mode):
    """A fresh=False verdict implies the id really was seen before (bitmap);
    hash mode may duplicate (benign) but must never lose recall-critical
    inserts silently: a fresh id is queryable afterwards."""
    rng = np.random.RandomState(seed)
    v = vs.make_visited(mode, 500, hash_bits=10)
    seen = set()
    for _ in range(6):
        ids = rng.choice(500, size=8).astype(np.int32)
        valid = rng.rand(8) > 0.2
        v, fresh = vs.check_and_insert(v, jnp.asarray(ids),
                                       jnp.asarray(valid))
        fresh = np.asarray(fresh)
        for i, (id_, ok, fr) in enumerate(zip(ids, valid, fresh)):
            if not ok:
                assert not fr
            elif not fr and mode == "bitmap":
                # claimed already-visited -> must actually have been seen
                assert int(id_) in seen or id_ in ids[:i][valid[:i]]
            if ok and fr:
                seen.add(int(id_))
    # everything marked fresh is now definitely visited (no forgetting)
    if mode == "bitmap":
        ids = jnp.asarray(sorted(seen), jnp.int32)
        if len(seen):
            v2, fresh2 = vs.check_and_insert(
                v, ids, jnp.ones((len(seen),), bool))
            assert not np.asarray(fresh2).any()


@given(seed=st.integers(0, 10_000),
       b=st.sampled_from([1, 3, 8]),
       c=st.sampled_from([4, 8, 11]),
       idmax=st.sampled_from([5, 40, 200]))
@settings(max_examples=20, deadline=None)
def test_dedup_gather_scatter_extensional(seed, b, c, idmax):
    """For random id multisets (including sentinel/padding ids) the
    dedup-gather-scatter pipeline is extensionally equal to the direct
    per-lane gather, and its unique buffer is a faithful factorization."""
    rng = np.random.RandomState(seed)
    n, d = idmax, 8
    table = jnp.asarray(rng.randn(n, d), np.float32)
    q = jnp.asarray(rng.randn(b, d), np.float32)
    # idmax+3 head-room -> some draws are padding ids (>= n)
    ids = jnp.asarray(rng.randint(0, n + 3, size=(b, c)), jnp.int32)
    got = np.asarray(dedupdist(table, ids, q))
    want = np.asarray(l2dist_rowgather(table, ids, q))
    np.testing.assert_array_equal(got, want)
    uniq, inv, n_uniq = unique_ids_inverse(ids, n)
    uniq, inv = np.asarray(uniq), np.asarray(inv)
    ids_np = np.asarray(ids)
    # the factorization folds back exactly (padding folded to the sentinel)
    np.testing.assert_array_equal(uniq[inv], np.minimum(ids_np, n))
    real = uniq[uniq < n]
    assert len(real) == len(set(real.tolist())) == int(n_uniq)
    assert set(real.tolist()) == set(ids_np[ids_np < n].ravel().tolist())
    # tile-padded tail is all sentinel
    assert uniq.shape[0] % 8 == 0 and (uniq[len(real):] >= n).all()


@given(seed=st.integers(0, 10_000),
       b=st.sampled_from([1, 4, 7]),
       c=st.sampled_from([3, 8]),
       idmax=st.sampled_from([4, 30, 500]))
@settings(max_examples=25, deadline=None)
def test_first_toucher_counts_bound_and_exact(seed, b, c, idmax):
    """uniq <= counted per lane, with equality iff the lane's counted ids
    are disjoint from every LOWER lane's; matches a pure-Python recount."""
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(0, idmax, size=(b, c)), jnp.int32)
    counted = jnp.asarray(rng.rand(b, c) > 0.3)
    # in-lane candidates are id-distinct in real traversals (visited dedups
    # first); enforce it so the first-toucher contract's premise holds
    ids_np = np.asarray(ids)
    for lane in range(b):
        _, idx = np.unique(ids_np[lane], return_index=True)
        keep = np.zeros(c, bool)
        keep[idx] = True
        counted = counted.at[lane].set(jnp.asarray(keep)
                                       & counted[lane])
    got = np.asarray(batch_unique_counts(ids, counted))
    counted_np = np.asarray(counted)
    seen, want = set(), np.zeros(b, np.int64)
    for lane in range(b):
        for slot in range(c):
            if counted_np[lane, slot] and int(ids_np[lane, slot]) not in seen:
                seen.add(int(ids_np[lane, slot]))
                want[lane] += 1
    np.testing.assert_array_equal(got, want)
    per_lane = counted_np.sum(axis=1)
    assert (got <= per_lane).all()
    assert got.sum() == len(seen)
    # equality iff all counted ids are pairwise distinct across the batch
    all_counted = ids_np[counted_np]
    if len(set(all_counted.tolist())) == len(all_counted):
        np.testing.assert_array_equal(got, per_lane)
    else:
        assert (got < per_lane).any()


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_recall_bounds(seed):
    rng = np.random.RandomState(seed)
    gt = rng.choice(1000, size=(4, 10), replace=False)
    assert recall_at_k(gt, gt, 10) == 1.0
    other = gt + 5000
    assert recall_at_k(other, gt, 10) == 0.0
    assert 0.0 <= recall_at_k(rng.randint(0, 50, (4, 10)), gt, 10) <= 1.0
