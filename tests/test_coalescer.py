"""Async request coalescing + sharded serving: policy, parity, deadlines.

The coalescer must be *transparent* (a query served in a coalesced batch is
bit-identical to the same query via ``AnnIndex.search``), *ordered*
(earliest-deadline-first batch formation), and *bounded* (max-wait flush;
expired requests rejected, not silently served late).  The sharded engine
mode must match the single-host engine's recall on a 1-device mesh — the
same code path multi-device meshes run, no special-casing.

Timing-sensitive tests run on the deterministic serving harness
(``tests/serving_harness.py``): a virtual clock injected via
``serve_async(..., clock=)`` replaces wall-clock sleeps, so flush timing
and deadline expiry are exact, not raced.
"""
import numpy as np
import pytest

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.data import make_vector_dataset
from repro.serve import AnnEngine, DeadlineExceeded
from repro.serve.coalescer import _Pending, select_batch
from serving_harness import Arrival, ServingHarness, VirtualClock

BUCKETS = (1, 2, 4, 8)
PARAMS = SearchParams(k=10, queue_len=48, m_max=4, num_walkers=4,
                      max_steps=128, local_steps=4)


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=1200, n_queries=16, k=10, dim=24,
                               n_clusters=12, seed=7)


@pytest.fixture(scope="module")
def index(ds):
    return AnnIndex.build(ds, IndexSpec(degree=12, passes=1))


# -- batch formation (pure, no threads) --------------------------------------

def _pending(seq, deadline_t):
    return _Pending(seq=seq, query=np.zeros(4, np.float32), enqueue_t=0.0,
                    deadline_t=deadline_t, future=None)


def test_select_batch_orders_by_deadline_then_fifo():
    pend = [_pending(0, 9.0), _pending(1, 3.0), _pending(2, None),
            _pending(3, 3.0), _pending(4, 1.0)]
    batch, expired, rest = select_batch(pend, now=0.0, max_batch=3)
    assert [p.seq for p in batch] == [4, 1, 3]   # EDF; FIFO among ties
    assert expired == []
    # remainder keeps arrival order (deadline 9.0 before the deadline-less)
    assert [p.seq for p in rest] == [0, 2]


def test_select_batch_expires_late_requests():
    pend = [_pending(0, 1.0), _pending(1, 5.0), _pending(2, None)]
    batch, expired, rest = select_batch(pend, now=2.0, max_batch=8)
    assert [p.seq for p in expired] == [0]
    assert [p.seq for p in batch] == [1, 2]      # None sorts last
    assert rest == []


# -- coalesced serving: parity ------------------------------------------------

def test_coalesced_query_bit_identical_to_direct_search(ds, index):
    """THE transparency pin: single queries submitted separately, coalesced
    into one batch, return per-request results bit-identical to the same
    queries through AnnIndex.search — coalescing never changes answers."""
    srv = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS)
    futs = [srv.submit(q) for q in ds.queries[:6]]
    assert srv.flush() == 6
    direct = index.search(ds.queries[:6], PARAMS)
    for i, f in enumerate(futs):
        res = f.result(timeout=0)
        np.testing.assert_array_equal(res.ids, np.asarray(direct.ids)[i])
        np.testing.assert_array_equal(res.dists, np.asarray(direct.dists)[i])
        assert res.batch_size == 6.0
    st = srv.stats()
    assert st["served"] == 6 and st["batches_dispatched"] == 1
    srv.close()


def test_single_vs_batched_submission_parity(ds, index):
    """A query alone in its batch == the same query coalesced with others
    (vmap lanes are independent)."""
    srv = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS)
    alone = srv.submit(ds.queries[0])
    srv.flush()
    futs = [srv.submit(q) for q in ds.queries[:5]]
    srv.flush()
    np.testing.assert_array_equal(alone.result().ids, futs[0].result().ids)
    assert alone.result().batch_size == 1.0
    assert futs[0].result().batch_size == 5.0
    srv.close()


# -- coalescing policy ---------------------------------------------------------

def test_max_batch_splits_flushes(ds, index):
    srv = index.serve_async(PARAMS, start=False, max_batch=4,
                            bucket_sizes=BUCKETS)
    futs = [srv.submit(q) for q in ds.queries[:10]]
    assert srv.flush() == 10
    st = srv.stats()
    assert st["batches_dispatched"] == 3         # 4 + 4 + 2
    assert st["batch_size_max"] == 4.0
    assert all(f.result().batch_size <= 4 for f in futs)
    srv.close()


def test_max_wait_flushes_partial_batch(ds, index):
    """A lone request is served EXACTLY max_wait_ms after arrival even
    though the batch never fills — on the virtual clock the policy's wait
    budget is exact, not a lower bound raced against the scheduler."""
    clock = VirtualClock()
    srv = index.serve_async(PARAMS, max_batch=64, max_wait_ms=10.0,
                            bucket_sizes=BUCKETS, start=False, clock=clock)
    harness = ServingHarness(srv, clock)
    res = harness.run([Arrival(t=0.0, query=ds.queries[0])])
    out = res.futures[0].result(timeout=0)
    assert out.ids.shape == (PARAMS.k,)
    assert out.queue_wait_ms == pytest.approx(10.0)  # the full wait budget
    assert out.batch_size == 1.0
    assert clock() == pytest.approx(0.010)       # flushed at due time exactly
    srv.close()


def test_expired_deadline_rejected_not_served(ds, index):
    clock = VirtualClock()
    srv = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS,
                            clock=clock)
    dead = srv.submit(ds.queries[0], deadline_ms=1.0)
    live = srv.submit(ds.queries[1], deadline_ms=10_000.0)
    clock.advance(0.005)                         # the deadline lapses
    srv.flush()
    with pytest.raises(DeadlineExceeded):
        dead.result(timeout=0)
    assert live.result(timeout=0).ids.shape == (PARAMS.k,)
    st = srv.stats()
    assert st["rejected_deadline"] == 1 and st["served"] == 1
    srv.close()


def test_client_cancel_does_not_kill_dispatch(ds, index):
    """A client cancelling its queued future must not poison the batch:
    set_result on a cancelled future raises InvalidStateError, which would
    kill the dispatcher thread — the coalescer claims futures with
    set_running_or_notify_cancel before resolving them."""
    srv = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS)
    gone = srv.submit(ds.queries[0])
    kept = [srv.submit(q) for q in ds.queries[1:4]]
    assert gone.cancel()                         # still queued: cancellable
    srv.flush()
    for f in kept:                               # the rest of the batch
        assert f.result(timeout=0).ids.shape == (PARAMS.k,)   # still served
    assert gone.cancelled()
    st = srv.stats()
    assert st["cancelled"] == 1 and st["served"] == 3
    # a dispatched (RUNNING) future can no longer be cancelled
    assert not kept[0].cancel()
    srv.close()


def test_close_drains_queue(ds, index):
    srv = index.serve_async(PARAMS, max_batch=64, max_wait_ms=10_000.0,
                            bucket_sizes=BUCKETS)
    futs = [srv.submit(q) for q in ds.queries[:3]]
    srv.close()                                  # drain=True default
    for f in futs:
        assert f.result(timeout=0).ids.shape == (PARAMS.k,)
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(ds.queries[0])


def test_policy_validation(index):
    with pytest.raises(ValueError, match="max_batch"):
        index.serve_async(PARAMS, max_batch=0)
    srv = index.serve_async(PARAMS, start=False)
    with pytest.raises(ValueError, match="ONE query"):
        srv.submit(np.zeros((3, 4), np.float32))
    srv.close()


# -- sharded engine mode -------------------------------------------------------

def test_sharded_engine_matches_single_host_recall(ds, index):
    """The walker-sharded engine mode on a 1-device mesh passes the same
    recall bar as the single-host engine, through the same serve() API."""
    gt, _ = index.exact(ds.queries, 10)
    single = index.serve(PARAMS, bucket_sizes=BUCKETS)
    sharded = index.serve(
        PARAMS.with_(algorithm="sharded", global_rounds=16),
        bucket_sizes=BUCKETS)
    assert sharded.mode == "sharded"
    r1 = single.search(ds.queries, gt_ids=gt)
    r2 = sharded.search(ds.queries, gt_ids=gt)
    assert r1.ids.shape == r2.ids.shape
    s1, s2 = single.stats(), sharded.stats()
    assert s1["recall_at_k"] >= 0.9
    assert s2["recall_at_k"] >= 0.9
    assert s2["jit_cache_size"] >= 1


def test_sharded_engine_through_coalescer(ds, index):
    """Coalescing composes with sharded dispatch: submitted single queries
    match the sharded engine's own batched results bit for bit."""
    p = PARAMS.with_(algorithm="sharded", global_rounds=16)
    srv = index.serve_async(p, start=False, bucket_sizes=BUCKETS)
    assert srv.engine.mode == "sharded"
    futs = [srv.submit(q) for q in ds.queries[:4]]
    srv.flush()
    direct = index.search(ds.queries[:4], p)
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(f.result().ids,
                                      np.asarray(direct.ids)[i])
    srv.close()


def test_legacy_graph_engine_still_rejects_sharded(ds, index):
    from repro.config import SearchConfig
    with pytest.raises(ValueError, match="facade"):
        AnnEngine(index.graph, SearchConfig(k=10), algorithm="sharded")


def test_corpus_engine_recall_on_one_device_mesh(ds):
    """Corpus-sharded serving (partitioned corpus, global top-K merge)
    through the engine API on a 1-device mesh."""
    from repro.core.distributed import (build_partitioned_index,
                                        make_search_mesh)
    spec = IndexSpec(degree=12, passes=1)
    sharded = build_partitioned_index(ds.base, num_shards=1, spec=spec)
    mesh = make_search_mesh((1, 1), ("data", "model"))
    eng = AnnEngine(sharded, PARAMS.with_(queue_len=64, max_steps=256),
                    mesh=mesh, bucket_sizes=BUCKETS)
    assert eng.mode == "corpus"
    gt_ids = None
    res = eng.search(ds.queries, gt_ids=gt_ids)
    from repro.core import recall_at_k
    assert recall_at_k(res.ids, ds.gt_ids, 10) >= 0.9


def test_per_bucket_latency_stats(ds, index):
    engine = index.serve(PARAMS, bucket_sizes=BUCKETS)
    engine.search(ds.queries[:3])                # bucket 4
    engine.search(ds.queries[:3])
    engine.search(ds.queries[:8])                # bucket 8
    st = engine.stats()
    assert st["bucket4_chunks"] == 2.0
    assert st["bucket8_chunks"] == 1.0
    for b in (4, 8):
        assert st[f"bucket{b}_p50_ms"] <= st[f"bucket{b}_p99_ms"] + 1e-9
        assert st[f"bucket{b}_p99_ms"] <= st[f"bucket{b}_max_ms"] + 1e-9
    assert "bucket1_chunks" not in st            # untouched bucket: no keys
