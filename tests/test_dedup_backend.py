"""Parity matrix for the batch-dedup distance backends.

``dedup_gather`` reorganizes the step's gather traffic (each distinct row
fetched once for the whole batch) without changing WHAT is computed, so its
contract is equality with the per-lane backends:

* vs ``rowgather`` / ``rowgather_int8`` / ``ref_int8`` — BIT-IDENTICAL
  (same per-pair op order; the int8 path's integer accumulation is exact).
* vs the f32 ``ref`` backend — identical traversals (ids and every
  SearchStats counter bit-equal) with distances equal to float tolerance:
  XLA fuses the pure-jnp (B, C, d) reduction with a different f32
  accumulation order than the Pallas kernels' per-pair (d,) sums, a
  last-ulp reassociation the repo's kernel tests have always allowed
  (see tests/test_kernels.py tolerances).

Covers topm|speedann x l2|ip|cosine x B in {1, 8, 64}, plus the degenerate
all-duplicates batch (every lane expands the same vertices) and the
no-overlap batch (kernel-level, where disjoint lanes can be constructed).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_nsg
from repro.core.bfis import search_topm_batch
from repro.core.config import SearchConfig
from repro.core.speedann import search_speedann_batch
from repro.data import make_vector_dataset
from repro.kernels.dedup import (dedupdist, dedupdist_int8,
                                 make_dedup_int8_dist_fn, unique_ids_inverse)
from repro.kernels.l2dist import l2dist_rowgather
from repro.kernels.ref import dist_ref
from repro.kernels.registry import available_backends
from repro.quant.codec import fit_scales, quantize
from repro.quant.scheme import QuantSpec, required_quant_dtype

K = 10
BASE = SearchConfig(k=K, queue_len=32, m_max=3, staged=False, max_steps=96)
SPEED = BASE.with_(m_max=4, num_walkers=4, staged=True, local_steps=4)
ALGOS = {"topm": search_topm_batch, "speedann": search_speedann_batch}


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=600, n_queries=64, k=K, dim=16,
                               n_clusters=8, seed=11)


@pytest.fixture(scope="module")
def graphs(ds):
    """One graph per metric (cosine = l2 build on normalized vectors)."""
    out = {"l2": build_nsg(ds.base, degree=10, knn_k=10, ef_construction=20,
                           passes=1)}
    base = np.asarray(ds.base, np.float32)
    out["ip"] = build_nsg(base, degree=10, knn_k=10, ef_construction=20,
                          passes=1, metric="ip")
    normed = base / np.maximum(
        np.linalg.norm(base, axis=1, keepdims=True), 1e-12)
    out["cosine"] = build_nsg(normed, degree=10, knn_k=10,
                              ef_construction=20, passes=1)
    return out


def queries_for(ds, metric, b):
    q = jnp.asarray(ds.queries[:b])
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=1, keepdims=True), 1e-12)
    return q


def assert_search_parity(fn, graph, q, cfg):
    """dedup_gather == rowgather bit for bit; == ref up to f32 fusion."""
    i_d, d_d, s_d = fn(graph, q, cfg.with_(dist_backend="dedup_gather"))
    i_r, d_r, s_r = fn(graph, q, cfg.with_(dist_backend="rowgather"))
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(d_d), np.asarray(d_r))
    for f in s_d._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(s_d, f)), np.asarray(getattr(s_r, f)),
            err_msg=f"stats field {f!r} drifted vs rowgather")
    i_f, d_f, s_f = fn(graph, q, cfg.with_(dist_backend="ref"))
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_f))
    np.testing.assert_allclose(np.asarray(d_d), np.asarray(d_f),
                               rtol=1e-5, atol=1e-5)
    for f in s_d._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(s_d, f)), np.asarray(getattr(s_f, f)),
            err_msg=f"stats field {f!r} drifted vs ref")
    return i_d, d_d, s_d


def test_backends_registered():
    have = available_backends()
    assert "dedup_gather" in have and "dedup_gather_int8" in have
    # the facade's quant validation picks the codes table up from the name
    assert required_quant_dtype("dedup_gather_int8") == "int8"
    assert required_quant_dtype("dedup_gather") == "none"


@pytest.mark.parametrize("algo", ["topm", "speedann"])
@pytest.mark.parametrize("metric", ["l2", "ip", "cosine"])
def test_search_parity_matrix(ds, graphs, algo, metric):
    cfg = (BASE if algo == "topm" else SPEED).with_(metric=metric)
    assert_search_parity(ALGOS[algo], graphs[metric], queries_for(ds, metric, 8),
                         cfg)


@pytest.mark.parametrize("algo", ["topm", "speedann"])
@pytest.mark.parametrize("b", [1, 64])
def test_search_parity_batch_sizes(ds, graphs, algo, b):
    """B=1 (no cross-query overlap at all) and the wide batch; l2 keeps the
    matrix affordable — the metric axis is covered at B=8 above."""
    cfg = BASE if algo == "topm" else SPEED
    assert_search_parity(ALGOS[algo], graphs["l2"], queries_for(ds, "l2", b),
                         cfg)


def test_all_duplicates_batch(ds, graphs):
    """Every lane expands the same vertices: identical queries make the
    degenerate maximal-overlap batch.  First-toucher attribution charges
    lane 0 with every gather; the dedup backend still matches ref."""
    q = jnp.broadcast_to(jnp.asarray(ds.queries[:1]), (8, ds.queries.shape[1]))
    ids, dists, stats = assert_search_parity(search_topm_batch, graphs["l2"],
                                             q, BASE)
    # all lanes identical -> ids identical across the batch
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.broadcast_to(np.asarray(ids)[:1],
                                                  ids.shape))
    u = np.asarray(stats.uniq_comps)
    d = np.asarray(stats.dist_comps)
    dup = np.asarray(stats.batch_dup_comps)
    assert (u + dup == d).all()
    np.testing.assert_array_equal(u[1:], 0)        # lane 0 first-touches all
    assert u[0] == d[0]
    np.testing.assert_array_equal(dup[1:], d[1:])


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_kernel_no_overlap_and_all_dup(metric):
    """Kernel-level degenerate batches (disjoint lanes are constructible
    here, unlike in a traversal that shares the entry point)."""
    rng = np.random.RandomState(3)
    n, d, b, c = 64, 16, 4, 8
    table = jnp.asarray(rng.randn(n, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, d), jnp.float32)
    # no overlap: every lane's ids disjoint -> T holds all B*C of them
    ids = jnp.arange(b * c, dtype=jnp.int32).reshape(b, c)
    np.testing.assert_array_equal(
        np.asarray(dedupdist(table, ids, q, metric=metric)),
        np.asarray(l2dist_rowgather(table, ids, q, metric=metric)))
    _, _, n_uniq = unique_ids_inverse(ids, n)
    assert int(n_uniq) == b * c
    # all duplicates: one id everywhere -> a single real gather
    ids = jnp.full((b, c), 7, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(dedupdist(table, ids, q, metric=metric)),
        np.asarray(l2dist_rowgather(table, ids, q, metric=metric)))
    _, _, n_uniq = unique_ids_inverse(ids, n)
    assert int(n_uniq) == 1


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_kernel_matches_ref_with_padding(metric):
    rng = np.random.RandomState(0)
    n, d, b, c = 50, 16, 6, 9
    table = jnp.asarray(rng.randn(n, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, d), jnp.float32)
    ids = jnp.asarray(rng.randint(0, n + 1, size=(b, c)), jnp.int32)
    got = np.asarray(dedupdist(table, ids, q, metric=metric))
    np.testing.assert_array_equal(
        got, np.asarray(l2dist_rowgather(table, ids, q, metric=metric)))
    np.testing.assert_allclose(
        got, np.asarray(dist_ref(table, ids, q, metric=metric)),
        rtol=1e-5, atol=1e-5)
    assert np.isinf(got[np.asarray(ids) >= n]).all()


# -- int8 variant -----------------------------------------------------------

def quantized(graph, dtype="int8"):
    spec = QuantSpec(dtype=dtype)
    scales = fit_scales(graph.vectors, spec)
    return graph._replace(
        codes=quantize(graph.vectors, spec, scales),
        scales=jnp.asarray(scales, jnp.float32))


@pytest.mark.parametrize("metric", ["l2", "ip", "cosine"])
@pytest.mark.parametrize("b", [1, 8])
def test_int8_search_bit_identity(ds, graphs, metric, b):
    """dedup_gather_int8 == ref_int8 == rowgather_int8 BIT-identically: the
    integer dot is exact, so no fusion reassociation can leak in."""
    gq = quantized(graphs[metric])
    q = queries_for(ds, metric, b)
    cfg = BASE.with_(metric=metric)
    i_d, d_d, s_d = search_topm_batch(gq, q,
                                      cfg.with_(dist_backend="dedup_gather_int8"))
    for other in ("ref_int8", "rowgather_int8"):
        i_o, d_o, s_o = search_topm_batch(gq, q, cfg.with_(dist_backend=other))
        np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_o),
                                      err_msg=other)
        np.testing.assert_array_equal(np.asarray(d_d), np.asarray(d_o),
                                      err_msg=other)
        for f in s_d._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(s_d, f)), np.asarray(getattr(s_o, f)),
                err_msg=f"{other}:{f}")


def test_int8_kernel_all_dup_batch64(graphs):
    """Wide-batch int8 kernel parity on a high-overlap id grid."""
    gq = quantized(graphs["l2"])
    rng = np.random.RandomState(1)
    b, c = 64, 8
    ids = jnp.asarray(rng.randint(0, 12, size=(b, c)), jnp.int32)  # heavy dup
    q = jnp.asarray(rng.randn(b, gq.vectors.shape[1]), jnp.float32)
    from repro.quant.kernels import int8dist_rowgather
    np.testing.assert_array_equal(
        np.asarray(dedupdist_int8(gq.codes, gq.scales, ids, q)),
        np.asarray(int8dist_rowgather(gq.codes, gq.scales, ids, q)))


def test_int8_per_dim_scales_rejected(graphs):
    g = graphs["l2"]
    spec = QuantSpec(dtype="int8", per_dim=True)
    scales = fit_scales(g.vectors, spec)
    gq = g._replace(codes=quantize(g.vectors, spec, scales),
                    scales=jnp.asarray(scales, jnp.float32))
    fn = make_dedup_int8_dist_fn()
    with pytest.raises(NotImplementedError, match="per-vector"):
        fn(gq, jnp.zeros((1, 1), jnp.int32), jnp.zeros((1, 1, 4), jnp.int32),
           jnp.zeros((1, gq.vectors.shape[1]), jnp.float32))
