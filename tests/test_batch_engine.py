"""Batch-major traversal engine: parity with the per-query path.

The batch-major engine (one ``lax.while_loop`` over batch-leading state,
one distance launch per global step) replaced per-query searches under
``jax.vmap``.  Its contract is BIT-IDENTITY: for every algorithm × backend
× metric × quantization, ``search_*_batch(graph, Q)`` must equal
``jax.vmap(search_*)(Q)`` exactly — ids, dists, AND every SearchStats
counter (converged lanes are masked no-ops, so per-query counters cannot
drift).  Batch composition must also be invisible: a query's result cannot
depend on which other queries share its batch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.core import build_nsg, recall_at_k
from repro.core.bfis import search_topm, search_topm_batch
from repro.core.config import SearchConfig
from repro.core.speedann import search_speedann, search_speedann_batch
from repro.data import make_vector_dataset
from repro.quant.codec import fit_scales, quantize
from repro.quant.scheme import QuantSpec

K = 10


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=900, n_queries=8, k=K, dim=16,
                               n_clusters=8, seed=11)


@pytest.fixture(scope="module")
def graph(ds):
    return build_nsg(ds.base, degree=12, knn_k=12, ef_construction=24,
                     passes=1)


def quantized(graph, dtype):
    spec = QuantSpec(dtype=dtype)
    scales = fit_scales(graph.vectors, spec)
    return graph._replace(
        codes=quantize(graph.vectors, spec, scales),
        scales=jnp.asarray(scales, jnp.float32))


BASE = SearchConfig(k=K, queue_len=32, m_max=3, staged=False, max_steps=96)
SPEED = BASE.with_(m_max=4, num_walkers=4, staged=True, local_steps=4)


def assert_batch_matches_vmap(batch_fn, single_fn, graph, queries, cfg):
    """The acceptance bar: batched == vmapped per-query, bit for bit."""
    ids_b, d_b, st_b = batch_fn(graph, queries, cfg)
    ids_v, d_v, st_v = jax.vmap(
        lambda q: single_fn(graph, q, cfg))(queries)
    np.testing.assert_array_equal(np.asarray(ids_b), np.asarray(ids_v))
    np.testing.assert_array_equal(np.asarray(d_b), np.asarray(d_v))
    for field in st_b._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(st_b, field)),
            np.asarray(getattr(st_v, field)),
            err_msg=f"stats field {field!r} drifted")
    return ids_b


@pytest.mark.parametrize("backend", ["ref", "rowgather", "dma"])
def test_topm_batch_bit_identical_fp32_backends(ds, graph, backend):
    q = jnp.asarray(ds.queries)
    ids = assert_batch_matches_vmap(
        search_topm_batch, search_topm, graph, q,
        BASE.with_(dist_backend=backend))
    assert recall_at_k(np.asarray(ids), ds.gt_ids, K) >= 0.9


@pytest.mark.parametrize("backend", ["ref", "dma"])
def test_speedann_batch_bit_identical(ds, graph, backend):
    q = jnp.asarray(ds.queries)
    ids = assert_batch_matches_vmap(
        search_speedann_batch, search_speedann, graph, q,
        SPEED.with_(dist_backend=backend))
    assert recall_at_k(np.asarray(ids), ds.gt_ids, K) >= 0.9


@pytest.mark.parametrize("backend,dtype", [
    ("ref_int8", "int8"), ("rowgather_int8", "int8"), ("ref_bf16", "bf16")])
def test_batch_bit_identical_quant_backends(ds, graph, backend, dtype):
    gq = quantized(graph, dtype)
    q = jnp.asarray(ds.queries)
    assert_batch_matches_vmap(
        search_topm_batch, search_topm, gq, q,
        BASE.with_(dist_backend=backend))


@pytest.mark.parametrize("metric", ["ip", "cosine"])
def test_batch_bit_identical_across_metrics(ds, metric):
    base = np.asarray(ds.base, np.float32)
    if metric == "cosine":
        base = base / np.maximum(
            np.linalg.norm(base, axis=1, keepdims=True), 1e-12)
    g = build_nsg(base, degree=12, knn_k=12, ef_construction=24, passes=1,
                  metric="l2" if metric == "cosine" else metric)
    q = jnp.asarray(ds.queries)
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=1, keepdims=True), 1e-12)
    assert_batch_matches_vmap(search_topm_batch, search_topm, g, q,
                              BASE.with_(metric=metric))
    assert_batch_matches_vmap(search_speedann_batch, search_speedann, g, q,
                              SPEED.with_(metric=metric))


def test_batch_composition_is_invisible(ds, graph):
    """A query's result must not depend on its batch mates: lanes that
    converge early are exact no-ops while stragglers keep looping."""
    q = jnp.asarray(ds.queries)
    ids_all, d_all, st_all = search_topm_batch(graph, q, BASE)
    # front slice of the batch vs the same queries in a smaller batch
    ids_sub, d_sub, st_sub = search_topm_batch(graph, q[:3], BASE)
    np.testing.assert_array_equal(np.asarray(ids_all)[:3],
                                  np.asarray(ids_sub))
    np.testing.assert_array_equal(np.asarray(d_all)[:3], np.asarray(d_sub))
    for field in st_all._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(st_all, field))[:3],
            np.asarray(getattr(st_sub, field)))
    # B=1 wrapper == the corresponding batch row
    ids_1, d_1, st_1 = search_topm(graph, q[5], BASE)
    np.testing.assert_array_equal(np.asarray(ids_all)[5], np.asarray(ids_1))
    np.testing.assert_array_equal(np.asarray(d_all)[5], np.asarray(d_1))
    assert int(st_1.steps) == int(np.asarray(st_all.steps)[5])


def test_facade_batch_parity_with_rerank(ds):
    """The two-stage (quantized traverse + exact re-rank) facade search is
    batch-major end to end and batch-composition invariant."""
    index = AnnIndex.build(ds.base, IndexSpec(degree=12, passes=1,
                                              quant="int8"))
    params = SearchParams(k=K, queue_len=32, max_steps=96,
                          backend="ref_int8", rerank_k=2 * K)
    q = np.asarray(ds.queries)
    full = index.search(q, params)
    sub = index.search(q[:3], params)
    np.testing.assert_array_equal(np.asarray(full.ids)[:3],
                                  np.asarray(sub.ids))
    np.testing.assert_array_equal(np.asarray(full.dists)[:3],
                                  np.asarray(sub.dists))
    assert recall_at_k(np.asarray(full.ids), ds.gt_ids, K) >= 0.9


def test_engine_inherits_batch_major_path(ds):
    """AnnEngine serves through the index's batch-major searchers: padded
    bucket execution is bit-identical to direct AnnIndex.search."""
    index = AnnIndex.build(ds.base, IndexSpec(degree=12, passes=1))
    params = SearchParams(k=K, queue_len=32, max_steps=96,
                          algorithm="speedann", num_walkers=2)
    engine = index.serve(params, bucket_sizes=(2, 4, 8))
    direct = index.search(ds.queries, params)
    for bsz in (1, 3, 8):
        res = engine.search(ds.queries[:bsz])
        np.testing.assert_array_equal(res.ids,
                                      np.asarray(direct.ids)[:bsz])
        np.testing.assert_array_equal(res.dists,
                                      np.asarray(direct.dists)[:bsz])
    assert engine.jit_cache_size <= 3


def test_max_norm_entry_policy_mips(ds, tmp_path):
    """IndexSpec(entry_policy='max_norm') seeds ip traversals at the
    max-norm vertex, reaches reference recall, and round-trips."""
    rng = np.random.RandomState(3)
    base = np.asarray(ds.base, np.float32) \
        * np.exp(rng.randn(ds.base.shape[0], 1) * 0.6).astype(np.float32)
    spec = IndexSpec(metric="ip", degree=12, passes=1,
                     entry_policy="max_norm")
    index = AnnIndex.build(base, spec)
    norms = np.linalg.norm(base, axis=1)
    assert int(index.graph.medoid) == int(np.argmax(norms))
    gt, _ = index.exact(ds.queries, K)
    res = index.search(ds.queries, SearchParams(k=K, queue_len=64,
                                                max_steps=128))
    assert recall_at_k(np.asarray(res.ids), gt, K) >= 0.9
    # the policy is build-time state: persisted with the spec + medoid
    path = index.save(str(tmp_path / "maxnorm"))
    loaded = AnnIndex.load(path)
    assert loaded.spec.entry_policy == "max_norm"
    assert int(loaded.graph.medoid) == int(index.graph.medoid)
    # default-policy artifacts must NOT carry the key: readers predating
    # entry_policy construct IndexSpec(**spec_json) and would crash on it
    default_index = AnnIndex.build(base, IndexSpec(metric="ip", degree=12,
                                                   passes=1))
    dpath = default_index.save(str(tmp_path / "default"))
    import json as _json
    spec_json = _json.loads(str(np.load(dpath)["spec"]))
    assert "entry_policy" not in spec_json
    assert AnnIndex.load(dpath).spec.entry_policy == "medoid"
    # ...and validated at construction
    with pytest.raises(ValueError, match="max_norm"):
        IndexSpec(metric="l2", entry_policy="max_norm")
    with pytest.raises(ValueError, match="entry_policy"):
        IndexSpec(entry_policy="bogus")
