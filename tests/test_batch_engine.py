"""Batch-major traversal engine: parity with the per-query path.

The batch-major engine (one ``lax.while_loop`` over batch-leading state,
one distance launch per global step) replaced per-query searches under
``jax.vmap``.  Its contract is BIT-IDENTITY: for every algorithm × backend
× metric × quantization, ``search_*_batch(graph, Q)`` must equal
``jax.vmap(search_*)(Q)`` exactly — ids, dists, AND every SearchStats
counter (converged lanes are masked no-ops, so per-query counters cannot
drift).  Batch composition must also be invisible: a query's result cannot
depend on which other queries share its batch.

The one sanctioned exception is ``SearchStats.BATCH_RELATIVE``
(``uniq_comps`` / ``batch_dup_comps``): those are DEFINED relative to the
batch (first-toucher attribution across the step's flattened lanes), so the
vmapped per-query run yields the B=1 values, not the cross-query ones.
They still obey hard invariants checked here — ``uniq + dup == dist_comps``
per lane, batched uniq <= per-query uniq — and stay exact under
front-slicing and batch permutation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.core import build_nsg, recall_at_k
from repro.core.bfis import search_topm, search_topm_batch
from repro.core.config import SearchConfig
from repro.core.metrics import SearchStats, batch_unique_counts
from repro.core.speedann import search_speedann, search_speedann_batch
from repro.data import make_vector_dataset
from repro.quant.codec import fit_scales, quantize
from repro.quant.scheme import QuantSpec

K = 10


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=900, n_queries=8, k=K, dim=16,
                               n_clusters=8, seed=11)


@pytest.fixture(scope="module")
def graph(ds):
    return build_nsg(ds.base, degree=12, knn_k=12, ef_construction=24,
                     passes=1)


def quantized(graph, dtype):
    spec = QuantSpec(dtype=dtype)
    scales = fit_scales(graph.vectors, spec)
    return graph._replace(
        codes=quantize(graph.vectors, spec, scales),
        scales=jnp.asarray(scales, jnp.float32))


BASE = SearchConfig(k=K, queue_len=32, m_max=3, staged=False, max_steps=96)
SPEED = BASE.with_(m_max=4, num_walkers=4, staged=True, local_steps=4)


def assert_batch_matches_vmap(batch_fn, single_fn, graph, queries, cfg):
    """The acceptance bar: batched == vmapped per-query, bit for bit
    (batch-relative overlap counters verify their invariants instead)."""
    ids_b, d_b, st_b = batch_fn(graph, queries, cfg)
    ids_v, d_v, st_v = jax.vmap(
        lambda q: single_fn(graph, q, cfg))(queries)
    np.testing.assert_array_equal(np.asarray(ids_b), np.asarray(ids_v))
    np.testing.assert_array_equal(np.asarray(d_b), np.asarray(d_v))
    for field in st_b._fields:
        if field in SearchStats.BATCH_RELATIVE:
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(st_b, field)),
            np.asarray(getattr(st_v, field)),
            err_msg=f"stats field {field!r} drifted")
    for st in (st_b, st_v):
        u, dup, dc = (np.asarray(st.uniq_comps),
                      np.asarray(st.batch_dup_comps),
                      np.asarray(st.dist_comps))
        np.testing.assert_array_equal(u + dup, dc)
        assert (u >= 0).all() and (dup >= 0).all()
    # a wider batch can only add first-touchers AHEAD of a lane
    assert (np.asarray(st_b.uniq_comps)
            <= np.asarray(st_v.uniq_comps)).all()
    return ids_b


@pytest.mark.parametrize("backend", ["ref", "rowgather", "dma"])
def test_topm_batch_bit_identical_fp32_backends(ds, graph, backend):
    q = jnp.asarray(ds.queries)
    ids = assert_batch_matches_vmap(
        search_topm_batch, search_topm, graph, q,
        BASE.with_(dist_backend=backend))
    assert recall_at_k(np.asarray(ids), ds.gt_ids, K) >= 0.9


@pytest.mark.parametrize("backend", ["ref", "dma"])
def test_speedann_batch_bit_identical(ds, graph, backend):
    q = jnp.asarray(ds.queries)
    ids = assert_batch_matches_vmap(
        search_speedann_batch, search_speedann, graph, q,
        SPEED.with_(dist_backend=backend))
    assert recall_at_k(np.asarray(ids), ds.gt_ids, K) >= 0.9


@pytest.mark.parametrize("backend,dtype", [
    ("ref_int8", "int8"), ("rowgather_int8", "int8"), ("ref_bf16", "bf16")])
def test_batch_bit_identical_quant_backends(ds, graph, backend, dtype):
    gq = quantized(graph, dtype)
    q = jnp.asarray(ds.queries)
    assert_batch_matches_vmap(
        search_topm_batch, search_topm, gq, q,
        BASE.with_(dist_backend=backend))


@pytest.mark.parametrize("metric", ["ip", "cosine"])
def test_batch_bit_identical_across_metrics(ds, metric):
    base = np.asarray(ds.base, np.float32)
    if metric == "cosine":
        base = base / np.maximum(
            np.linalg.norm(base, axis=1, keepdims=True), 1e-12)
    g = build_nsg(base, degree=12, knn_k=12, ef_construction=24, passes=1,
                  metric="l2" if metric == "cosine" else metric)
    q = jnp.asarray(ds.queries)
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=1, keepdims=True), 1e-12)
    assert_batch_matches_vmap(search_topm_batch, search_topm, g, q,
                              BASE.with_(metric=metric))
    assert_batch_matches_vmap(search_speedann_batch, search_speedann, g, q,
                              SPEED.with_(metric=metric))


def test_batch_composition_is_invisible(ds, graph):
    """A query's result must not depend on its batch mates: lanes that
    converge early are exact no-ops while stragglers keep looping."""
    q = jnp.asarray(ds.queries)
    ids_all, d_all, st_all = search_topm_batch(graph, q, BASE)
    # front slice of the batch vs the same queries in a smaller batch
    ids_sub, d_sub, st_sub = search_topm_batch(graph, q[:3], BASE)
    np.testing.assert_array_equal(np.asarray(ids_all)[:3],
                                  np.asarray(ids_sub))
    np.testing.assert_array_equal(np.asarray(d_all)[:3], np.asarray(d_sub))
    for field in st_all._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(st_all, field))[:3],
            np.asarray(getattr(st_sub, field)))
    # B=1 wrapper == the corresponding batch row
    ids_1, d_1, st_1 = search_topm(graph, q[5], BASE)
    np.testing.assert_array_equal(np.asarray(ids_all)[5], np.asarray(ids_1))
    np.testing.assert_array_equal(np.asarray(d_all)[5], np.asarray(d_1))
    assert int(st_1.steps) == int(np.asarray(st_all.steps)[5])


def test_facade_batch_parity_with_rerank(ds):
    """The two-stage (quantized traverse + exact re-rank) facade search is
    batch-major end to end and batch-composition invariant."""
    index = AnnIndex.build(ds.base, IndexSpec(degree=12, passes=1,
                                              quant="int8"))
    params = SearchParams(k=K, queue_len=32, max_steps=96,
                          backend="ref_int8", rerank_k=2 * K)
    q = np.asarray(ds.queries)
    full = index.search(q, params)
    sub = index.search(q[:3], params)
    np.testing.assert_array_equal(np.asarray(full.ids)[:3],
                                  np.asarray(sub.ids))
    np.testing.assert_array_equal(np.asarray(full.dists)[:3],
                                  np.asarray(sub.dists))
    assert recall_at_k(np.asarray(full.ids), ds.gt_ids, K) >= 0.9


def test_engine_inherits_batch_major_path(ds):
    """AnnEngine serves through the index's batch-major searchers: padded
    bucket execution is bit-identical to direct AnnIndex.search."""
    index = AnnIndex.build(ds.base, IndexSpec(degree=12, passes=1))
    params = SearchParams(k=K, queue_len=32, max_steps=96,
                          algorithm="speedann", num_walkers=2)
    engine = index.serve(params, bucket_sizes=(2, 4, 8))
    direct = index.search(ds.queries, params)
    for bsz in (1, 3, 8):
        res = engine.search(ds.queries[:bsz])
        np.testing.assert_array_equal(res.ids,
                                      np.asarray(direct.ids)[:bsz])
        np.testing.assert_array_equal(res.dists,
                                      np.asarray(direct.dists)[:bsz])
    assert engine.jit_cache_size <= 3


def test_max_norm_entry_policy_mips(ds, tmp_path):
    """IndexSpec(entry_policy='max_norm') seeds ip traversals at the
    max-norm vertex, reaches reference recall, and round-trips."""
    rng = np.random.RandomState(3)
    base = np.asarray(ds.base, np.float32) \
        * np.exp(rng.randn(ds.base.shape[0], 1) * 0.6).astype(np.float32)
    spec = IndexSpec(metric="ip", degree=12, passes=1,
                     entry_policy="max_norm")
    index = AnnIndex.build(base, spec)
    norms = np.linalg.norm(base, axis=1)
    assert int(index.graph.medoid) == int(np.argmax(norms))
    gt, _ = index.exact(ds.queries, K)
    res = index.search(ds.queries, SearchParams(k=K, queue_len=64,
                                                max_steps=128))
    assert recall_at_k(np.asarray(res.ids), gt, K) >= 0.9
    # the policy is build-time state: persisted with the spec + medoid
    path = index.save(str(tmp_path / "maxnorm"))
    loaded = AnnIndex.load(path)
    assert loaded.spec.entry_policy == "max_norm"
    assert int(loaded.graph.medoid) == int(index.graph.medoid)
    # default-policy artifacts must NOT carry the key: readers predating
    # entry_policy construct IndexSpec(**spec_json) and would crash on it
    default_index = AnnIndex.build(base, IndexSpec(metric="ip", degree=12,
                                                   passes=1))
    dpath = default_index.save(str(tmp_path / "default"))
    import json as _json
    spec_json = _json.loads(str(np.load(dpath)["spec"]))
    assert "entry_policy" not in spec_json
    assert AnnIndex.load(dpath).spec.entry_policy == "medoid"
    # ...and validated at construction
    with pytest.raises(ValueError, match="max_norm"):
        IndexSpec(metric="l2", entry_policy="max_norm")
    with pytest.raises(ValueError, match="entry_policy"):
        IndexSpec(entry_policy="bogus")


# -- cross-query overlap counters (SearchStats.BATCH_RELATIVE) --------------

def test_batch_unique_counts_numpy_recount():
    """The counting primitive matches a transparent pure-NumPy first-toucher
    recount on recorded candidate grids (ids + counted masks exactly as the
    engines hand them over: per-lane distinct, dead lanes masked out)."""
    rng = np.random.RandomState(7)
    for b, c, idmax in [(1, 6, 9), (4, 8, 12), (8, 5, 400), (6, 7, 7)]:
        ids = rng.randint(0, idmax, size=(b, c)).astype(np.int32)
        counted = rng.rand(b, c) > 0.25
        for lane in range(b):           # enforce per-lane distinctness
            _, first_idx = np.unique(ids[lane], return_index=True)
            keep = np.zeros(c, bool)
            keep[first_idx] = True
            counted[lane] &= keep
        got = np.asarray(batch_unique_counts(jnp.asarray(ids),
                                             jnp.asarray(counted)))
        seen, want = set(), np.zeros(b, np.int64)
        for lane in range(b):
            for slot in range(c):
                if counted[lane, slot] and int(ids[lane, slot]) not in seen:
                    seen.add(int(ids[lane, slot]))
                    want[lane] += 1
        np.testing.assert_array_equal(got, want)
        assert got.sum() == len(seen)


@pytest.mark.parametrize("algo,cfg", [("topm", BASE), ("speedann", SPEED)])
def test_overlap_counters_search_invariants(ds, graph, algo, cfg):
    """Search-level exactness: uniq + dup == dist_comps per lane, an
    identical-queries batch charges every gather to lane 0, and a topm
    B=1 run is all-unique."""
    fn = search_topm_batch if algo == "topm" else search_speedann_batch
    q = jnp.asarray(ds.queries)
    _, _, st = fn(graph, q, cfg)
    u, dup, dc = (np.asarray(st.uniq_comps), np.asarray(st.batch_dup_comps),
                  np.asarray(st.dist_comps))
    np.testing.assert_array_equal(u + dup, dc)
    # degenerate all-duplicates batch: identical lanes -> lane 0 first-
    # touches EVERY computation, later lanes are pure reuse
    q_same = jnp.broadcast_to(q[:1], q.shape)
    _, _, st_same = fn(graph, q_same, cfg)
    u, dup, dc = (np.asarray(st_same.uniq_comps),
                  np.asarray(st_same.batch_dup_comps),
                  np.asarray(st_same.dist_comps))
    assert u[0] == dc[0] if algo == "topm" else u[0] <= dc[0]
    np.testing.assert_array_equal(u[1:], 0)
    np.testing.assert_array_equal(dup[1:], dc[1:])
    if algo == "topm":
        # B=1: no other lane exists, every computation is a first touch
        _, _, st1 = fn(graph, q[:1], cfg)
        np.testing.assert_array_equal(np.asarray(st1.uniq_comps),
                                      np.asarray(st1.dist_comps))
        np.testing.assert_array_equal(np.asarray(st1.batch_dup_comps), 0)


@pytest.mark.parametrize("algo,cfg", [("topm", BASE), ("speedann", SPEED)])
def test_overlap_counters_permutation_invariant(ds, graph, algo, cfg):
    """Batch-composition invariance for the overlap counters: per-lane
    attribution follows lane order (first-toucher), but the batch TOTALS —
    how many gathers a dedup backend runs — are permutation invariant, and
    every non-batch-relative counter permutes exactly with its query."""
    fn = search_topm_batch if algo == "topm" else search_speedann_batch
    q = jnp.asarray(ds.queries)
    perm = np.random.RandomState(0).permutation(q.shape[0])
    _, _, st = fn(graph, q, cfg)
    _, _, st_p = fn(graph, q[jnp.asarray(perm)], cfg)
    for field in st._fields:
        a = np.asarray(getattr(st, field))
        b = np.asarray(getattr(st_p, field))
        if field in SearchStats.BATCH_RELATIVE:
            assert a.sum() == b.sum(), field
        else:
            np.testing.assert_array_equal(a[perm], b, err_msg=field)
