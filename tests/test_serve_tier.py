"""Serving tier: result cache, admission control, replica routing.

The serving-tier guarantees, pinned:

* **cache** — a hit REPLAYS the engine's answer bit for bit, and a miss
  falls through to serving unchanged, so caching never changes results;
  key equality implies quantized-code equality (Hypothesis), so a false
  hit is impossible by construction; LRU / TTL / recall-guard semantics;
* **admission** — decisions are monotone in queue depth, and the critical
  class is never shed before the throughput class (both Hypothesis-swept
  over random policies); under a deterministic modeled overload, critical
  p99 WITH admission control is strictly lower than without;
* **router** — routed results match a single engine bit for bit
  (replicated mode is data-parallel over identical replicas); hedged
  requests resolve exactly once with the duplicate answer deduplicated;
  sharded fan-out merges per-shard top-k deterministically;
* **drain** — ``close(drain=True)`` returns only after in-flight batches
  have resolved their futures (the drain-under-load regression).

Timing-sensitive tests run on the deterministic harness
(``tests/serving_harness.py``): virtual clock, scripted arrivals, modeled
service time — no ``time.sleep`` anywhere in this file.
"""
import threading

import numpy as np
import pytest

try:  # property sweeps want hypothesis (requirements-dev); the rest of the
    # file runs without it, matching tests/test_quant.py
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare installs
    class _NoStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _NoStrategy()

    def given(**kw):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(**kw):
        return lambda f: f

from repro.ann import AnnIndex, IndexSpec, SearchParams  # noqa: E402
from repro.data import make_vector_dataset  # noqa: E402
from repro.quant import cache_codes, query_cache_key  # noqa: E402
from repro.serve import (AdmissionController, AdmissionPolicy,  # noqa: E402
                         AdmissionRejected, AsyncAnnEngine, CachePolicy,
                         CoalescePolicy, ReplicaRouter, ResultCache,
                         RouterPolicy)
from repro.serve.coalescer import _Pending, select_batch  # noqa: E402
from serving_harness import (Arrival, ServingHarness,  # noqa: E402
                             VirtualClock, poisson_schedule)

BUCKETS = (1, 2, 4, 8)
PARAMS = SearchParams(k=10, queue_len=48, m_max=4, num_walkers=4,
                      max_steps=128, local_steps=4)


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=1200, n_queries=16, k=10, dim=24,
                               n_clusters=12, seed=7)


@pytest.fixture(scope="module")
def index(ds):
    return AnnIndex.build(ds, IndexSpec(degree=12, passes=1))


class _Res:
    def __init__(self, ids, dists):
        self.ids, self.dists, self.latency_ms = ids, dists, 0.25


class FakeEngine:
    """Engine double: deterministic per-query answers derived from the
    query itself, so parity and replay checks work without a real index."""

    def __init__(self, k=4):
        self.k = k
        self.calls = 0

    def search(self, queries):
        q = np.atleast_2d(np.asarray(queries, np.float32))
        self.calls += 1
        ids = np.rint(q[:, :self.k] * 100).astype(np.int32)
        dists = q[:, :self.k] * np.float32(0.5)
        return _Res(ids, dists)


# -- cache: keys ---------------------------------------------------------------

@given(seed=st.integers(0, 10_000), d=st.integers(1, 48),
       scale=st.sampled_from([1e-3, 1.0, 50.0]))
@settings(max_examples=30, deadline=None)
def test_cache_key_equality_implies_code_equality(seed, d, scale):
    """No false hits by construction: two queries share a cache key IFF
    their int8 codes AND scale are identical — the key is those bytes."""
    rng = np.random.RandomState(seed)
    q1 = (rng.randn(d) * scale).astype(np.float32)
    q2 = (rng.randn(d) * scale).astype(np.float32)
    c1, s1 = cache_codes(q1)
    c2, s2 = cache_codes(q2)
    same_codes = bool(np.array_equal(c1, c2) and s1 == s2)
    assert (query_cache_key(q1) == query_cache_key(q2)) == same_codes
    # and the key is a pure function of the query
    assert query_cache_key(q1) == query_cache_key(q1.copy())


def test_cache_key_stable_bytes():
    key = query_cache_key(np.arange(8, dtype=np.float32))
    assert isinstance(key, bytes) and len(key) == 8 + 4  # d int8 + f32 scale


# -- cache: semantics ----------------------------------------------------------

def _ids(*vals):
    return np.asarray(vals, np.int32)


def test_cache_lru_eviction_order():
    c = ResultCache(CachePolicy(capacity=2))
    qa, qb, qc = (np.full(4, v, np.float32) for v in (1.0, 2.0, 3.0))
    c.insert(qa, _ids(1), _ids(1))
    c.insert(qb, _ids(2), _ids(2))
    assert c.lookup(qa) is not None       # touch a: b is now LRU
    c.insert(qc, _ids(3), _ids(3))        # evicts b, not a
    assert c.lookup(qb) is None
    assert c.lookup(qa) is not None and c.lookup(qc) is not None
    st_ = c.stats()
    assert st_["evictions"] == 1 and st_["size"] == 2


def test_cache_ttl_expiry_on_virtual_clock():
    clock = VirtualClock()
    c = ResultCache(CachePolicy(capacity=8, ttl_s=1.0), clock=clock)
    q = np.ones(4, np.float32)
    c.insert(q, _ids(7), _ids(7))
    clock.advance(0.5)
    assert c.lookup(q) is not None        # young enough
    clock.advance(1.0)
    assert c.lookup(q) is None            # aged out
    st_ = c.stats()
    assert st_["expirations"] == 1 and st_["size"] == 0
    c.insert(q, _ids(7), _ids(7))         # re-insert restarts the TTL
    assert c.lookup(q) is not None


def test_cache_recall_guard_demotes_colliding_query():
    """Two DIFFERENT queries can share a key (same codes after rounding);
    guard_eps=0 refuses to replay across them, a loose guard allows it."""
    codes, scale = cache_codes(np.array([1.0, 0.5, 0.0, 0.0], np.float32))
    base = (codes.astype(np.float32) * scale)        # exactly on the grid
    drift = base.copy()
    drift[1] += scale / 4                            # same cell, new vector
    assert query_cache_key(base) == query_cache_key(drift)
    strict = ResultCache(CachePolicy(capacity=4, guard_eps=0.0))
    strict.insert(base, _ids(1), _ids(1))
    assert strict.lookup(drift) is None              # guarded
    assert strict.stats()["guard_misses"] == 1
    loose = ResultCache(CachePolicy(capacity=4, guard_eps=1.0))
    loose.insert(base, _ids(1), _ids(1))
    assert loose.lookup(drift) is not None           # within the bound


def test_cache_insert_refreshes_existing_key():
    c = ResultCache(CachePolicy(capacity=2))
    q = np.ones(4, np.float32)
    c.insert(q, _ids(1), _ids(1))
    c.insert(q, _ids(2), _ids(2))
    hit = c.lookup(q)
    assert list(hit[0]) == [2] and len(c) == 1
    assert c.stats()["evictions"] == 0


def test_cache_policy_validation():
    with pytest.raises(ValueError, match="capacity"):
        ResultCache(CachePolicy(capacity=0))
    with pytest.raises(ValueError, match="ttl_s"):
        ResultCache(CachePolicy(ttl_s=0.0))
    with pytest.raises(ValueError, match="guard_eps"):
        ResultCache(CachePolicy(guard_eps=-1.0))


# -- cache through the coalescer: bit-identical replay -------------------------

def test_cache_hit_bit_identical_to_direct_search(ds, index):
    """THE cache pin: a miss falls through unchanged, and the hit replay
    of the same query returns byte-identical arrays to AnnIndex.search."""
    srv = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS,
                            cache=CachePolicy(capacity=16))
    q = ds.queries[0]
    miss = srv.submit(q)
    assert srv.flush() == 1
    hit = srv.submit(q)                   # resolved without any flush
    r_miss, r_hit = miss.result(timeout=0), hit.result(timeout=0)
    direct = index.search(q[None], PARAMS)
    np.testing.assert_array_equal(r_miss.ids, np.asarray(direct.ids)[0])
    np.testing.assert_array_equal(r_miss.dists, np.asarray(direct.dists)[0])
    np.testing.assert_array_equal(r_hit.ids, r_miss.ids)
    np.testing.assert_array_equal(r_hit.dists, r_miss.dists)
    assert r_hit.batch_size == 0.0 and r_hit.latency_ms == 0.0
    st_ = srv.stats()
    assert st_["served"] == 1 and st_["served_cache"] == 1
    assert srv.cache.stats()["hits"] == 1
    srv.close()


def test_cached_and_uncached_miss_paths_identical(ds, index):
    """Serving WITH a (cold) cache returns the same answers as serving
    without one — the cache only ever replays, never computes."""
    plain = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS)
    cached = index.serve_async(PARAMS, start=False, bucket_sizes=BUCKETS,
                               cache=CachePolicy(capacity=16))
    f_plain = [plain.submit(q) for q in ds.queries[:4]]
    f_cached = [cached.submit(q) for q in ds.queries[:4]]
    plain.flush(), cached.flush()
    for fp, fc in zip(f_plain, f_cached):
        np.testing.assert_array_equal(fp.result().ids, fc.result().ids)
        np.testing.assert_array_equal(fp.result().dists, fc.result().dists)
    assert cached.cache.stats()["hits"] == 0      # all cold misses
    plain.close(), cached.close()


# -- admission: properties -----------------------------------------------------

@given(tw=st.integers(1, 100), extra=st.integers(0, 100),
       d1=st.integers(0, 300), d2=st.integers(0, 300))
@settings(max_examples=60, deadline=None)
def test_admission_monotone_in_queue_depth(tw, extra, d1, d2):
    """Admitted at depth d ⇒ admitted at every shallower depth (for every
    class): admission never flips back on as the queue grows."""
    pol = AdmissionPolicy(throughput_watermark=tw,
                          critical_watermark=tw + extra)
    lo, hi = min(d1, d2), max(d1, d2)
    for priority in ("critical", "throughput"):
        if pol.admits(hi, priority):
            assert pol.admits(lo, priority)


@given(tw=st.integers(1, 100), extra=st.integers(0, 100),
       depth=st.integers(0, 300))
@settings(max_examples=60, deadline=None)
def test_critical_never_shed_before_throughput(tw, extra, depth):
    pol = AdmissionPolicy(throughput_watermark=tw,
                          critical_watermark=tw + extra)
    if not pol.admits(depth, "critical"):          # critical shed here...
        assert not pol.admits(depth, "throughput")  # ...so throughput too


def test_admission_policy_validation():
    with pytest.raises(ValueError, match="throughput_watermark"):
        AdmissionPolicy(throughput_watermark=0)
    with pytest.raises(ValueError, match="never shed before"):
        AdmissionPolicy(throughput_watermark=8, critical_watermark=4)
    with pytest.raises(ValueError, match="unknown priority"):
        AdmissionPolicy().admits(0, "bulk")


def test_admission_through_submit_sheds_throughput_first():
    srv = AsyncAnnEngine(
        FakeEngine(), CoalescePolicy(max_batch=8, max_wait_ms=1.0),
        start=False,
        admission=AdmissionPolicy(throughput_watermark=1,
                                  critical_watermark=2))
    q = np.arange(4, dtype=np.float32)
    keep = srv.submit(q, priority="throughput")        # depth 0: admitted
    shed_t = srv.submit(q + 1, priority="throughput")  # depth 1: shed
    keep_c = srv.submit(q + 2, priority="critical")    # depth 1: admitted
    shed_c = srv.submit(q + 3, priority="critical")    # depth 2: shed
    with pytest.raises(AdmissionRejected):
        shed_t.result(timeout=0)
    with pytest.raises(AdmissionRejected):
        shed_c.result(timeout=0)
    srv.flush()
    assert keep.result(timeout=0).ids.shape == (4,)
    assert keep_c.result(timeout=0).ids.shape == (4,)
    st_ = srv.stats()
    assert st_["rejected_admission"] == 2 and st_["served"] == 2
    adm = srv.admission.stats()
    assert adm["shed_throughput"] == 1 and adm["shed_critical"] == 1
    srv.close()
    with pytest.raises(ValueError, match="unknown priority"):
        srv.submit(q, priority="bulk")


def test_priority_ranks_batch_formation():
    """Critical requests sort ahead of throughput requests in batch
    formation even with LATER deadlines; EDF applies within a class."""
    def pend(seq, deadline_t, priority):
        return _Pending(seq=seq, query=np.zeros(2, np.float32),
                        enqueue_t=0.0, deadline_t=deadline_t, future=None,
                        priority=priority)
    pending = [pend(0, 1.0, priority=1), pend(1, 9.0, priority=0),
               pend(2, 5.0, priority=1), pend(3, 2.0, priority=0)]
    batch, expired, rest = select_batch(pending, now=0.0, max_batch=3)
    assert [p.seq for p in batch] == [3, 1, 0]   # critical EDF, then tput
    assert [p.seq for p in rest] == [2]


# -- admission: overload tail (deterministic, modeled service time) ------------

def _overloaded_run(admission):
    """Replay one fixed Poisson overload (offered ~3x modeled capacity,
    half the traffic critical) and return (critical p99, harness, srv)."""
    clock = VirtualClock()
    srv = AsyncAnnEngine(
        FakeEngine(),
        CoalescePolicy(max_batch=4, max_wait_ms=2.0),
        start=False, clock=clock, admission=admission)
    harness = ServingHarness(srv, clock, service_time_s=0.010)  # 400 req/s
    rng = np.random.default_rng(42)
    queries = np.arange(32, dtype=np.float32)[:, None] * np.ones(
        (1, 8), np.float32)
    arrivals = poisson_schedule(rng, queries, qps=1200.0, duration_s=0.4,
                                critical_fraction=0.5)
    result = harness.run(arrivals)
    lats = harness.client_latencies_ms(arrivals, result,
                                       priority="critical")
    assert lats, "no critical request survived the overload"
    return float(np.percentile(lats, 99)), harness, srv


def test_admission_bounds_critical_p99_under_overload():
    """The acceptance pin: identical overloaded arrivals, critical-class
    p99 WITH admission control strictly below without — shedding the
    throughput class keeps the critical queue (and its tail) short."""
    p99_off, _, srv_off = _overloaded_run(admission=None)
    p99_on, _, srv_on = _overloaded_run(
        admission=AdmissionPolicy(throughput_watermark=4,
                                  critical_watermark=12))
    assert p99_on < p99_off
    adm = srv_on.admission.stats()
    assert adm["shed_throughput"] > 0              # overload DID shed
    assert adm["shed_throughput"] >= adm["shed_critical"]
    assert srv_off.stats()["rejected_admission"] == 0
    srv_off.close(), srv_on.close()


def test_harness_replay_is_deterministic():
    """Same schedule, same policies → bit-identical outcomes and stats."""
    def run():
        _, harness, srv = _overloaded_run(
            admission=AdmissionPolicy(throughput_watermark=4,
                                      critical_watermark=12))
        st_ = srv.stats()
        srv.close()
        return st_
    a, b = run(), run()
    for key in ("submitted", "served", "rejected_admission",
                "batches_dispatched"):
        assert a[key] == b[key]


# -- drain under load ----------------------------------------------------------

class _BlockingEngine:
    """Engine whose search parks until released — freezes a batch in
    flight so the close()/drain race is reachable deterministically."""

    def __init__(self):
        self.entered = threading.Event()
        self.release = threading.Event()

    def search(self, queries):
        q = np.atleast_2d(queries)
        self.entered.set()
        assert self.release.wait(timeout=30)
        return _Res(np.zeros((q.shape[0], 4), np.int32),
                    np.zeros((q.shape[0], 4), np.float32))


def test_close_drain_waits_for_inflight_batch():
    """Drain-under-load regression: a flush that popped its batch leaves
    the queue EMPTY while the engine still runs — close(drain=True) must
    wait for those futures, not return on the empty queue."""
    eng = _BlockingEngine()
    srv = AsyncAnnEngine(eng, CoalescePolicy(max_batch=2, max_wait_ms=0.0),
                         start=False)
    futs = [srv.submit(np.full(4, v, np.float32)) for v in (1.0, 2.0)]
    worker = threading.Thread(target=srv.flush, daemon=True)
    worker.start()
    assert eng.entered.wait(timeout=10)   # batch popped, search in flight
    state = {}

    def closer():
        srv.close(drain=True)
        state["done_at_close"] = all(f.done() for f in futs)

    ct = threading.Thread(target=closer, daemon=True)
    ct.start()
    ct.join(timeout=0.25)
    assert ct.is_alive(), "close() returned while a batch was in flight"
    eng.release.set()
    ct.join(timeout=10)
    assert not ct.is_alive()
    assert state["done_at_close"], "close() returned before futures resolved"
    worker.join(timeout=10)
    for f in futs:
        assert f.result(timeout=0).ids.shape == (4,)


# -- router --------------------------------------------------------------------

def test_router_parity_with_single_engine(ds, index):
    """Replicated routing is transparent: results through a 2-replica
    router (direct AND coalesced) match AnnIndex.search bit for bit."""
    replicas = [index.serve(PARAMS, bucket_sizes=BUCKETS) for _ in range(2)]
    router = ReplicaRouter(replicas,
                           policy=RouterPolicy(strategy="round_robin"))
    direct = index.search(ds.queries[:4], PARAMS)
    for _ in range(2):                     # both replicas take a turn
        res = router.search(ds.queries[:4])
        np.testing.assert_array_equal(res.ids, np.asarray(direct.ids))
        np.testing.assert_array_equal(res.dists, np.asarray(direct.dists))
    srv = AsyncAnnEngine(router, CoalescePolicy(max_batch=8), start=False)
    futs = [srv.submit(q) for q in ds.queries[:4]]
    srv.flush()
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(f.result().ids,
                                      np.asarray(direct.ids)[i])
    srv.close()
    st_ = router.stats()
    assert st_["replica0_served"] + st_["replica1_served"] == 3
    router.close()


def test_hedged_request_resolves_once_and_dedups():
    """A hedge fires on deadline risk, the fast replica wins, and the
    slow duplicate is discarded + counted — never double-resolved."""
    slow_gate = threading.Event()

    class SlowEngine(FakeEngine):
        def search(self, queries):
            assert slow_gate.wait(timeout=30)
            return super().search(queries)

    slow, fast = SlowEngine(), FakeEngine()
    router = ReplicaRouter(
        [slow, fast],
        policy=RouterPolicy(strategy="round_robin", hedge_after_ms=5.0))
    q = np.arange(8, dtype=np.float32)[None]
    res = router.search(q)
    assert res.hedged and res.replica == 1
    np.testing.assert_array_equal(res.ids, FakeEngine().search(q).ids)
    slow_gate.set()
    router.drain_hedges()
    st_ = router.stats()
    assert st_["requests"] == 1            # resolved exactly once
    assert st_["hedges"] == 1 and st_["hedge_wins"] == 1
    assert st_["hedge_discarded"] == 1     # the duplicate, counted not used
    router.close()


def test_router_failover_marks_unhealthy_then_recovers():
    clock = VirtualClock()

    class DownEngine(FakeEngine):
        def __init__(self):
            super().__init__()
            self.down = True

        def search(self, queries):
            if self.down:
                raise RuntimeError("replica down")
            return super().search(queries)

    flaky, steady = DownEngine(), FakeEngine()
    router = ReplicaRouter(
        [flaky, steady],
        policy=RouterPolicy(strategy="round_robin", hedge_after_ms=50.0,
                            max_failures=1, cooldown_s=10.0),
        clock=clock)
    q = np.arange(8, dtype=np.float32)[None]
    res = router.search(q)
    assert res.replica == 1 and res.hedged          # failed over
    assert router.stats()["replica0_healthy"] == 0.0
    res = router.search(q)
    assert res.replica == 1 and not res.hedged      # unhealthy one skipped
    flaky.down = False
    clock.advance(11.0)                             # cooldown lapses
    res = router.search(q)
    assert res.replica == 0                         # re-probed and serving
    assert router.stats()["failovers"] == 1
    router.close()


def test_sharded_router_merges_global_topk():
    """Corpus-sharded fan-out: per-shard local top-k remaps through shard
    offsets and merges into a deterministic global top-k."""
    class Shard(FakeEngine):
        def __init__(self, dists):
            super().__init__()
            self._d = np.asarray(dists, np.float32)

        def search(self, queries):
            q = np.atleast_2d(queries)
            b = q.shape[0]
            return _Res(np.tile(np.arange(4, dtype=np.int32), (b, 1)),
                        np.tile(self._d, (b, 1)))

    router = ReplicaRouter(
        [Shard([0.1, 0.4, 0.6, 0.9]), Shard([0.2, 0.3, 0.7, 0.8])],
        mode="sharded", shard_offsets=[0, 1000])
    res = router.search(np.ones((2, 8), np.float32))
    assert res.replica == -1 and res.ids.shape == (2, 4)
    np.testing.assert_array_equal(res.ids[0], [0, 1000, 1001, 1])
    np.testing.assert_array_equal(res.ids[0], res.ids[1])
    assert list(res.dists[0]) == sorted(res.dists[0])
    router.close()


def test_router_validation():
    with pytest.raises(ValueError, match="at least one"):
        ReplicaRouter([])
    with pytest.raises(ValueError, match="mode"):
        ReplicaRouter([FakeEngine()], mode="mirrored")
    with pytest.raises(ValueError, match="strategy"):
        ReplicaRouter([FakeEngine()],
                      policy=RouterPolicy(strategy="random"))
    with pytest.raises(ValueError, match="shard offset"):
        ReplicaRouter([FakeEngine(), FakeEngine()], mode="sharded",
                      shard_offsets=[0])
    with pytest.raises(ValueError, match="sharded"):
        ReplicaRouter([FakeEngine()], shard_offsets=[0])
