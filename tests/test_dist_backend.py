"""The pluggable distance-backend seam (SearchConfig.dist_backend).

Asserts the acceptance bar for the kernel-backed hot path: inside a *full*
``search_topm`` run the Pallas backends must retrace the reference search —
same result ids, same recall — and the DMA tile padding must be transparent
for candidate counts not divisible by the tile size.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SearchConfig
from repro.core import (build_nsg, recall_at_k, resolve_dist_fn,
                        search_speedann_batch, search_topm_batch)
from repro.data import make_vector_dataset
from repro.kernels import (available_backends, l2dist, make_dist_fn,
                           pad_ids_to_tile, resolve_backend)
from repro.kernels import ref as kref


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("deep", n=1200, n_queries=16, k=10, dim=24,
                               n_clusters=12, seed=7)


@pytest.fixture(scope="module")
def graph(ds):
    # degree chosen so M*R is NOT a multiple of the DMA tile (see below)
    return build_nsg(ds.base, degree=12, knn_k=12, ef_construction=24,
                     passes=1)


# m_max=3, degree=12 -> C = 36 candidates per expansion, 36 % 8 != 0:
# every "dma" expansion exercises the tile-padding path.
BASE = SearchConfig(k=10, queue_len=48, m_max=3, staged=False, max_steps=128)


def test_registry_exposes_builtin_backends():
    assert set(available_backends()) >= {"ref", "rowgather", "dma"}
    with pytest.raises(ValueError, match="unknown dist_backend"):
        resolve_backend(BASE.with_(dist_backend="nope"))


def test_explicit_dist_fn_overrides_config():
    sentinel = make_dist_fn("rowgather")
    assert resolve_dist_fn(BASE.with_(dist_backend="dma"),
                           sentinel) is sentinel


@pytest.fixture(scope="module")
def ref_run(ds, graph):
    q = jnp.asarray(ds.queries)
    ids, dists, stats = search_topm_batch(
        graph, q, BASE.with_(dist_backend="ref"))
    return np.asarray(ids), np.asarray(dists), stats


@pytest.mark.parametrize("backend", ["rowgather", "dma"])
def test_backend_parity_inside_search_topm(ds, graph, ref_run, backend):
    """Kernel backends retrace the reference search: same ids, same recall."""
    ids_ref, d_ref, _ = ref_run
    ids, dists, _ = search_topm_batch(
        graph, jnp.asarray(ds.queries), BASE.with_(dist_backend=backend))
    ids, dists = np.asarray(ids), np.asarray(dists)
    np.testing.assert_array_equal(ids, ids_ref)
    assert recall_at_k(ids, ds.gt_ids, 10) == \
        recall_at_k(ids_ref, ds.gt_ids, 10)
    fin = np.isfinite(d_ref)
    np.testing.assert_allclose(dists[fin], d_ref[fin], rtol=1e-4, atol=1e-4)


def test_rowgather_distances_bitwise_equal(ds, graph, ref_run):
    """rowgather computes the same diff-and-square reduction as ref —
    distances must match bit for bit, not just approximately."""
    _, d_ref, _ = ref_run
    _, dists, _ = search_topm_batch(
        graph, jnp.asarray(ds.queries), BASE.with_(dist_backend="rowgather"))
    np.testing.assert_array_equal(np.asarray(dists), d_ref)


def test_backend_parity_inside_speedann(ds, graph):
    """Algorithm 3 (private walkers + lazy sync) is also kernel-backed."""
    q = jnp.asarray(ds.queries)
    cfg = BASE.with_(m_max=4, num_walkers=4, staged=True, local_steps=4)
    ids_ref, _, _ = search_speedann_batch(graph, q,
                                          cfg.with_(dist_backend="ref"))
    ids_dma, _, _ = search_speedann_batch(graph, q,
                                          cfg.with_(dist_backend="dma"))
    r_ref = recall_at_k(np.asarray(ids_ref), ds.gt_ids, 10)
    r_dma = recall_at_k(np.asarray(ids_dma), ds.gt_ids, 10)
    assert r_ref >= 0.9
    assert r_dma == r_ref
    np.testing.assert_array_equal(np.asarray(ids_dma), np.asarray(ids_ref))


def test_dma_padding_edge_case_kernel_level():
    """C not divisible by the tile: padded ids are sentinels, distances for
    the real candidates are unaffected, padding slots report +inf."""
    rng = np.random.RandomState(0)
    n, d, c, g = 200, 16, 13, 8          # 13 % 8 != 0
    table = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, n, size=(c,)).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(1, d)).astype(np.float32))

    padded = pad_ids_to_tile(ids, g, n)
    assert padded.shape[0] == 16
    assert int(padded.shape[0]) % g == 0
    np.testing.assert_array_equal(np.asarray(padded[:c]), np.asarray(ids))
    assert (np.asarray(padded[c:]) == n).all()

    got = l2dist(table, padded[None, :], q, impl="dma", g=g)
    want = kref.l2dist_ref(table, ids[None, :], q)
    np.testing.assert_allclose(np.asarray(got)[0, :c], np.asarray(want)[0],
                               rtol=1e-5, atol=1e-5)
    assert np.isinf(np.asarray(got)[0, c:]).all()


def test_pad_ids_noop_when_aligned():
    ids = jnp.arange(16, dtype=jnp.int32)
    assert pad_ids_to_tile(ids, 8, 100) is ids
