"""Training substrate: optimizer behaviour, loss goes down, microbatch
equivalence, checkpoint round-trip, fault recovery, schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.configs import get_smoke_config
from repro.data.tokens import TokenStream, _batch_at
from repro.models import build_model
from repro.optim import clip_by_global_norm, global_norm, warmup_cosine
from repro.runtime import FailureInjector
from repro.train import Trainer, make_train_step
from repro.train.train_step import init_train_state


def _setup(tmp_path, arch="llama3.2-3b", **tkw):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(total_steps=30, warmup_steps=2, learning_rate=3e-3,
                       checkpoint_every=5, checkpoint_dir=str(tmp_path),
                       **tkw)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=16, batch=4,
                         seed=0, shard=0, num_shards=1)
    return model, tcfg, stream


def test_loss_decreases(tmp_path):
    model, tcfg, stream = _setup(tmp_path)
    tr = Trainer(model, tcfg, stream)
    tr.run(steps=30)
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_microbatch_equivalence(tmp_path):
    """grad accumulation over k microbatches == one big batch (same update)."""
    model, tcfg, stream = _setup(tmp_path)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    batch = jax.tree.map(jnp.asarray, _batch_at(stream, 0))

    s1, m1 = jax.jit(make_train_step(model, tcfg))(state, batch)
    import dataclasses
    tcfg2 = dataclasses.replace(tcfg, microbatches=2)
    s2, m2 = jax.jit(make_train_step(model, tcfg2))(state, batch)

    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 3e-2, d


def test_adafactor_state_is_factored(tmp_path):
    model, tcfg, stream = _setup(tmp_path, optimizer="adafactor")
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    # second-moment memory is O(rows + cols), not O(rows*cols)
    p_bytes = sum(x.size for x in jax.tree.leaves(state.params))
    o_bytes = sum(x.size for x in jax.tree.leaves(state.opt))
    assert o_bytes < 0.2 * p_bytes
    batch = jax.tree.map(jnp.asarray, _batch_at(stream, 0))
    s1, m1 = jax.jit(make_train_step(model, tcfg))(state, batch)
    assert np.isfinite(float(m1["loss"]))


def test_bf16_moments(tmp_path):
    model, tcfg, stream = _setup(tmp_path, moment_dtype="bfloat16")
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    assert all(x.dtype == jnp.bfloat16
               for x in jax.tree.leaves(state.opt["m"]))
    batch = jax.tree.map(jnp.asarray, _batch_at(stream, 0))
    _, m = jax.jit(make_train_step(model, tcfg))(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_checkpoint_roundtrip_and_resume(tmp_path):
    model, tcfg, stream = _setup(tmp_path)
    tr = Trainer(model, tcfg, stream)
    state = tr.run(steps=10)
    tr.ckpt.wait()
    # a fresh trainer resumes from step 10 with identical params
    tr2 = Trainer(model, tcfg, stream)
    st2, step = tr2.init_or_resume()
    assert step == 10
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_recovery_continues_training(tmp_path):
    """Crash at steps 7 and 13 -> recover from checkpoints -> finish."""
    model, tcfg, stream = _setup(tmp_path)
    tr = Trainer(model, tcfg, stream)
    inj = FailureInjector([7, 13])
    state = tr.run(steps=20, fault_hook=inj)
    assert inj.fired == {7, 13}
    assert int(np.asarray(state.opt["step"])) == 20


def test_fault_recovery_is_deterministic(tmp_path):
    """Recovered run == uninterrupted run (same data order, same ckpts)."""
    model, tcfg, stream = _setup(tmp_path)
    t_clean = Trainer(model, tcfg, stream)
    clean = t_clean.run(steps=12)
    t_clean.ckpt.wait()

    import shutil
    shutil.rmtree(tcfg.checkpoint_dir)
    t_fault = Trainer(model, tcfg, stream)
    faulty = t_fault.run(steps=12, fault_hook=FailureInjector([8]))
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(faulty.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_schedule_and_clip():
    lr0 = float(warmup_cosine(0, 1e-3, 10, 100))
    lr10 = float(warmup_cosine(10, 1e-3, 10, 100))
    lr100 = float(warmup_cosine(100, 1e-3, 10, 100))
    assert lr0 == 0.0 and abs(lr10 - 1e-3) < 1e-9 and lr100 < 2e-4
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - np.sqrt(250.0)) < 1e-3
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
