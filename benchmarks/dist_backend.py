"""Distance-backend sweep: fp32 vs quantized backends in the real search loop.

``PYTHONPATH=src python -m benchmarks.run --sweep-backends``

Runs the same top-M search (and the full Speed-ANN searcher) through the
``AnnIndex`` facade with every registered distance backend — the fp32 ones
(ref | rowgather | dma) on a fp32 index and the quantized ones (ref_int8 |
rowgather_int8 | ref_bf16) on int8/bf16 indices with the two-stage exact
re-rank enabled — and records per-backend wall time, recall, and parity
against the ``ref`` backend into ``BENCH_dist_backend.json``.  Every row
carries a ``quant`` key so the trajectory tracks fp32 vs int8/bf16 on the
same host.  The file is a TRAJECTORY: each sweep APPENDS its rows, replacing
only rows with the same (searcher, backend, host, interpret) key — so this
container's interpret-mode numbers and future Mosaic/TPU numbers from other
hosts accumulate side by side instead of overwriting each other.  On this
CPU container the Pallas backends run in interpret mode, so absolute times
measure the emulation, not Mosaic.
"""
from __future__ import annotations

import platform
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (dataset, nsg_index, time_batched,
                               write_trajectory)
from repro.ann import SearchParams
from repro.core import recall_at_k
from repro.kernels import available_backends
from repro.kernels import ops as kops
from repro.quant.scheme import required_quant_dtype

K = 10
BASE = SearchParams(k=K, queue_len=64, m_max=6, num_walkers=4,
                    max_steps=256, local_steps=4, sync_ratio=0.8)
# quantized rows run the full AQR-HNSW two-stage shape: quantized traversal
# over a pool widened to RERANK_K, then exact f32 re-ranking — that is the
# configuration whose recall is comparable to the fp32 rows
RERANK_K = 2 * K


def _row_key(row: Dict) -> tuple:
    """Identity of a trajectory row: same key ⇒ newer run supersedes.

    ``batch`` distinguishes the --sweep-batch rows (one per batch size B)
    from the plain backend rows (no batch key ⇒ None), so both families
    accumulate side by side in the same trajectory file."""
    return (row.get("searcher"), row.get("backend"), row.get("batch"),
            row.get("host", "<unknown>"), row.get("interpret"))


def _hostless_superseded(row: Dict, new_rows: list) -> bool:
    """Legacy rows written before the ``host`` field existed cannot name
    their machine; they are superseded by any new row with the same
    (searcher, backend, interpret) — otherwise a re-run on the very machine
    that wrote them would double-count it in the trajectory forever."""
    if "host" in row:
        return False
    return (row.get("searcher"), row.get("backend"),
            row.get("interpret")) in {
        (r.get("searcher"), r.get("backend"), r.get("interpret"))
        for r in new_rows}


def sweep(out_path: str = "BENCH_dist_backend.json", n: int = 2000,
          q: int = 16) -> Dict:
    """One row per (searcher, backend); appends to the JSON trajectory."""
    ds = dataset(n=n, q=q)
    queries = jnp.asarray(ds.queries)
    host = platform.node() or platform.machine()

    rows = []
    ref_ids: Dict[str, np.ndarray] = {}
    # ref first: it is the parity baseline for the other rows.  Each backend
    # runs on the index whose storage it reads (fp32 | int8 | bf16); the
    # graphs are built with identical parameters, only the table differs.
    backends = ("ref",) + tuple(
        b for b in available_backends() if b != "ref")
    indices = {quant: nsg_index(ds, degree=16, quant=quant)
               for quant in {required_quant_dtype(b) for b in backends}}
    for searcher in ("topm", "speedann"):
        for backend in backends:
            quant = required_quant_dtype(backend)
            rerank_k = RERANK_K if quant != "none" else 0
            fn = indices[quant].searcher(BASE.with_(
                algorithm=searcher, backend=backend, rerank_k=rerank_k))
            ids, _, stats = fn(queries)
            us = time_batched(fn, queries)
            ids = np.asarray(ids)
            if backend == "ref":
                ref_ids[searcher] = ids
            row = {
                "searcher": searcher,
                "backend": backend,
                "quant": quant,
                "rerank_k": rerank_k,
                "host": host,
                "interpret": bool(kops.INTERPRET),
                # dataset scale rides on every row: rows from sweeps with
                # different configs coexist in the trajectory, so the
                # top-level "config" (latest run) must not be trusted per row
                "n": n,
                "q": q,
                "unix_time": time.time(),
                "us_per_query": us / q,
                "recall_at_k": recall_at_k(ids, ds.gt_ids, K),
                "dist_comps": float(np.mean(np.asarray(stats.dist_comps))),
                "ids_match_ref": bool(
                    np.array_equal(ids, ref_ids[searcher])),
            }
            rows.append(row)
            print(f"bench_backend_{searcher}_{backend},"
                  f"{row['us_per_query']:.1f},"
                  f"recall={row['recall_at_k']:.3f};"
                  f"quant={quant};"
                  f"ids_match_ref={row['ids_match_ref']}")

    return write_trajectory(
        out_path, "dist_backend", rows, _row_key,
        config={"n": n, "q": q, "k": K, "m_max": BASE.m_max,
                "queue_len": BASE.queue_len, "dma_group": BASE.dma_group},
        superseded=_hostless_superseded)


if __name__ == "__main__":
    sweep()
