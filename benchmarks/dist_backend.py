"""Distance-backend sweep: ref vs rowgather vs dma in the real search loop.

``PYTHONPATH=src python -m benchmarks.run --sweep-backends``

Runs the same top-M search (and the full Speed-ANN searcher) with every
registered distance backend and records per-backend wall time, recall, and
parity against the ``ref`` backend into ``BENCH_dist_backend.json`` — the
trajectory file future kernel PRs append to.  On this CPU container the
Pallas backends run in interpret mode, so absolute times measure the
emulation, not Mosaic; the JSON keeps ``interpret`` alongside each row so
TPU runs are distinguishable in the trajectory.
"""
from __future__ import annotations

import json
import platform
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, nsg_index, time_batched
from repro.config import SearchConfig
from repro.core import (recall_at_k, search_speedann_batch,
                        search_topm_batch)
from repro.kernels import available_backends
from repro.kernels import ops as kops

K = 10
BASE = SearchConfig(k=K, queue_len=64, m_max=6, num_walkers=4,
                    max_steps=256, local_steps=4, sync_ratio=0.8)


def sweep(out_path: str = "BENCH_dist_backend.json", n: int = 2000,
          q: int = 16) -> Dict:
    """One row per (searcher, backend); writes the JSON trajectory file."""
    ds = dataset(n=n, q=q)
    g = nsg_index(ds, degree=16)
    queries = jnp.asarray(ds.queries)

    rows = []
    ref_ids: Dict[str, np.ndarray] = {}
    # ref first: it is the parity baseline for the other rows
    backends = ("ref",) + tuple(
        b for b in available_backends() if b != "ref")
    for searcher, run in (("topm", search_topm_batch),
                          ("speedann", search_speedann_batch)):
        for backend in backends:
            cfg = BASE.with_(dist_backend=backend)
            fn = jax.jit(lambda qq, run=run, cfg=cfg: run(g, qq, cfg))
            ids, _, stats = fn(queries)
            us = time_batched(fn, queries)
            ids = np.asarray(ids)
            if backend == "ref":
                ref_ids[searcher] = ids
            row = {
                "searcher": searcher,
                "backend": backend,
                "interpret": bool(kops.INTERPRET),
                "us_per_query": us / q,
                "recall_at_k": recall_at_k(ids, ds.gt_ids, K),
                "dist_comps": float(np.mean(np.asarray(stats.dist_comps))),
                "ids_match_ref": bool(
                    np.array_equal(ids, ref_ids[searcher])),
            }
            rows.append(row)
            print(f"bench_backend_{searcher}_{backend},"
                  f"{row['us_per_query']:.1f},"
                  f"recall={row['recall_at_k']:.3f};"
                  f"ids_match_ref={row['ids_match_ref']}")

    payload = {
        "bench": "dist_backend",
        "config": {"n": n, "q": q, "k": K, "m_max": BASE.m_max,
                   "queue_len": BASE.queue_len, "dma_group": BASE.dma_group},
        "platform": platform.machine(),
        "jax": jax.__version__,
        "unix_time": time.time(),
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path} ({len(rows)} rows)")
    return payload


if __name__ == "__main__":
    sweep()
