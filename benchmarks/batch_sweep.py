"""Batch-amortization sweep: per-query cost of the batch-major engine vs B.

``PYTHONPATH=src python -m benchmarks.run --sweep-batch``

The batch-major traversal engine advances a whole (B, d) query batch per
global step with ONE distance launch, so per-step fixed costs (kernel
launch, queue-op dispatch, interpret-mode emulation overhead) amortize over
B.  This sweep runs the same top-M searcher at B ∈ {1, 8, 64, 256} for each
requested backend and appends one row per (backend, B) to
``BENCH_dist_backend.json`` — the same trajectory file as
``--sweep-backends``, with rows keyed (searcher, backend, BATCH, host,
interpret) so batch rows and plain backend rows coexist.

Two per-query metrics per row:

* ``us_per_query``     — wall / B.  The serving-relevant number, but it
  conflates amortization with straggler cost (a batch runs until its
  SLOWEST query converges; converged lanes are masked no-ops).
* ``us_per_lane_step`` — wall / (B × executed steps), where executed steps
  = the batch's max step count.  This isolates the per-step, per-lane cost
  the batch dimension amortizes; it is the number that must DECREASE with
  B for the batch-major refactor to be paying off on a backend.

Each row also carries the cross-query frontier-overlap counters
(``SearchStats.uniq_comps`` / ``batch_dup_comps``, first-toucher
attribution): ``uniq_comps`` is how many rows a batch-deduplicating gather
actually fetches, ``dist_comps`` how many a per-lane gather fetches, and
``batch_dup_ratio`` = dup/dist the share of gathers dedup elides — the
ratio GROWS with B as frontiers overlap more, which is the dedup_gather
backend's scaling argument in one number.

On this CPU container the Pallas backends run in interpret mode, so their
absolute numbers measure the emulation; the ``ref`` backend is the
apples-to-apples amortization signal until a TPU session re-runs the sweep
compiled.
"""
from __future__ import annotations

import platform
import time
from typing import Dict, Sequence

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (dataset, nsg_index, time_batched,
                               write_trajectory)
from benchmarks.dist_backend import _row_key
from repro.ann import SearchParams
from repro.core import recall_at_k
from repro.kernels import ops as kops

K = 10
BATCHES = (1, 8, 64, 256)
BACKENDS = ("ref", "rowgather", "dedup_gather")
PARAMS = SearchParams(k=K, queue_len=32, m_max=4, max_steps=96,
                      algorithm="topm")


def sweep(out_path: str = "BENCH_dist_backend.json",
          backends: Sequence[str] = BACKENDS,
          batches: Sequence[int] = BATCHES, n: int = 2000) -> Dict:
    """One row per (backend, batch); appends to the JSON trajectory."""
    q_max = max(batches)
    ds = dataset(n=n, q=q_max)
    index = nsg_index(ds, degree=16)
    host = platform.node() or platform.machine()

    rows = []
    for backend in backends:
        fn = index.searcher(PARAMS.with_(backend=backend))
        run_batches = batches
        if backend.startswith("dedup") and kops.INTERPRET:
            # the dedup kernel trades gathers for a (uniques x B) reduce
            # grid — free on the MXU, but interpret-mode emulation walks it
            # cell by cell, so wall clock scales ~B^2; cap the sweep where
            # emulation stays tractable (a TPU session lifts this)
            run_batches = tuple(b for b in batches if b <= 64)
            dropped = tuple(b for b in batches if b > 64)
            if dropped:
                print(f"bench_batch_{backend}: skipping B={dropped} "
                      "(interpret-mode emulation; run compiled for full "
                      "range)")
        for bsz in run_batches:
            queries = jnp.asarray(ds.queries[:bsz])
            ids, _, stats = fn(queries)
            us = time_batched(fn, queries)
            steps = np.asarray(stats.steps)
            # the batch executes max(steps) loop iterations; converged
            # lanes ride along masked, so B×max(steps) is the lane-step
            # count the one-launch-per-step engine actually paid for
            lane_steps = bsz * max(int(steps.max()), 1)
            dist_comps = int(np.sum(np.asarray(stats.dist_comps)))
            uniq_comps = int(np.sum(np.asarray(stats.uniq_comps)))
            dup_comps = int(np.sum(np.asarray(stats.batch_dup_comps)))
            row = {
                "searcher": "topm",
                "backend": backend,
                "batch": bsz,
                "host": host,
                "interpret": bool(kops.INTERPRET),
                "n": n,
                "q": bsz,
                "unix_time": time.time(),
                "us_per_query": us / bsz,
                "us_per_lane_step": us / lane_steps,
                "steps_mean": float(steps.mean()),
                "steps_max": int(steps.max()),
                # cross-query overlap: unique-gather count <= candidate
                # count, with the dedup ratio improving as B grows
                "dist_comps": dist_comps,
                "uniq_comps": uniq_comps,
                "batch_dup_comps": dup_comps,
                "batch_dup_ratio": (dup_comps / dist_comps
                                    if dist_comps else 0.0),
                "recall_at_k": recall_at_k(
                    np.asarray(ids), ds.gt_ids[:bsz], K),
            }
            rows.append(row)
            print(f"bench_batch_{backend}_B{bsz},"
                  f"{row['us_per_query']:.1f},"
                  f"us_per_lane_step={row['us_per_lane_step']:.2f};"
                  f"dup_ratio={row['batch_dup_ratio']:.3f};"
                  f"recall={row['recall_at_k']:.3f}")

    return write_trajectory(out_path, "dist_backend", rows, _row_key)


if __name__ == "__main__":
    sweep()
