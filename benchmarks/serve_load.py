"""Latency-under-load sweep: client-observed percentiles vs offered QPS.

``PYTHONPATH=src python -m benchmarks.run --sweep-serve`` (full ladder) or
``PYTHONPATH=src python -m benchmarks.serve_load --qps 200 --cache
--priority-mix 0.5 --duration 2`` (one point, serving-tier knobs on —
the CI smoke invocation).

An open-loop load generator offers single-query requests at Poisson arrival
times (exponential inter-arrivals at each target QPS) to the async
coalescing front-end (``AnnIndex.serve_async``), which batches them under
the max-batch / max-wait policy and dispatches through the bucketed jit
cache.  Each request's latency is CLIENT-OBSERVED — submit to future
resolution, so queueing + coalescing wait + batch execution — which is the
number a caller of a serving system actually sees, and the one where
coalescing trades a little p50 for a lot of throughput.

The serving tier adds three sweep axes, all part of the row key:

* ``--cache`` — quantized-code result cache in front of the queue; the
  query pool is finite, so repeats hit and the row records the hit count;
* ``--priority-mix F`` + ``--admission TW,CW`` — an F fraction of requests
  in the critical class, the rest throughput-class; admission sheds
  throughput first at the watermarks, and the row carries PER-CLASS p50/p99
  (the overload claim — critical p99 lower WITH admission than without —
  is read off two rows differing only in ``admission``);
* ``--replicas N`` — a :class:`~repro.serve.ReplicaRouter` spreading
  dispatch over N data-parallel engine replicas.

``BENCH_serve.json`` is a TRAJECTORY with the same append semantics as
``BENCH_dist_backend.json``: each sweep APPENDS rows, replacing only rows
with the same (mode, backend, host, interpret, qps_offered, cache,
priority_mix, replicas, admission) key, so interpret-mode CPU numbers,
serving-tier variants, and future compiled Mosaic/TPU numbers accumulate
side by side.  Row schema is documented in docs/benchmarks.md.

On this CPU container absolute latencies measure single-core interpret-mode
execution — the shape of the latency-vs-load curve (flat until saturation,
then queueing blow-up) is the meaningful output, not the milliseconds.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from concurrent.futures import wait as futures_wait
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from benchmarks.common import dataset, merge_trajectory_rows, nsg_index
from repro.ann import SearchParams
from repro.kernels import ops as kops
from repro.serve import (AdmissionPolicy, AdmissionRejected, CachePolicy,
                         ReplicaRouter, RouterPolicy)
from repro.serve.coalescer import DeadlineExceeded

K = 10
PARAMS = SearchParams(k=K, queue_len=64, m_max=6, num_walkers=4,
                      max_steps=256, local_steps=4, sync_ratio=0.8)
BUCKETS = (1, 2, 4, 8, 16, 32)
QPS_LADDER = (25, 50, 100, 200)


def _row_key(row: Dict) -> tuple:
    """Identity of a trajectory row: same key ⇒ newer run supersedes.
    Serving-tier axes default to their pre-tier values so rows written
    before those axes existed merge as (no cache, all-critical, 1 replica,
    no admission)."""
    return (row.get("mode"), row.get("backend"),
            row.get("host", "<unknown>"), row.get("interpret"),
            row.get("qps_offered"), row.get("cache", False),
            row.get("priority_mix", 1.0), row.get("replicas", 1),
            row.get("admission", False))


def offered_load(srv, queries: np.ndarray, qps: float, duration_s: float,
                 seed: int = 0, deadline_ms: Optional[float] = None,
                 priority_mix: float = 1.0) -> Dict:
    """Open-loop Poisson arrivals at ``qps`` for ``duration_s`` seconds.

    Open loop means arrivals do NOT wait for completions — exactly the
    regime where queueing delay compounds and coalescing pays.  A
    ``priority_mix`` fraction of requests (rng-assigned, reproducible from
    ``seed``) is submitted in the critical class, the rest throughput-class.
    Returns client-observed latency percentiles — overall and per class —
    and throughput actually achieved.  Completion times come from
    ``AsyncServeResult.done_t``, stamped by the dispatcher at resolution —
    done-callbacks run AFTER waiters wake, so clocking them here would race.
    """
    rng = np.random.RandomState(seed)
    arrivals, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / qps)
        if t >= duration_s:
            break
        arrivals.append(t)
    if not arrivals:
        arrivals = [0.0]
    classes = ["critical" if rng.random_sample() < priority_mix
               else "throughput" for _ in arrivals]

    futs = []
    t0 = time.perf_counter()
    for i, at in enumerate(arrivals):
        sleep = t0 + at - time.perf_counter()
        if sleep > 0:
            time.sleep(sleep)
        fut = srv.submit(queries[i % queries.shape[0]],
                         deadline_ms=deadline_ms, priority=classes[i])
        futs.append((time.perf_counter(), fut))
    futures_wait([f for _, f in futs])
    wall_s = time.perf_counter() - t0

    lats, by_class = [], {"critical": [], "throughput": []}
    rejected = shed = cache_hits = 0
    for (submit_t, fut), cls in zip(futs, classes):
        err = fut.exception()
        if err is not None:
            rejected += isinstance(err, DeadlineExceeded)
            shed += isinstance(err, AdmissionRejected)
            continue
        res = fut.result()
        cache_hits += res.batch_size == 0.0      # replayed, never queued
        # a cache hit resolves INSIDE submit(), before the client stamps
        # submit_t — clamp the ~µs negative difference to zero
        ms = max(0.0, (res.done_t - submit_t) * 1e3)
        lats.append(ms)
        by_class[cls].append(ms)
    lat = np.asarray(lats, np.float64)
    out = {
        "qps_offered": float(qps),
        "qps_achieved": float(len(lats) / wall_s),
        "requests": len(arrivals),
        "served": len(lats),
        "served_cache": int(cache_hits),
        "rejected_deadline": int(rejected),
        "rejected_admission": int(shed),
        "duration_s": float(wall_s),
    }
    if lat.size:
        out.update(
            latency_mean_ms=float(lat.mean()),
            latency_p50_ms=float(np.percentile(lat, 50)),
            latency_p95_ms=float(np.percentile(lat, 95)),
            latency_p99_ms=float(np.percentile(lat, 99)),
            latency_max_ms=float(lat.max()),
        )
    for cls, ms in by_class.items():
        if ms and 0.0 < priority_mix < 1.0:      # mixed traffic only
            arr = np.asarray(ms, np.float64)
            out[f"{cls}_served"] = len(ms)
            out[f"{cls}_p50_ms"] = float(np.percentile(arr, 50))
            out[f"{cls}_p99_ms"] = float(np.percentile(arr, 99))
    return out


def sweep(out_path: str = "BENCH_serve.json", n: int = 2000, q: int = 32,
          qps_ladder: Sequence[float] = QPS_LADDER,
          duration_s: float = 1.5, backend: str = "ref",
          max_wait_ms: float = 2.0,
          trace_out: Optional[str] = None,
          cache: Optional[CachePolicy] = None,
          admission: Optional[AdmissionPolicy] = None,
          priority_mix: float = 1.0, replicas: int = 1,
          registry_out: Optional[str] = None) -> Dict:
    """One row per offered-QPS point; appends to the JSON trajectory.

    With ``trace_out`` the HIGHEST-QPS sweep point runs with request-scoped
    tracing on and dumps its Chrome-trace/Perfetto JSON there — the point
    where coalescing actually forms multi-request batches, so the trace
    shows nested batch_formation → dispatch → device_compute spans.
    Tracing stays off for every other point (and entirely without
    ``trace_out``), so the sweep's latency numbers are untraced.

    With ``registry_out`` every point records metrics into ONE shared
    registry, dumped as JSON at the end — cache hit/miss, admission
    decisions, coalescer outcomes — the counters the CI serve-tier smoke
    gates on.
    """
    from repro.obs import MetricsRegistry, Observability

    ds = dataset(n=n, q=q)
    index = nsg_index(ds, degree=16)
    params = PARAMS.with_(backend=backend)
    host = platform.node() or platform.machine()
    queries = np.asarray(ds.queries, np.float32)
    traced_qps = max(qps_ladder) if trace_out else None
    shared_registry = MetricsRegistry() if registry_out else None

    rows = []
    for qps in qps_ladder:
        tracing = qps == traced_qps
        if tracing or shared_registry is not None:
            obs = Observability(tracing=tracing,
                                metrics=shared_registry is not None,
                                registry=shared_registry)
        else:
            obs = None
        if replicas > 1:
            engines = [index.serve(params, bucket_sizes=BUCKETS, obs=obs)
                       for _ in range(replicas)]
            for eng in engines:
                eng.warmup(queries.shape[1])
            router = ReplicaRouter(engines, policy=RouterPolicy(), obs=obs)
            srv_engine = router
        else:
            router = None
            srv_engine = index.serve(params, bucket_sizes=BUCKETS, obs=obs)
            srv_engine.warmup(queries.shape[1])  # compiles outside the clock
        from repro.serve import AsyncAnnEngine, CoalescePolicy
        srv = AsyncAnnEngine(
            srv_engine,
            CoalescePolicy(max_batch=BUCKETS[-1], max_wait_ms=max_wait_ms),
            obs=obs, cache=cache, admission=admission)
        try:
            load = offered_load(srv, queries, qps, duration_s,
                                priority_mix=priority_mix)
        finally:
            srv.close()
            if router is not None:
                router.close()
        if obs is not None and tracing:
            obs.write_trace(trace_out)
            print(f"# wrote {trace_out} "
                  f"({obs.tracer.n_events} trace events at qps={qps:g})")
        cstats = srv.stats()
        estats = srv.engine.stats()
        row = {
            "mode": "async_coalesced",
            "backend": backend,
            "quant": "none",
            "algorithm": params.algorithm,
            "host": host,
            "interpret": bool(kops.INTERPRET),
            "n": n,
            "k": K,
            "max_batch": srv.policy.max_batch,
            "max_wait_ms": max_wait_ms,
            # serving-tier axes (all in the row key)
            "cache": cache is not None,
            "priority_mix": float(priority_mix),
            "replicas": int(replicas),
            "admission": admission is not None,
            "batch_size_mean": cstats.get("batch_size_mean", 1.0),
            # the tail DECOMPOSED: time queued before dispatch vs. engine
            # wall clock per dispatched batch — the split that says whether
            # a fat p99 is a queueing problem or a compute problem
            "queue_wait_p99_ms": cstats.get("queue_wait_p99_ms", 0.0),
            "compute_p99_ms": estats.get(
                "latency_p99_ms", estats.get("replica0_p99_ms", 0.0)),
            "unix_time": time.time(),
            **load,
        }
        rows.append(row)
        print(f"bench_serve_qps{qps:g},"
              f"{row.get('latency_p50_ms', float('nan')):.1f},"
              f"p95={row.get('latency_p95_ms', float('nan')):.1f};"
              f"p99={row.get('latency_p99_ms', float('nan')):.1f};"
              f"qwait_p99={row['queue_wait_p99_ms']:.1f};"
              f"compute_p99={row['compute_p99_ms']:.1f};"
              f"achieved={row['qps_achieved']:.0f}qps;"
              f"batch_mean={row['batch_size_mean']:.1f};"
              f"cache_hits={row['served_cache']};"
              f"shed={row['rejected_admission']}")

    if registry_out and shared_registry is not None:
        with open(registry_out, "w") as f:
            f.write(shared_registry.to_json(indent=2))
        print(f"# wrote {registry_out}")

    all_rows = merge_trajectory_rows(out_path, rows, _row_key)
    payload = {
        "bench": "serve",
        "config": {"n": n, "q": q, "k": K, "buckets": list(BUCKETS),
                   "duration_s": duration_s, "max_wait_ms": max_wait_ms,
                   "queue_len": PARAMS.queue_len, "m_max": PARAMS.m_max},
        "platform": platform.machine(),
        "jax": jax.__version__,
        "unix_time": time.time(),
        "rows": all_rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path} ({len(rows)} new rows, "
          f"{len(all_rows)} total in trajectory)")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="latency-under-load sweep with serving-tier knobs")
    ap.add_argument("--qps", type=float, action="append", default=None,
                    help="offered QPS point; repeatable (default: the "
                         f"ladder {QPS_LADDER})")
    ap.add_argument("--duration", type=float, default=1.5,
                    help="seconds of offered load per point")
    ap.add_argument("--n", type=int, default=2000, help="corpus size")
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--cache", action="store_true",
                    help="enable the quantized-code result cache")
    ap.add_argument("--cache-capacity", type=int, default=4096)
    ap.add_argument("--priority-mix", type=float, default=1.0,
                    help="fraction of requests in the critical class "
                         "(rest throughput-class)")
    ap.add_argument("--admission", default=None, metavar="TW,CW",
                    help="admission watermarks: throughput,critical "
                         "queue depths (e.g. 4,16); absent = no admission "
                         "control")
    ap.add_argument("--replicas", type=int, default=1,
                    help="route over N data-parallel engine replicas")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome-trace JSON of the highest-QPS point")
    ap.add_argument("--registry-out", default=None,
                    help="dump the shared metrics registry JSON here "
                         "(cache/admission/coalescer counters)")
    args = ap.parse_args(argv)
    cache = (CachePolicy(capacity=args.cache_capacity)
             if args.cache else None)
    admission = None
    if args.admission:
        tw, cw = (int(x) for x in args.admission.split(","))
        admission = AdmissionPolicy(throughput_watermark=tw,
                                    critical_watermark=cw)
    sweep(out_path=args.out, n=args.n,
          qps_ladder=tuple(args.qps) if args.qps else QPS_LADDER,
          duration_s=args.duration, backend=args.backend,
          max_wait_ms=args.max_wait_ms, trace_out=args.trace_out,
          cache=cache, admission=admission,
          priority_mix=args.priority_mix, replicas=args.replicas,
          registry_out=args.registry_out)


if __name__ == "__main__":
    main()
