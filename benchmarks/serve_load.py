"""Latency-under-load sweep: client-observed percentiles vs offered QPS.

``PYTHONPATH=src python -m benchmarks.run --sweep-serve``

An open-loop load generator offers single-query requests at Poisson arrival
times (exponential inter-arrivals at each target QPS) to the async
coalescing front-end (``AnnIndex.serve_async``), which batches them under
the max-batch / max-wait policy and dispatches through the bucketed jit
cache.  Each request's latency is CLIENT-OBSERVED — submit to future
resolution, so queueing + coalescing wait + batch execution — which is the
number a caller of a serving system actually sees, and the one where
coalescing trades a little p50 for a lot of throughput.

``BENCH_serve.json`` is a TRAJECTORY with the same append semantics as
``BENCH_dist_backend.json``: each sweep APPENDS rows, replacing only rows
with the same (mode, backend, host, interpret, qps_offered) key, so
interpret-mode CPU numbers and future compiled Mosaic/TPU numbers
accumulate side by side.  Row schema is documented in docs/benchmarks.md.

On this CPU container absolute latencies measure single-core interpret-mode
execution — the shape of the latency-vs-load curve (flat until saturation,
then queueing blow-up) is the meaningful output, not the milliseconds.
"""
from __future__ import annotations

import json
import platform
import time
from concurrent.futures import wait as futures_wait
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from benchmarks.common import dataset, merge_trajectory_rows, nsg_index
from repro.ann import SearchParams
from repro.kernels import ops as kops
from repro.serve.coalescer import DeadlineExceeded

K = 10
PARAMS = SearchParams(k=K, queue_len=64, m_max=6, num_walkers=4,
                      max_steps=256, local_steps=4, sync_ratio=0.8)
BUCKETS = (1, 2, 4, 8, 16, 32)
QPS_LADDER = (25, 50, 100, 200)


def _row_key(row: Dict) -> tuple:
    """Identity of a trajectory row: same key ⇒ newer run supersedes."""
    return (row.get("mode"), row.get("backend"),
            row.get("host", "<unknown>"), row.get("interpret"),
            row.get("qps_offered"))


def offered_load(srv, queries: np.ndarray, qps: float, duration_s: float,
                 seed: int = 0, deadline_ms: Optional[float] = None) -> Dict:
    """Open-loop Poisson arrivals at ``qps`` for ``duration_s`` seconds.

    Open loop means arrivals do NOT wait for completions — exactly the
    regime where queueing delay compounds and coalescing pays.  Returns
    client-observed latency percentiles and throughput actually achieved.
    Completion times come from ``AsyncServeResult.done_t``, stamped by the
    dispatcher at resolution — done-callbacks run AFTER waiters wake, so
    clocking them here would race.
    """
    rng = np.random.RandomState(seed)
    arrivals, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / qps)
        if t >= duration_s:
            break
        arrivals.append(t)
    if not arrivals:
        arrivals = [0.0]

    futs = []
    t0 = time.perf_counter()
    for i, at in enumerate(arrivals):
        sleep = t0 + at - time.perf_counter()
        if sleep > 0:
            time.sleep(sleep)
        fut = srv.submit(queries[i % queries.shape[0]],
                         deadline_ms=deadline_ms)
        futs.append((time.perf_counter(), fut))
    futures_wait([f for _, f in futs])
    wall_s = time.perf_counter() - t0

    lats, rejected = [], 0
    for submit_t, fut in futs:
        if fut.exception() is not None:
            rejected += isinstance(fut.exception(), DeadlineExceeded)
            continue
        lats.append((fut.result().done_t - submit_t) * 1e3)
    lat = np.asarray(lats, np.float64)
    out = {
        "qps_offered": float(qps),
        "qps_achieved": float(len(lats) / wall_s),
        "requests": len(arrivals),
        "served": len(lats),
        "rejected_deadline": int(rejected),
        "duration_s": float(wall_s),
    }
    if lat.size:
        out.update(
            latency_mean_ms=float(lat.mean()),
            latency_p50_ms=float(np.percentile(lat, 50)),
            latency_p95_ms=float(np.percentile(lat, 95)),
            latency_p99_ms=float(np.percentile(lat, 99)),
            latency_max_ms=float(lat.max()),
        )
    return out


def sweep(out_path: str = "BENCH_serve.json", n: int = 2000, q: int = 32,
          qps_ladder: Sequence[float] = QPS_LADDER,
          duration_s: float = 1.5, backend: str = "ref",
          max_wait_ms: float = 2.0,
          trace_out: Optional[str] = None) -> Dict:
    """One row per offered-QPS point; appends to the JSON trajectory.

    With ``trace_out`` the HIGHEST-QPS sweep point runs with request-scoped
    tracing on and dumps its Chrome-trace/Perfetto JSON there — the point
    where coalescing actually forms multi-request batches, so the trace
    shows nested batch_formation → dispatch → device_compute spans.
    Tracing stays off for every other point (and entirely without
    ``trace_out``), so the sweep's latency numbers are untraced.
    """
    from repro.obs import Observability

    ds = dataset(n=n, q=q)
    index = nsg_index(ds, degree=16)
    params = PARAMS.with_(backend=backend)
    host = platform.node() or platform.machine()
    queries = np.asarray(ds.queries, np.float32)
    traced_qps = max(qps_ladder) if trace_out else None

    rows = []
    for qps in qps_ladder:
        obs = (Observability(tracing=True, metrics=False)
               if qps == traced_qps else None)
        srv = index.serve_async(params, max_wait_ms=max_wait_ms,
                                bucket_sizes=BUCKETS, obs=obs)
        srv.engine.warmup(queries.shape[1])      # compiles outside the clock
        try:
            load = offered_load(srv, queries, qps, duration_s)
        finally:
            srv.close()
        if obs is not None:
            obs.write_trace(trace_out)
            print(f"# wrote {trace_out} "
                  f"({obs.tracer.n_events} trace events at qps={qps:g})")
        cstats = srv.stats()
        estats = srv.engine.stats()
        row = {
            "mode": "async_coalesced",
            "backend": backend,
            "quant": "none",
            "algorithm": params.algorithm,
            "host": host,
            "interpret": bool(kops.INTERPRET),
            "n": n,
            "k": K,
            "max_batch": srv.policy.max_batch,
            "max_wait_ms": max_wait_ms,
            "batch_size_mean": cstats.get("batch_size_mean", 1.0),
            # the tail DECOMPOSED: time queued before dispatch vs. engine
            # wall clock per dispatched batch — the split that says whether
            # a fat p99 is a queueing problem or a compute problem
            "queue_wait_p99_ms": cstats.get("queue_wait_p99_ms", 0.0),
            "compute_p99_ms": estats.get("latency_p99_ms", 0.0),
            "unix_time": time.time(),
            **load,
        }
        rows.append(row)
        print(f"bench_serve_qps{qps:g},"
              f"{row.get('latency_p50_ms', float('nan')):.1f},"
              f"p95={row.get('latency_p95_ms', float('nan')):.1f};"
              f"p99={row.get('latency_p99_ms', float('nan')):.1f};"
              f"qwait_p99={row['queue_wait_p99_ms']:.1f};"
              f"compute_p99={row['compute_p99_ms']:.1f};"
              f"achieved={row['qps_achieved']:.0f}qps;"
              f"batch_mean={row['batch_size_mean']:.1f}")

    all_rows = merge_trajectory_rows(out_path, rows, _row_key)
    payload = {
        "bench": "serve",
        "config": {"n": n, "q": q, "k": K, "buckets": list(BUCKETS),
                   "duration_s": duration_s, "max_wait_ms": max_wait_ms,
                   "queue_len": PARAMS.queue_len, "m_max": PARAMS.m_max},
        "platform": platform.machine(),
        "jax": jax.__version__,
        "unix_time": time.time(),
        "rows": all_rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path} ({len(rows)} new rows, "
          f"{len(all_rows)} total in trajectory)")
    return payload


if __name__ == "__main__":
    sweep()
