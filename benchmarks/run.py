"""Benchmark harness entry point.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``

Prints ``name,us_per_call,derived`` CSV, one block per paper table/figure
(see benchmarks/paper_figs.py) plus the roofline table from the dry-run
artifacts (benchmarks/roofline_report.py).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--sweep-backends", action="store_true",
                    help="sweep SearchConfig.dist_backend and write "
                         "BENCH_dist_backend.json (skips the figure suite)")
    ap.add_argument("--bench-out", default="BENCH_dist_backend.json",
                    help="output path for --sweep-backends")
    ap.add_argument("--sweep-serve", action="store_true",
                    help="latency-under-load sweep through the async "
                         "coalescing engine; writes BENCH_serve.json "
                         "(skips the figure suite)")
    ap.add_argument("--serve-out", default="BENCH_serve.json",
                    help="output path for --sweep-serve")
    ap.add_argument("--trace-out", default=None,
                    help="with --sweep-serve: dump Chrome-trace/Perfetto "
                         "JSON of the highest-QPS sweep point here "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--sweep-batch", action="store_true",
                    help="batch-amortization sweep of the batch-major "
                         "engine (B x backend); appends rows to "
                         "BENCH_dist_backend.json (skips the figure suite)")
    ap.add_argument("--sweep-build", action="store_true",
                    help="construction-throughput sweep of the batched "
                         "builder (build_batch x backend vs the serial "
                         "reference); appends rows to BENCH_build.json "
                         "(skips the figure suite)")
    ap.add_argument("--build-out", default="BENCH_build.json",
                    help="output path for --sweep-build")
    args = ap.parse_args()

    if args.sweep_backends:
        from benchmarks import dist_backend
        dist_backend.sweep(args.bench_out)
        return

    if args.sweep_batch:
        from benchmarks import batch_sweep
        batch_sweep.sweep(args.bench_out)
        return

    if args.sweep_build:
        from benchmarks import build_sweep
        build_sweep.sweep(args.build_out)
        return

    if args.sweep_serve:
        from benchmarks import serve_load
        serve_load.sweep(args.serve_out, trace_out=args.trace_out)
        return

    from benchmarks import paper_figs
    from benchmarks import roofline_report

    print("name,us_per_call,derived")
    failures = []
    for fn in paper_figs.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {fn.__name__} done in {time.time() - t0:.0f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append(fn.__name__)
            print(f"{fn.__name__},nan,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if not args.skip_roofline and not args.only:
        roofline_report.render()
        roofline_report.kernel_rooflines()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
