"""One benchmark per paper table/figure (Speed-ANN, CS.DC 2022).

Each function prints ``name,us_per_call,derived`` CSV rows and returns a
dict for the harness.  Naming follows the paper:
  fig05  convergence steps BFiS vs Speed-ANN
  fig06  distance computations BFiS vs Speed-ANN (M=walkers)
  fig07  comps & steps vs expansion width M
  fig08  staged vs non-staged over-expansion
  fig09  sync frequency vs comps (sync_ratio sweep)
  fig12  latency at recall targets: Speed-ANN vs NSG(BFiS) vs HNSW
  fig13  tail latency (per-query percentiles)
  fig14  thread (walker) scaling
  fig15  graph-size scaling
  fig16  §5.3 ablation (NSG-T / NoStaged / NoSync / Adaptive)
  fig17  neighbor grouping (degree/frequency-centric)
  tab02  no-sync vs adaptive sync comps+latency
  tab04  GPU comparison — N/A on this container (documented)
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (K, dataset, hnsw_index, latency_at_recall,
                               modeled_parallel_us, nsg_index, run_method)
from repro.config import SearchConfig
from repro.core import recall_at_k, search_speedann_batch, variant
from repro.core.graph import group_by_indegree

BASE = SearchConfig(k=K, queue_len=64, m_max=8, num_walkers=8,
                    max_steps=512, local_steps=8, sync_ratio=0.8)
TARGETS = (0.9, 0.99, 1.0)   # paper: 0.9 / 0.99 / 0.999 (K=10 here)


def row(name, us, derived):
    print(f"{name},{us if us == us else 'nan'},{derived}")


def fig05_convergence() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    _, _, s_b = run_method("bfis", g, q, BASE)
    _, _, s_s = run_method("speedann", g, q, BASE)
    b, s = float(np.mean(np.asarray(s_b.steps))), float(
        np.mean(np.asarray(s_s.steps)))
    row("fig05_convergence_steps", 0,
        f"bfis_steps={b:.1f};speedann_steps={s:.1f};reduction={b / s:.1f}x")
    return {"bfis": b, "speedann": s}


def fig06_distance_comps() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    _, _, s_b = run_method("bfis", g, q, BASE)
    _, _, s_m = run_method("topm", g, q, BASE.with_(staged=False))
    b = float(np.mean(np.asarray(s_b.dist_comps)))
    m = float(np.mean(np.asarray(s_m.dist_comps)))
    row("fig06_dist_comps", 0,
        f"bfis={b:.0f};topm_nostage={m:.0f};overhead={m / b:.2f}x")
    return {"bfis": b, "topm": m}


def fig07_width_sweep() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    out = {}
    for m in (1, 2, 4, 8, 16):
        _, _, st = run_method(
            "topm", g, q, BASE.with_(m_max=m, staged=False))
        steps = float(np.mean(np.asarray(st.steps)))
        comps = float(np.mean(np.asarray(st.dist_comps)))
        out[m] = (steps, comps)
        row(f"fig07_M{m}", 0, f"steps={steps:.1f};comps={comps:.0f}")
    return out


def fig08_staged() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    cfg = BASE.with_(m_max=16)
    _, _, s_f = run_method("topm", g, q, cfg.with_(staged=False))
    _, _, s_s = run_method("topm", g, q, cfg.with_(staged=True))
    cf = float(np.mean(np.asarray(s_f.dist_comps)))
    cs = float(np.mean(np.asarray(s_s.dist_comps)))
    tf = float(np.mean(np.asarray(s_f.steps)))
    ts = float(np.mean(np.asarray(s_s.steps)))
    row("fig08_staged", 0,
        f"comps_fixed={cf:.0f};comps_staged={cs:.0f};"
        f"steps_fixed={tf:.1f};steps_staged={ts:.1f}")
    return {"fixed": (tf, cf), "staged": (ts, cs)}


def fig09_sync_frequency() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    out = {}
    for ratio, ls in ((0.5, 2), (0.7, 4), (0.8, 8), (0.9, 16), (2.0, 512)):
        cfg = BASE.with_(sync_ratio=ratio, local_steps=ls)
        ids, _, st = run_method("speedann", g, q, cfg)
        r = recall_at_k(np.asarray(ids), ds.gt_ids, K)
        out[ratio] = dict(st.summary(), recall=r)
        row(f"fig09_ratio{ratio}", 0,
            f"syncs={out[ratio]['syncs']:.1f};"
            f"comps={out[ratio]['dist_comps']:.0f};recall={r:.3f}")
    return out


def fig12_latency_vs_baselines() -> Dict:
    """Latency at equal recall.  On this 1-core container the wall clock is
    total WORK; the paper's latency gain is critical-path parallelism, so we
    report both the measured work-time and the W-core modeled latency (see
    common.modeled_parallel_us)."""
    ds = dataset()
    g = nsg_index(ds)
    h = hnsw_index(ds)
    out = {}
    for tgt in TARGETS:
        res = {}
        for method, idx in (("bfis", g), ("hnsw", h), ("speedann", g)):
            us, r, stats = latency_at_recall(method, idx, ds, BASE, tgt)
            mus = modeled_parallel_us(us, stats) if stats else us
            res[method] = (us, mus)
            row(f"fig12_{method}_r{tgt}", round(us, 1),
                f"recall>={tgt};modeled_parallel_us={mus:.1f}")
        sp_work = res["bfis"][0] / res["speedann"][0]
        sp_lat = res["bfis"][1] / res["speedann"][1]
        sp_h = res["hnsw"][1] / res["speedann"][1]
        row(f"fig12_speedup_r{tgt}", 0,
            f"latency_vs_nsg={sp_lat:.2f}x;latency_vs_hnsw={sp_h:.2f}x;"
            f"work_vs_nsg={sp_work:.2f}x")
        out[tgt] = res
    return out


def fig13_tail_latency() -> Dict:
    """Work-proxy percentiles: per-query steps (latency ∝ critical path)."""
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    out = {}
    for method in ("bfis", "speedann"):
        _, _, st = run_method(method, g, q, BASE.with_(queue_len=96))
        steps = np.asarray(st.steps)
        p50, p90, p99 = (np.percentile(steps, p) for p in (50, 90, 99))
        out[method] = (p50, p90, p99)
        row(f"fig13_{method}", 0,
            f"p50={p50:.0f};p90={p90:.0f};p99={p99:.0f};"
            f"tail_blowup={p99 / max(p50, 1):.2f}x")
    return out


def fig14_walker_scaling() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    base_steps = None
    out = {}
    for w in (1, 2, 4, 8, 16, 32):
        cfg = BASE.with_(num_walkers=w, m_max=w)
        _, _, st = run_method("speedann", g, q, cfg)
        steps = float(np.mean(np.asarray(st.steps)))
        comps = float(np.mean(np.asarray(st.dist_comps)))
        base_steps = base_steps or steps
        out[w] = (steps, comps)
        row(f"fig14_w{w}", 0,
            f"global_steps={steps:.1f};comps={comps:.0f};"
            f"crit_path_speedup={base_steps / steps:.2f}x")
    return out


def fig15_graph_size_scaling() -> Dict:
    out = {}
    for n in (2000, 8000, 20000):
        ds = dataset(n=n, q=32)
        g = nsg_index(ds)
        us_b, _, _ = latency_at_recall("bfis", g, ds, BASE, 0.99)
        us_s, _, _ = latency_at_recall("speedann", g, ds, BASE, 0.99)
        out[n] = (us_b, us_s)
        row(f"fig15_n{n}", round(us_s, 1),
            f"bfis_us={us_b:.1f};speedup={us_b / us_s:.2f}x")
    return out


def fig16_ablation() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    q = jnp.asarray(ds.queries)
    out = {}
    for name in ("bfis", "nostaged", "nosync", "adaptive"):
        cfg = variant(BASE, name)
        method = "bfis" if name == "bfis" else "speedann"
        ids, _, st = run_method(method, g, q, cfg)
        r = recall_at_k(np.asarray(ids), ds.gt_ids, K)
        s = st.summary()
        out[name] = dict(s, recall=r)
        row(f"fig16_{name}", 0,
            f"steps={s['steps']:.1f};comps={s['dist_comps']:.0f};"
            f"dups={s['dup_comps']:.0f};recall={r:.3f}")
    return out


def fig17_neighbor_grouping() -> Dict:
    ds = dataset()
    base = nsg_index(ds).graph      # the facade's underlying PaddedCSR
    # degree-centric regrouping with 1% top level (paper: 0.1% at 100M)
    g2, _perm = group_by_indegree(np.asarray(base.nbrs),
                                  np.asarray(base.vectors),
                                  medoid=int(base.medoid), top_fraction=0.01)
    q = jnp.asarray(ds.queries)

    # search returns REGROUPED ids; map back through the permutation
    ids_new, _, st = search_speedann_batch(g2, q, BASE)
    ids_new = np.asarray(ids_new)
    safe = np.minimum(ids_new, g2.n_nodes - 1)
    ids = np.where(ids_new < g2.n_nodes, np.asarray(_perm)[safe], -1)
    r = recall_at_k(ids, ds.gt_ids, K)
    # hit fraction estimated from frontier contents (hot vertices rank low)
    hot = np.mean(ids_new < g2.n_top)
    # access-mass estimate: expansions visit vertices ∝ in-degree, so the
    # top level's share of total in-degree approximates the fraction of
    # expansions served by the flattened (1-burst) layout
    nb = np.asarray(g2.nbrs)
    indeg = np.bincount(nb[nb < g2.n_nodes], minlength=g2.n_nodes)
    mass = indeg[:g2.n_top].sum() / max(indeg.sum(), 1)
    row("fig17_grouping", 0,
        f"recall={r:.3f};result_hit_frac≈{hot:.3f};"
        f"expansion_mass≈{mass:.3f};n_top={g2.n_top}")
    return {"recall": r, "hot": float(hot), "mass": float(mass)}


def tab02_sync_comparison() -> Dict:
    ds = dataset()
    g = nsg_index(ds)
    out = {}
    for name in ("nosync", "adaptive"):
        cfg = variant(BASE, name)
        us, r, stats = latency_at_recall("speedann", g, ds, cfg, 0.9)
        out[name] = (us, stats.get("dist_comps", 0))
        row(f"tab02_{name}", round(us, 1),
            f"comps={stats.get('dist_comps', 0):.0f};recall>=0.9")
    return out


def tab04_gpu() -> Dict:
    row("tab04_gpu", 0,
        "N/A:no GPU in container;paper compares Faiss-GPU IVFFlat — see "
        "EXPERIMENTS.md for the qualitative mapping")
    return {}


ALL = [fig05_convergence, fig06_distance_comps, fig07_width_sweep,
       fig08_staged, fig09_sync_frequency, fig12_latency_vs_baselines,
       fig13_tail_latency, fig14_walker_scaling, fig15_graph_size_scaling,
       fig16_ablation, fig17_neighbor_grouping, tab02_sync_comparison,
       tab04_gpu]
