"""Roofline benchmark: renders the §Roofline table from dryrun_results.json.

Reads the dry-run artifacts (FLOPs / bytes / collective bytes per cell) and
prints per-cell roofline terms + the dominant bottleneck + the
MODEL_FLOPS/HLO_FLOPs usefulness ratio.  Also emits the kernel-level
micro-rooflines for the two Pallas kernels (analytic, from BlockSpec tiling).
"""
from __future__ import annotations

import json
import os
from typing import Dict

from repro.launch.roofline import HBM_BW

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def render(results_path: str = RESULTS) -> Dict:
    if not os.path.exists(results_path):
        print("roofline,0,missing dryrun_results.json — run "
              "`python -m repro.launch.dryrun --all` first")
        return {}
    with open(results_path) as f:
        res = json.load(f)
    rows = {}
    for key in sorted(res):
        v = res[key]
        if v.get("status") == "skip":
            print(f"roofline_{key.replace('|', '_')},0,SKIP:"
                  f"{v['reason'].split(';')[0]}")
            continue
        if v.get("status") != "ok" or v.get("mesh") != "16x16":
            continue
        tc, tm, tx = (v["t_compute_s"], v["t_memory_s"],
                      v["t_collective_s"])
        bound = max(tc, tm, tx)
        frac = tc / bound if bound else 0.0
        ratio = v.get("useful_flops_ratio") or 0.0
        rows[key] = v
        print(f"roofline_{key.replace('|', '_')},"
              f"{bound * 1e6:.0f},"
              f"tc={tc:.3f}s;tm={tm:.3f}s;tx={tx:.3f}s;"
              f"dom={v['dominant']};roofline_frac={frac:.3f};"
              f"useful={ratio:.2f}")
    return rows


def kernel_rooflines():
    """Analytic micro-rooflines for the Pallas kernels (documented math)."""
    # l2dist (dma variant): per G=8 rows of d=128 f32: bytes = G*d*4 read +
    # G*4 write; flops = G*(3d) ≈ arithmetic intensity ~0.75 flop/byte ->
    # firmly memory-bound: the kernel's job is to keep gathers streaming.
    d, g = 128, 8
    bytes_ = g * d * 4 + g * 4
    flops = g * 3 * d
    ai = flops / bytes_
    t_mem = bytes_ / HBM_BW
    print(f"kernel_l2dist,{t_mem * 1e6:.4f},AI={ai:.2f}flop/B;memory-bound;"
          f"design=stream_rows_HBM->VMEM_overlap_reduce")
    # bitonic: n=2048 co-sort: passes = log2(n)*(log2(n)+1)/2 = 66;
    # each pass touches 3 arrays r/w in VMEM — VPU-bound, zero HBM after load
    n = 2048
    passes = 11 * 12 // 2
    vmem_bytes = passes * 3 * 2 * n * 4
    print(f"kernel_bitonic,0,passes={passes};vmem_traffic={vmem_bytes}B;"
          f"VPU-bound;HBM_traffic=one_load_one_store")


if __name__ == "__main__":
    render()
    kernel_rooflines()
