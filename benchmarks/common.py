"""Shared benchmark fixtures: datasets, indices, timing, latency-at-recall.

Benchmarks mirror the paper's methodology: methods are compared at EQUAL
RECALL by sweeping the queue capacity L (the paper's recall knob) and
reporting latency/work at the smallest L reaching each target.  Scale is
laptop-CPU (n≈8–20k, synthetic clustered vectors with exact ground truth);
the paper's 1M–1B runs map onto the dry-run/roofline path instead.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.config import SearchConfig
from repro.core import recall_at_k
from repro.data import make_vector_dataset

K = 10
_CACHE: Dict = {}


def dataset(name="sift", n=8000, q=64, dim=32, seed=0):
    key = ("ds", name, n, q, dim, seed)
    if key not in _CACHE:
        _CACHE[key] = make_vector_dataset(name, n=n, n_queries=q, k=K,
                                          dim=dim, n_clusters=64, seed=seed)
    return _CACHE[key]


def nsg_index(ds, degree=24, metric="l2", quant="none") -> AnnIndex:
    key = ("nsg", id(ds), degree, metric, str(quant))
    if key not in _CACHE:
        _CACHE[key] = AnnIndex.build(ds, IndexSpec(
            builder="nsg", metric=metric, degree=degree, knn_k=degree,
            ef_construction=2 * degree, passes=2, quant=quant))
    return _CACHE[key]


def hnsw_index(ds, degree=24, metric="l2") -> AnnIndex:
    key = ("hnsw", id(ds), degree, metric)
    if key not in _CACHE:
        _CACHE[key] = AnnIndex.build(ds, IndexSpec(
            builder="hnsw", metric=metric, degree=degree))
    return _CACHE[key]


def time_batched(fn: Callable, *args, iters=3) -> float:
    """Wall-clock microseconds per call of a jitted batched search."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


# method name -> facade algorithm ("hnsw" = bfis on an hnsw-built index,
# which routes through the greedy upper-level descent)
_METHOD_ALGO = {"bfis": "bfis", "hnsw": "bfis", "topm": "topm",
                "speedann": "speedann", "sharded": "sharded"}


def run_method(method: str, index: AnnIndex, queries, cfg):
    """Dispatch by method name through the AnnIndex facade.

    ``cfg`` may be a ``SearchParams`` or a legacy ``SearchConfig`` (lifted
    onto params; the paper-figure sweeps mutate SearchConfig knobs).
    Returns (ids, dists, stats)."""
    try:
        algo = _METHOD_ALGO[method]
    except KeyError:
        raise ValueError(method) from None
    if isinstance(cfg, SearchConfig):
        params = SearchParams.from_search_config(cfg, algorithm=algo)
    else:
        params = cfg.with_(algorithm=algo)
    return index.search(queries, params)


def latency_at_recall(
    method: str, graph_or_idx, ds, cfg: SearchConfig, target: float,
    l_sweep=(16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512),
) -> Tuple[float, float, dict]:
    """Smallest-L run reaching ``target`` recall.

    Returns (us_per_query, recall, stats_summary); (inf, best_recall, {})
    when the target is unreachable within the sweep.

    NOTE on latency semantics: this container has ONE cpu core, so the
    wall clock measures TOTAL WORK.  The paper's latency gains come from
    running walkers in parallel; ``stats['crit_rounds']`` (sequential
    expansion rounds) is the measured critical path, and
    ``modeled_parallel_us = us * crit_rounds / total_expansions``
    is the W-core latency model reported alongside (see EXPERIMENTS.md).
    """
    q = jnp.asarray(ds.queries)
    best = (float("inf"), 0.0, {})
    for L in l_sweep:
        c = cfg.with_(queue_len=L, max_steps=max(6 * L, cfg.max_steps))
        ids, _, stats = run_method(method, graph_or_idx, q, c)
        r = recall_at_k(np.asarray(ids), ds.gt_ids, K)
        if r >= target:
            us = time_batched(
                lambda qq: run_method(method, graph_or_idx, qq, c), q)
            return us / ds.queries.shape[0], r, stats.summary()
        best = (best[0], max(best[1], r), best[2])
    return best


def merge_trajectory_rows(out_path: str, new_rows: list,
                          row_key: Callable[[Dict], tuple],
                          superseded: Optional[Callable] = None) -> list:
    """Shared append semantics for the BENCH_*.json trajectory files
    (docs/benchmarks.md): existing rows + new rows, where a new row
    REPLACES any existing row with the same ``row_key``.

    ``superseded(row, new_rows) -> bool`` optionally retires additional
    legacy rows (e.g. rows written before a key field existed, which would
    otherwise double-count their machine in the trajectory forever).
    """
    existing = []
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                existing = json.load(f).get("rows", [])
        except (json.JSONDecodeError, OSError):
            existing = []
    fresh = {row_key(r) for r in new_rows}

    def drop(r):
        if row_key(r) in fresh:
            return True
        return bool(superseded and superseded(r, new_rows))

    return [r for r in existing if not drop(r)] + new_rows


def write_trajectory(out_path: str, bench: str, new_rows: list,
                     row_key: Callable[[Dict], tuple],
                     config: Optional[Dict] = None,
                     superseded: Optional[Callable] = None) -> Dict:
    """Merge ``new_rows`` into the trajectory at ``out_path`` and write the
    standard payload (bench / config / platform / jax / unix_time / rows).

    ``config`` defaults to the existing file's config block (sweeps that
    add rows without changing scale, e.g. --sweep-batch, leave the latest
    full-sweep config in place)."""
    import platform as _platform

    import jax as _jax
    if config is None:
        try:
            with open(out_path) as f:
                config = json.load(f).get("config", {})
        except (OSError, json.JSONDecodeError):
            config = {}
    all_rows = merge_trajectory_rows(out_path, new_rows, row_key,
                                     superseded=superseded)
    payload = {
        "bench": bench,
        "config": config,
        "platform": _platform.machine(),
        "jax": _jax.__version__,
        "unix_time": time.time(),
        "rows": all_rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path} ({len(new_rows)} new rows, "
          f"{len(all_rows)} total in trajectory)")
    return payload


def modeled_parallel_us(us: float, stats: dict) -> float:
    """W-core latency model: expansions are the unit of work; walkers run
    rounds in parallel, so latency ≈ wall_us × crit_rounds / expansions."""
    total = max(stats.get("local_steps", 0) + stats.get("steps", 0), 1)
    crit = stats.get("crit_rounds", 0) + stats.get("steps", 0)
    return us * min(crit / total, 1.0)
