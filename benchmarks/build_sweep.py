"""Build-throughput sweep: batched graph construction vs ``build_batch``.

``PYTHONPATH=src python -m benchmarks.run --sweep-build``

Construction routes every candidate search through the jit-compiled
batch-major engine (``search_topm_batch``), ``build_batch`` lanes per device
call.  Like serving, per-call fixed costs (dispatch, queue ops, interpret
emulation) amortize over the batch — so build wall-clock should DROP as
``build_batch`` grows while the output graph stays bit-identical.  This
sweep measures exactly that claim:

* one ``mode="serial"`` baseline row — the scalar per-point reference
  builder (``build_nsg_serial``: host prune loops, one search lane per
  device call), the seed builder's cost shape;
* one ``mode="batched"`` row per (build_batch, backend) over the SAME data
  and seed, reporting ``points_per_s`` (insertion throughput) and
  ``build_s`` (wall clock);
* ``build_s`` is **steady-state**: every configuration builds once untimed
  to compile its batch shape, and the clock runs on the second build — the
  same convention as the serving trajectories (us_per_query is steady-state
  jitted).  A cold first build would charge each batch size its one-off
  jit compile and bury the amortization signal under it;
* every batched row is checked for **bit-parity** against the
  ``build_batch=1`` graph (identical nbrs/medoid bytes) and the row records
  ``deterministic`` = a second run + a batch-order-permuted run reproduced
  the same bytes — the acceptance gate of the batched-construction change,
  recomputed at bench time on bench-scale data;
* ``recall_at_k`` of a fixed beam search over each built graph vs exact
  ground truth, so a throughput win can never silently trade recall away.

Rows append to ``BENCH_build.json`` keyed (n, batch, backend, mode, host,
interpret) — re-runs on the same host replace their own rows, other hosts'
trajectories persist (docs/benchmarks.md).

On this CPU container the Pallas backends run in interpret mode; ``ref`` is
the apples-to-apples amortization signal until a TPU session re-runs the
sweep compiled.
"""
from __future__ import annotations

import platform
import time
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from benchmarks.common import K, dataset, write_trajectory
from repro.core import build_nsg, build_nsg_serial, recall_at_k
from repro.core.bfis import search_topm_batch
from repro.core.config import SearchConfig
from repro.kernels import ops as kops

BATCHES = (1, 8, 32, 128)
BACKENDS = ("ref",)
DEGREE = 16
EF = 32
PASSES = 2
SEED = 0


def _row_key(row: Dict) -> tuple:
    return (row["n"], row["batch"], row["backend"], row["mode"],
            row["host"], row["interpret"])


def _graph_bytes(g) -> bytes:
    return (np.asarray(g.nbrs).tobytes()
            + np.asarray(g.medoid).tobytes())


def _build(data, *, batch: int, backend: str,
           batch_perm: Optional[int] = None):
    return build_nsg(data, degree=DEGREE, alpha=1.2, ef_construction=EF,
                     seed=SEED, passes=PASSES, metric="l2",
                     build_batch=batch, build_backend=backend,
                     batch_perm=batch_perm)


def _graph_recall(g, ds) -> float:
    cfg = SearchConfig(k=K, queue_len=64, m_max=4, max_steps=128)
    ids, _, _ = search_topm_batch(g, jnp.asarray(ds.queries), cfg)
    return recall_at_k(np.asarray(ids), ds.gt_ids, K)


def sweep(out_path: str = "BENCH_build.json",
          backends: Sequence[str] = BACKENDS,
          batches: Sequence[int] = BATCHES, n: int = 2000) -> Dict:
    """One serial baseline + one row per (build_batch, backend)."""
    ds = dataset(n=n, q=64)
    data = np.asarray(ds.base, np.float32)
    host = platform.node() or platform.machine()
    base = {"n": n, "host": host, "interpret": bool(kops.INTERPRET),
            "degree": DEGREE, "ef": EF, "passes": PASSES}

    def _serial():
        return build_nsg_serial(data, degree=DEGREE, alpha=1.2,
                                ef_construction=EF, seed=SEED,
                                passes=PASSES)

    rows = []
    g_serial = _serial()                     # warm-up: compiles the 1-lane shape
    t0 = time.perf_counter()
    g_serial = _serial()
    serial_s = time.perf_counter() - t0
    ref_bytes = _graph_bytes(g_serial)
    rows.append(dict(base, mode="serial", batch=1, backend="ref",
                     unix_time=time.time(), build_s=serial_s,
                     points_per_s=n / serial_s, deterministic=True,
                     parity_vs_serial=True,
                     recall_at_k=_graph_recall(g_serial, ds)))
    print(f"bench_build_serial,{serial_s:.2f}s,"
          f"{rows[-1]['points_per_s']:.0f}pts/s,"
          f"recall={rows[-1]['recall_at_k']:.3f}")

    for backend in backends:
        for batch in batches:
            g = _build(data, batch=batch, backend=backend)   # compile pass
            gb = _graph_bytes(g)
            t0 = time.perf_counter()
            g2 = _build(data, batch=batch, backend=backend)  # timed, warm
            build_s = time.perf_counter() - t0
            # two-run + permuted-chunk reproducibility, recomputed here
            # (the timed warm run doubles as the second-run witness)
            deterministic = (
                gb == _graph_bytes(g2)
                and gb == _graph_bytes(_build(data, batch=batch,
                                              backend=backend,
                                              batch_perm=7)))
            row = dict(base, mode="batched", batch=batch, backend=backend,
                       unix_time=time.time(), build_s=build_s,
                       points_per_s=n / build_s,
                       deterministic=deterministic,
                       parity_vs_serial=gb == ref_bytes,
                       recall_at_k=_graph_recall(g, ds))
            rows.append(row)
            print(f"bench_build_{backend}_bb{batch},{build_s:.2f}s,"
                  f"{row['points_per_s']:.0f}pts/s,"
                  f"parity={row['parity_vs_serial']};"
                  f"det={row['deterministic']};"
                  f"recall={row['recall_at_k']:.3f}")
            assert row["parity_vs_serial"], (
                f"build_batch={batch} diverged from the serial reference")
            assert deterministic, (
                f"build_batch={batch} is not run/permutation deterministic")

    return write_trajectory(out_path, "build", rows, _row_key,
                            config=dict(base, batches=list(batches),
                                        backends=list(backends)))


if __name__ == "__main__":
    sweep()
