"""Train a small LM end-to-end with the full substrate: data pipeline,
AdamW + cosine schedule, remat, checkpointing, fault recovery.

    PYTHONPATH=src python examples/train_tiny_lm.py [--arch llama3.2-3b]
        [--steps 100] [--full-config]

``--full-config`` uses the real architecture config (for multi-host runs;
on this CPU container stick to the default reduced config).
"""
import argparse

import numpy as np

from repro.config import TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.data.tokens import TokenStream
from repro.models import build_model
from repro.runtime import FailureInjector
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a worker failure at this step")
    args = ap.parse_args()

    cfg = (get_config if args.full_config else get_smoke_config)(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(total_steps=args.steps, warmup_steps=args.steps // 10,
                       learning_rate=3e-3, checkpoint_every=20,
                       checkpoint_dir=f"/tmp/repro_train_{args.arch}")
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8,
                         seed=0, shard=0, num_shards=1)
    trainer = Trainer(model, tcfg, stream)
    hook = (FailureInjector([args.inject_failure])
            if args.inject_failure else None)
    trainer.run(steps=args.steps, fault_hook=hook)
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"arch={cfg.name} steps={args.steps} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(mean last 10: {np.mean(losses[-10:]):.3f})")
    assert losses[-1] < losses[0], "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
