"""Quickstart: the AnnIndex lifecycle — build, save/load, search, serve.

One facade covers the whole paper stack: metric-general index construction
(l2 | ip | cosine), npz persistence, every search algorithm (BFiS, top-M,
Speed-ANN, sharded walkers), every distance-kernel backend — including the
int8/bf16 quantized ones with two-stage exact re-ranking — and batched
serving.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile
import time

import numpy as np

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.core import recall_at_k
from repro.data import make_vector_dataset


def main():
    print("== Speed-ANN quickstart (AnnIndex facade) ==")
    ds = make_vector_dataset("sift", n=5000, n_queries=32, k=10, dim=32)
    print(f"dataset: {ds.base.shape[0]} points, d={ds.base.shape[1]}")

    # -- build: the metric is an index-time property ------------------------
    t0 = time.time()
    index = AnnIndex.build(ds, IndexSpec(builder="nsg", metric="l2",
                                         degree=24))
    print(f"built {index} in {time.time() - t0:.1f}s")

    # -- save / load round-trip ---------------------------------------------
    path = index.save(os.path.join(tempfile.mkdtemp(), "sift_analog.npz"))
    index = AnnIndex.load(path)
    print(f"round-tripped through {path}")

    # -- search: per-query knobs live in SearchParams -----------------------
    gt, _ = index.exact(ds.queries, 10)      # metric-aware ground truth
    for algorithm in ("bfis", "topm", "speedann"):
        params = SearchParams(k=10, queue_len=64, m_max=8, num_walkers=8,
                              max_steps=256, local_steps=8,
                              algorithm=algorithm)
        ids, _, st = index.search(ds.queries, params)
        r = recall_at_k(np.asarray(ids), gt, 10)
        s = st.summary()
        print(f"{algorithm:9s} recall@10={r:.3f} steps={s['steps']:.1f} "
              f"comps={s['dist_comps']:.0f}")

    # -- the same search through a Pallas distance kernel -------------------
    ids, _, _ = index.search(
        ds.queries, SearchParams(k=10, queue_len=64, m_max=8, num_walkers=8,
                                 max_steps=256, local_steps=8,
                                 algorithm="speedann", backend="rowgather"))
    r = recall_at_k(np.asarray(ids), gt, 10)
    print(f"speedann (Pallas rowgather kernel, interpret) recall@10={r:.3f}")

    # -- quantized storage + two-stage search -------------------------------
    # int8 codes shrink the gather-side payload 4x; the two-stage search
    # (quantized traversal, exact f32 re-rank of the top rerank_k) recovers
    # fp32 recall.  Backend + quant are pure config — no algorithm changes.
    q8 = AnnIndex.build(ds, IndexSpec(builder="nsg", metric="l2", degree=24,
                                      quant="int8"))
    q8_path = q8.save(os.path.join(tempfile.mkdtemp(), "sift_int8.npz"))
    q8 = AnnIndex.load(q8_path)          # codes + scales round-trip
    ids, _, _ = q8.search(
        ds.queries, SearchParams(k=10, queue_len=64, m_max=8, num_walkers=8,
                                 max_steps=256, local_steps=8,
                                 algorithm="speedann", backend="ref_int8",
                                 rerank_k=30))
    r = recall_at_k(np.asarray(ids), gt, 10)
    print(f"int8 two-stage (ref_int8 + rerank_k=30) recall@10={r:.3f}  "
          f"[codes table {np.asarray(q8.graph.codes).nbytes} B vs f32 "
          f"{np.asarray(q8.graph.vectors).nbytes} B]")

    # -- metric choice: cosine retrieval over the same raw vectors ----------
    cos = AnnIndex.build(ds, IndexSpec(metric="cosine", degree=24))
    cgt, _ = cos.exact(ds.queries, 10)
    ids, _, _ = cos.search(ds.queries, SearchParams(algorithm="speedann",
                                                    m_max=8, num_walkers=8,
                                                    max_steps=256))
    r = recall_at_k(np.asarray(ids), cgt, 10)
    print(f"cosine index recall@10={r:.3f} (queries normalized inside the "
          f"facade)")

    # -- serve: bucketed batched engine over the index ----------------------
    engine = index.serve(SearchParams(k=10, m_max=8, num_walkers=8,
                                      max_steps=256),
                         bucket_sizes=(1, 4, 16, 32))
    res = engine.search(ds.queries[:5], gt_ids=gt[:5])
    print(f"served B=5 -> bucket {res.buckets} in {res.latency_ms:.1f} ms, "
          f"recall@10={engine.metrics()['recall_at_k']:.3f}")


if __name__ == "__main__":
    main()
