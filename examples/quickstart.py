"""Quickstart: build a Speed-ANN index and search it three ways.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.config import SearchConfig
from repro.core import (bfis_search_batch, build_nsg, recall_at_k,
                        search_speedann_batch)
from repro.data import make_vector_dataset


def main():
    print("== Speed-ANN quickstart ==")
    ds = make_vector_dataset("sift", n=5000, n_queries=32, k=10, dim=32)
    print(f"dataset: {ds.base.shape[0]} points, d={ds.base.shape[1]}")

    t0 = time.time()
    graph = build_nsg(ds.base, degree=24, knn_k=24, ef_construction=48)
    print(f"NSG-style index built in {time.time() - t0:.1f}s "
          f"(degree {graph.degree}, medoid {int(graph.medoid)})")

    q = jnp.asarray(ds.queries)
    cfg = SearchConfig(k=10, queue_len=64, m_max=8, num_walkers=8,
                       max_steps=256, local_steps=8, sync_ratio=0.8)

    # 1. sequential best-first search (the NSG/HNSW baseline, M=1)
    ids, _, st = bfis_search_batch(graph, q, cfg)
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    print(f"BFiS      recall@10={r:.3f} steps={st.summary()['steps']:.1f} "
          f"comps={st.summary()['dist_comps']:.0f}")

    # 2. Speed-ANN: staged parallel neighbor expansion + adaptive sync
    ids, _, st = search_speedann_batch(graph, q, cfg)
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    s = st.summary()
    print(f"Speed-ANN recall@10={r:.3f} steps={s['steps']:.1f} "
          f"comps={s['dist_comps']:.0f} syncs={s['syncs']:.1f} "
          f"dup_comps={s['dup_comps']:.0f}")

    # 3. same search through the Pallas fused gather+distance kernel
    from repro.kernels import make_dist_fn
    ids, _, _ = search_speedann_batch(graph, q, cfg,
                                      dist_fn=make_dist_fn("rowgather"))
    r = recall_at_k(np.asarray(ids), ds.gt_ids, 10)
    print(f"Speed-ANN (Pallas dist kernel, interpret) recall@10={r:.3f}")


if __name__ == "__main__":
    main()
