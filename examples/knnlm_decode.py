"""kNN-LM: Speed-ANN retrieval fused into LM decoding.

Trains a tiny LM for a few steps, builds a hidden-state datastore with a
Speed-ANN index over it, then decodes with retrieval-interpolated logits.

    PYTHONPATH=src python examples/knnlm_decode.py
"""
import jax.numpy as jnp
import numpy as np

from repro.ann import SearchParams
from repro.config import TrainConfig
from repro.configs import get_smoke_config
from repro.data.tokens import TokenStream, _batch_at
from repro.models import build_model
from repro.serve.knnlm import _final_hidden, build_datastore, knnlm_logits
from repro.train import Trainer


def main():
    print("== kNN-LM with Speed-ANN retrieval ==")
    cfg = get_smoke_config("llama3.2-3b")
    model = build_model(cfg)
    tcfg = TrainConfig(total_steps=40, warmup_steps=4, learning_rate=3e-3,
                       checkpoint_every=1000,
                       checkpoint_dir="/tmp/repro_knnlm_ckpt")
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8,
                         seed=0, shard=0, num_shards=1)
    trainer = Trainer(model, tcfg, stream)
    state = trainer.run(steps=40)
    print(f"trained tiny LM: loss {trainer.metrics_log[0]['loss']:.3f} -> "
          f"{trainer.metrics_log[-1]['loss']:.3f}")

    corpus = [jnp.asarray(_batch_at(stream, s)["tokens"])
              for s in range(6)]
    # inner-product retrieval over hidden states — the metric that matches
    # the LM head's own dot-product similarity (a one-flag choice now)
    ds = build_datastore(model, state.params, corpus, cfg.vocab_size,
                         degree=12, metric="ip")
    print(f"datastore: {ds.graph.n_nodes} (hidden, next-token) pairs "
          f"(metric={ds.index.metric})")

    # decode a prompt with and without retrieval
    prompt = jnp.asarray(_batch_at(stream, 99)["tokens"][:4, :16])
    hidden = _final_hidden(model, state.params, prompt)[:, -1]
    logits, _ = model.forward(state.params, prompt, remat=False)
    lm_last = logits[:, -1]
    sparams = SearchParams(k=8, queue_len=32, m_max=4, num_walkers=4,
                           max_steps=64, local_steps=4)
    mixed, retrieved = knnlm_logits(ds, hidden, lm_last, sparams, lam=0.3)
    lm_tok = np.asarray(jnp.argmax(lm_last, -1))
    mix_tok = np.asarray(jnp.argmax(mixed, -1))
    print(f"LM argmax tokens:      {lm_tok}")
    print(f"kNN-LM argmax tokens:  {mix_tok}")
    print(f"retrieved neighbors[0]: {np.asarray(retrieved)[0]}")
    print("OK")


if __name__ == "__main__":
    main()
