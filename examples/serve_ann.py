"""END-TO-END DRIVER: batched ANN serving (the paper's kind is search
serving, so this is the production-shaped example).

Builds an index, then serves batched query traffic through the full
Speed-ANN stack — staged parallel expansion, adaptive synchronization,
bounded per-query budgets (straggler mitigation) — and reports
recall / mean / tail latency per batch, like an online vector-search node.

    PYTHONPATH=src python examples/serve_ann.py [--batches 20] [--batch 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SearchConfig
from repro.core import build_nsg, recall_at_k, search_speedann_batch
from repro.core.build import exact_knn
from repro.data import make_vector_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--recall-target", type=float, default=0.9)
    args = ap.parse_args()

    print("== Speed-ANN serving driver ==")
    ds = make_vector_dataset("deep", n=args.n, n_queries=args.batch, k=10,
                             dim=48)
    graph = build_nsg(ds.base, degree=32, knn_k=32, ef_construction=96)
    cfg = SearchConfig(k=10, queue_len=128, m_max=8, num_walkers=8,
                       max_steps=512, local_steps=8, sync_ratio=0.8)

    search = jax.jit(
        lambda q: search_speedann_batch(graph, q, cfg))
    # warmup / compile
    jax.block_until_ready(search(jnp.asarray(ds.queries))[0])

    rng = np.random.RandomState(0)
    lat, recalls = [], []
    for i in range(args.batches):
        # fresh query traffic each batch, drawn from the corpus's own
        # generative process (cluster center + unit noise)
        c_ids = rng.randint(0, ds.centers.shape[0], size=args.batch)
        queries = (ds.centers[c_ids]
                   + rng.normal(size=(args.batch, ds.base.shape[1]))
                   .astype(np.float32))
        gt_ids, _ = exact_knn(ds.base, queries, 10)
        t0 = time.perf_counter()
        ids, dists, stats = search(jnp.asarray(queries))
        jax.block_until_ready(ids)
        ms = (time.perf_counter() - t0) * 1e3
        r = recall_at_k(np.asarray(ids), gt_ids, 10)
        lat.append(ms)
        recalls.append(r)
        print(f"batch {i:02d}: {ms:7.1f} ms ({ms / args.batch:6.2f} "
              f"ms/query) recall@10={r:.3f} "
              f"steps={stats.summary()['steps']:.1f}")

    lat = np.asarray(lat)
    print(f"\nserved {args.batches * args.batch} queries | "
          f"recall@10={np.mean(recalls):.3f} | "
          f"mean={lat.mean():.1f}ms p90={np.percentile(lat, 90):.1f}ms "
          f"p99={np.percentile(lat, 99):.1f}ms per batch of {args.batch}")
    assert np.mean(recalls) >= args.recall_target, "recall target missed"
    print("OK")


if __name__ == "__main__":
    main()
