"""END-TO-END DRIVER: batched ANN serving (the paper's kind is search
serving, so this is the production-shaped example).

Builds an index, then serves query traffic through the serving stack
(docs/serving.md).  Two client models:

* default — *variable-size batched* traffic through ``repro.serve.
  AnnEngine``: batches are quantized to a fixed bucket ladder so the jit
  cache stays bounded and warm while traffic sizes fluctuate;
* ``--async-client`` — *single-query* traffic with per-request deadlines at
  a Poisson arrival rate (``--qps``), coalesced into batches by
  ``AsyncAnnEngine`` under the max-batch / max-wait policy and dispatched
  through the same bucketed jit cache.

Underneath, the full Speed-ANN stack (staged parallel expansion, adaptive
synchronization, bounded budgets) runs with the distance backend picked by
``--dist-backend``; ``--sharded`` dispatches every bucket through the
``shard_map`` walker path (one walker per device on this host's mesh).

    PYTHONPATH=src python examples/serve_ann.py [--batches 20] \
        [--max-batch 32] [--dist-backend ref|rowgather|dma|ref_int8|...] \
        [--metric l2|ip|cosine] [--quant none|int8|bf16] [--rerank-k 30] \
        [--async-client --qps 50 --deadline-ms 200] [--sharded] \
        [--cache] [--priority-mix 0.5 --admission 4,16] [--replicas 2] \
        [--trace-out trace.json]

The serving-tier flags (all ``--async-client``): ``--cache`` puts the
quantized-code result cache in front of the queue (clients draw from a
finite query pool, so repeats replay for free); ``--priority-mix F`` sends
an F fraction of requests latency-critical and the rest throughput-class,
with ``--admission TW,CW`` shedding throughput-class first at those queue
depths; ``--replicas N`` routes dispatch over N data-parallel engine
replicas with latency-aware replica selection.

``--quant int8 --dist-backend ref_int8 --rerank-k 30`` serves the two-stage
quantized configuration: int8 traversal, exact f32 re-ranking — the engine
inherits it all from the facade, and ``engine.stats()`` shows where the
tail latency lands.
"""
import argparse
import time

import numpy as np

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.data import make_vector_dataset
from repro.kernels.registry import available_backends


def main():
    ap = argparse.ArgumentParser()
    def positive_int(v):
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("must be >= 1")
        return n

    ap.add_argument("--batches", type=positive_int, default=10)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--recall-target", type=float, default=0.9)
    ap.add_argument("--dist-backend", default="ref",
                    choices=tuple(available_backends()))
    ap.add_argument("--metric", default="l2",
                    choices=("l2", "ip", "cosine"))
    ap.add_argument("--quant", default="none",
                    choices=("none", "int8", "bf16"))
    ap.add_argument("--rerank-k", type=int, default=0,
                    help="two-stage search: exact f32 re-rank of this many "
                         "stage-1 candidates (0 disables)")
    ap.add_argument("--sharded", action="store_true",
                    help="dispatch every bucket through the shard_map "
                         "walker path (one walker per device)")
    ap.add_argument("--async-client", action="store_true",
                    help="simulate single-query clients: Poisson arrivals "
                         "with deadlines through the coalescing queue")
    ap.add_argument("--qps", type=float, default=50.0,
                    help="offered arrival rate for --async-client")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for --async-client "
                         "(default: none)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="coalescer max-wait flush for --async-client")
    ap.add_argument("--cache", action="store_true",
                    help="with --async-client: quantized-code result cache "
                         "in front of the coalescing queue")
    ap.add_argument("--priority-mix", type=float, default=1.0,
                    help="with --async-client: fraction of requests in the "
                         "critical class (rest throughput-class)")
    ap.add_argument("--admission", default=None, metavar="TW,CW",
                    help="with --async-client: admission watermarks "
                         "(throughput,critical queue depths, e.g. 4,16)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --async-client: route over N data-parallel "
                         "engine replicas")
    ap.add_argument("--trace-out", default=None,
                    help="record request-scoped spans and write "
                         "Chrome-trace/Perfetto JSON here (open in "
                         "ui.perfetto.dev); also prints the metrics "
                         "registry (docs/observability.md)")
    args = ap.parse_args()

    print("== Speed-ANN serving driver ==")
    ds = make_vector_dataset("deep", n=args.n, n_queries=args.max_batch,
                             k=10, dim=48)
    index = AnnIndex.build(ds, IndexSpec(
        builder="nsg", metric=args.metric, degree=32, ef_construction=96,
        quant=args.quant))
    params = SearchParams(k=10, queue_len=128, m_max=8, num_walkers=8,
                          max_steps=512, local_steps=8, sync_ratio=0.8,
                          backend=args.dist_backend,
                          rerank_k=args.rerank_k)
    if args.sharded:
        params = params.with_(algorithm="sharded", global_rounds=16)

    buckets = tuple(b for b in (1, 2, 4, 8, 16, 32, 64, 128)
                    if b <= args.max_batch)
    obs = None
    if args.trace_out:
        from repro.obs import Observability
        obs = Observability(tracing=True, metrics=True)
    if args.async_client:
        return serve_async_clients(index, params, buckets, args, obs)
    engine = index.serve(params, bucket_sizes=buckets, obs=obs)
    compile_s = engine.warmup(ds.base.shape[1])
    print(f"warmed {len(compile_s)} buckets "
          f"({', '.join(f'{b}:{s:.1f}s' for b, s in compile_s.items())})")

    rng = np.random.RandomState(0)
    for i in range(args.batches):
        # fresh query traffic each batch, drawn from the corpus's own
        # generative process (cluster center + unit noise) — with the
        # batch size itself fluctuating like online traffic
        bsz = int(rng.randint(1, args.max_batch + 1))
        c_ids = rng.randint(0, ds.centers.shape[0], size=bsz)
        queries = (ds.centers[c_ids]
                   + rng.normal(size=(bsz, ds.base.shape[1]))
                   .astype(np.float32))
        gt_ids, _ = index.exact(queries, 10)   # metric-aware ground truth
        res = engine.search(queries, gt_ids=gt_ids)
        print(f"batch {i:02d}: B={bsz:3d} -> bucket {res.buckets} "
              f"{res.latency_ms:7.1f} ms ({res.latency_ms / bsz:6.2f} "
              f"ms/query)")

    m = engine.stats()
    print(f"\nserved {m['queries_served']:.0f} queries in "
          f"{m['requests_served']:.0f} requests | "
          f"recall@10={m['recall_at_k']:.3f} | "
          f"mean={m['latency_mean_ms']:.1f}ms "
          f"p50={m['latency_p50_ms']:.1f}ms p95={m['latency_p95_ms']:.1f}ms "
          f"p99={m['latency_p99_ms']:.1f}ms"
          f" | jit entries={m['jit_cache_size']:.0f} "
          f"(hits={m['cache_hits']:.0f} misses={m['cache_misses']:.0f}) "
          f"padded={m['padded_queries']:.0f}")
    assert m["recall_at_k"] >= args.recall_target, "recall target missed"
    if obs is not None:
        _dump_obs(obs, args.trace_out)
    print("OK")


def _dump_obs(obs, trace_out):
    obs.write_trace(trace_out)
    print(f"wrote {trace_out} ({obs.tracer.n_events} trace events) — "
          f"open in ui.perfetto.dev")
    prom = obs.registry.to_prometheus()
    if prom.strip():
        print("-- metrics registry (Prometheus text format) --")
        print(prom, end="")


def serve_async_clients(index, params, buckets, args, obs=None):
    """Single-query clients at Poisson arrivals through the coalescer,
    optionally behind the serving tier (cache / admission / replicas)."""
    from repro.serve import (AdmissionPolicy, AsyncAnnEngine, CachePolicy,
                             CoalescePolicy, ReplicaRouter, RouterPolicy)
    cache = CachePolicy(capacity=4096) if args.cache else None
    admission = None
    if args.admission:
        tw, cw = (int(x) for x in args.admission.split(","))
        admission = AdmissionPolicy(throughput_watermark=tw,
                                    critical_watermark=cw)
    router = None
    if args.replicas > 1:
        engines = [index.serve(params, bucket_sizes=buckets, obs=obs)
                   for _ in range(args.replicas)]
        router = ReplicaRouter(engines, policy=RouterPolicy(), obs=obs)
        srv = AsyncAnnEngine(
            router,
            CoalescePolicy(max_batch=max(buckets),
                           max_wait_ms=args.max_wait_ms,
                           default_deadline_ms=args.deadline_ms),
            obs=obs, cache=cache, admission=admission)
    else:
        engines = None
        srv = index.serve_async(params, max_wait_ms=args.max_wait_ms,
                                default_deadline_ms=args.deadline_ms,
                                bucket_sizes=buckets, obs=obs,
                                cache=cache, admission=admission)
    for eng in (engines if engines is not None else [srv.engine]):
        eng.warmup()
    print(f"offering ~{args.qps:g} qps "
          f"(deadline={args.deadline_ms} ms, "
          f"max_wait={args.max_wait_ms:g} ms, cache={bool(cache)}, "
          f"admission={args.admission or 'off'}, "
          f"replicas={args.replicas}, "
          f"priority_mix={args.priority_mix:g})")

    rng = np.random.RandomState(0)
    ds_dim = index.dim
    n_requests = args.batches * args.max_batch
    # a finite query pool, so --cache has repeats to replay
    pool = rng.normal(size=(32, ds_dim)).astype(np.float32)
    futs = []
    t_next = time.perf_counter()
    for i in range(n_requests):
        t_next += rng.exponential(1.0 / args.qps)
        dt = t_next - time.perf_counter()
        if dt > 0:
            time.sleep(dt)
        prio = ("critical" if rng.random_sample() < args.priority_mix
                else "throughput")
        q = pool[i % pool.shape[0]]
        futs.append((time.perf_counter(), srv.submit(q, priority=prio)))
    lats, rejected = [], 0
    for submit_t, fut in futs:
        try:
            # done_t is stamped by the dispatcher at resolution (clocking
            # here would measure this loop, not the request)
            res = fut.result(timeout=120)
            lats.append((res.done_t - submit_t) * 1e3)
        except Exception:                # noqa: BLE001 - deadline/admission
            rejected += 1
    srv.close()
    if router is not None:
        router.close()

    st = srv.stats()
    est = (engines[0] if engines is not None else srv.engine).stats()
    if lats:
        lat = np.asarray(lats)
        print(f"client-observed: p50={np.percentile(lat, 50):.1f}ms "
              f"p95={np.percentile(lat, 95):.1f}ms "
              f"p99={np.percentile(lat, 99):.1f}ms "
              f"(n={lat.size})")
    print(f"\nsubmitted {st['submitted']:.0f} requests -> "
          f"{st['batches_dispatched']:.0f} batches "
          f"(mean size {st.get('batch_size_mean', 1):.1f}) | "
          f"served={st['served']:.0f} cache={st['served_cache']:.0f} "
          f"shed={st['rejected_admission']:.0f} rejected={rejected} | "
          f"queue wait p50={st.get('queue_wait_p50_ms', 0):.2f}ms "
          f"p99={st.get('queue_wait_p99_ms', 0):.2f}ms")
    if srv.cache is not None:
        cst = srv.cache.stats()
        print(f"cache: hit_rate={cst['hit_rate']:.2f} "
              f"(hits={cst['hits']:.0f} misses={cst['misses']:.0f} "
              f"evictions={cst['evictions']:.0f})")
    if srv.admission is not None:
        ast = srv.admission.stats()
        print(f"admission: shed critical={ast['shed_critical']:.0f} "
              f"throughput={ast['shed_throughput']:.0f}")
    if router is not None:
        rst = router.stats()
        per = " ".join(f"r{i}={rst[f'replica{i}_served']:.0f}"
                       for i in range(len(router)))
        print(f"router: {per} hedges={rst['hedges']:.0f} "
              f"discarded={rst['hedge_discarded']:.0f}")
    print(f"engine: p50={est.get('latency_p50_ms', 0):.1f}ms "
          f"p95={est.get('latency_p95_ms', 0):.1f}ms "
          f"p99={est.get('latency_p99_ms', 0):.1f}ms | "
          f"jit entries={est['jit_cache_size']:.0f} "
          f"padded={est['padded_queries']:.0f}")
    bucket_engine = engines[0] if engines is not None else srv.engine
    for b in sorted(bucket_engine.bucket_sizes):
        if f"bucket{b}_chunks" in est:
            print(f"  bucket {b:3d}: {est[f'bucket{b}_chunks']:4.0f} chunks "
                  f"p50={est[f'bucket{b}_p50_ms']:.1f}ms "
                  f"p99={est[f'bucket{b}_p99_ms']:.1f}ms")
    if obs is not None:
        _dump_obs(obs, args.trace_out)
    print("OK")


if __name__ == "__main__":
    main()
