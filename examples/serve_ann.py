"""END-TO-END DRIVER: batched ANN serving (the paper's kind is search
serving, so this is the production-shaped example).

Builds an index, then serves *variable-size* batched query traffic through
``repro.serve.AnnEngine``: batches are quantized to a fixed bucket ladder so
the jit cache stays bounded and warm while traffic sizes fluctuate, and the
full Speed-ANN stack (staged parallel expansion, adaptive synchronization,
bounded budgets) runs underneath with the distance backend picked by
``--dist-backend``.

    PYTHONPATH=src python examples/serve_ann.py [--batches 20] \
        [--max-batch 32] [--dist-backend ref|rowgather|dma|ref_int8|...] \
        [--metric l2|ip|cosine] [--quant none|int8|bf16] [--rerank-k 30]

``--quant int8 --dist-backend ref_int8 --rerank-k 30`` serves the two-stage
quantized configuration: int8 traversal, exact f32 re-ranking — the engine
inherits it all from the facade, and ``engine.stats()`` shows where the
tail latency lands.
"""
import argparse

import numpy as np

from repro.ann import AnnIndex, IndexSpec, SearchParams
from repro.data import make_vector_dataset


def main():
    ap = argparse.ArgumentParser()
    def positive_int(v):
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("must be >= 1")
        return n

    ap.add_argument("--batches", type=positive_int, default=10)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--recall-target", type=float, default=0.9)
    ap.add_argument("--dist-backend", default="ref",
                    choices=("ref", "rowgather", "dma", "ref_int8",
                             "rowgather_int8", "ref_bf16"))
    ap.add_argument("--metric", default="l2",
                    choices=("l2", "ip", "cosine"))
    ap.add_argument("--quant", default="none",
                    choices=("none", "int8", "bf16"))
    ap.add_argument("--rerank-k", type=int, default=0,
                    help="two-stage search: exact f32 re-rank of this many "
                         "stage-1 candidates (0 disables)")
    args = ap.parse_args()

    print("== Speed-ANN serving driver ==")
    ds = make_vector_dataset("deep", n=args.n, n_queries=args.max_batch,
                             k=10, dim=48)
    index = AnnIndex.build(ds, IndexSpec(
        builder="nsg", metric=args.metric, degree=32, ef_construction=96,
        quant=args.quant))
    params = SearchParams(k=10, queue_len=128, m_max=8, num_walkers=8,
                          max_steps=512, local_steps=8, sync_ratio=0.8,
                          backend=args.dist_backend,
                          rerank_k=args.rerank_k)

    buckets = tuple(b for b in (1, 2, 4, 8, 16, 32, 64, 128)
                    if b <= args.max_batch)
    engine = index.serve(params, bucket_sizes=buckets)
    compile_s = engine.warmup(ds.base.shape[1])
    print(f"warmed {len(compile_s)} buckets "
          f"({', '.join(f'{b}:{s:.1f}s' for b, s in compile_s.items())})")

    rng = np.random.RandomState(0)
    for i in range(args.batches):
        # fresh query traffic each batch, drawn from the corpus's own
        # generative process (cluster center + unit noise) — with the
        # batch size itself fluctuating like online traffic
        bsz = int(rng.randint(1, args.max_batch + 1))
        c_ids = rng.randint(0, ds.centers.shape[0], size=bsz)
        queries = (ds.centers[c_ids]
                   + rng.normal(size=(bsz, ds.base.shape[1]))
                   .astype(np.float32))
        gt_ids, _ = index.exact(queries, 10)   # metric-aware ground truth
        res = engine.search(queries, gt_ids=gt_ids)
        print(f"batch {i:02d}: B={bsz:3d} -> bucket {res.buckets} "
              f"{res.latency_ms:7.1f} ms ({res.latency_ms / bsz:6.2f} "
              f"ms/query)")

    m = engine.stats()
    print(f"\nserved {m['queries_served']:.0f} queries in "
          f"{m['requests_served']:.0f} requests | "
          f"recall@10={m['recall_at_k']:.3f} | "
          f"mean={m['latency_mean_ms']:.1f}ms "
          f"p50={m['latency_p50_ms']:.1f}ms p95={m['latency_p95_ms']:.1f}ms "
          f"p99={m['latency_p99_ms']:.1f}ms"
          f" | jit entries={m['jit_cache_size']:.0f} "
          f"(hits={m['cache_hits']:.0f} misses={m['cache_misses']:.0f}) "
          f"padded={m['padded_queries']:.0f}")
    assert m["recall_at_k"] >= args.recall_target, "recall target missed"
    print("OK")


if __name__ == "__main__":
    main()
