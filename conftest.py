"""Root pytest conftest.

Makes ``import repro`` work without an editable install — the package lives
under ``src/`` (pyproject's ``pythonpath = ["src"]`` covers pytest >= 7;
this covers direct imports from helper scripts run under pytest too).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
