"""Project model: parsed modules, symbol tables, and call resolution.

jaxlint analyzes the *project*, not single files: JL1's call-graph walk and
JL2's maker-chain resolution both cross module boundaries, so every swept
file is parsed up front into a :class:`Module` (AST + parent links + import
table + function index) and calls are resolved through a project-wide
``(module name, function name)`` index.

Resolution is deliberately name-based and conservative: a call that cannot
be resolved to a project function is simply not followed (external library,
dynamic dispatch) — jaxlint only reports what it can prove from the source.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from tools.jaxlint.config import Config

SUPPRESS_RE = re.compile(
    r"#\s*jaxlint:\s*ignore\[([A-Za-z0-9,\s]+)\]\s*(?:--\s*(.*?))?\s*$")


@dataclasses.dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]   # families ("JL1") or full ids ("JL101")
    justification: str

    def covers(self, rule: str) -> bool:
        return any(rule == r or rule.startswith(r) for r in self.rules)


@dataclasses.dataclass
class Module:
    path: Path                       # absolute
    relpath: str                     # repo-relative posix path
    modname: str                     # dotted import name, e.g. repro.core.bfis
    tree: ast.Module
    lines: List[str]
    parents: Dict[int, ast.AST] = dataclasses.field(default_factory=dict)
    # local name -> fully qualified module ("import x.y as z")
    import_aliases: Dict[str, str] = dataclasses.field(default_factory=dict)
    # local name -> (module, original name)  ("from x import f as g")
    import_names: Dict[str, Tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    functions: Dict[str, ast.FunctionDef] = dataclasses.field(
        default_factory=dict)   # top-level defs only
    suppressions: Dict[int, Suppression] = dataclasses.field(
        default_factory=dict)

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents.get(id(node))

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = self.parent(cur)
        return None


@dataclasses.dataclass
class FnRef:
    """A resolved project function: its def node plus the module it lives
    in (needed to keep walking calls from inside it)."""
    module: Module
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")


def _modname_for(path: Path, roots: Tuple[str, ...] = ("src",)) -> str:
    """Dotted module name; paths under a ``src`` root import from it."""
    parts = list(path.with_suffix("").parts)
    for root in roots:
        if root in parts:
            parts = parts[len(parts) - parts[::-1].index(root):]
            break
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    # fall back to the last two components for files outside any root
    return ".".join(parts[-4:]) if parts else path.stem


class Project:
    """All swept modules plus the cross-module lookup tables rules use."""

    def __init__(self, config: Config, root: Path):
        self.config = config
        self.root = root
        self.modules: List[Module] = []
        self._by_modname: Dict[str, Module] = {}
        # (modname, class name) -> frozen? for every @dataclass in the sweep
        self.dataclasses: Dict[Tuple[str, str], bool] = {}
        # configured static attributes plus every dataclass field declared
        # static=True in register_dataclass metadata (aux data, not leaves)
        self.static_attrs = set(config.all_static_attributes())

    # -- construction -----------------------------------------------------

    def add_paths(self, paths: Iterable[Path]) -> List[str]:
        """Collect ``*.py`` under ``paths`` minus the config excludes.
        Returns parse-error strings (syntax errors are reported, not
        fatal)."""
        errors: List[str] = []
        files: List[Path] = []
        for p in paths:
            if p.is_dir():
                files.extend(sorted(p.rglob("*.py")))
            elif p.suffix == ".py":
                files.append(p)
        for f in files:
            rel = self._rel(f)
            if any(fnmatch.fnmatch(rel, pat) for pat in self.config.exclude):
                continue
            try:
                self._add_file(f, rel)
            except SyntaxError as e:
                errors.append(f"{rel}:{e.lineno}: syntax error: {e.msg}")
        return errors

    def _rel(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root.resolve()).as_posix()
        except ValueError:
            return path.as_posix()

    def _add_file(self, path: Path, rel: str) -> None:
        text = path.read_text()
        tree = ast.parse(text, filename=rel)
        mod = Module(path=path, relpath=rel, modname=_modname_for(path),
                     tree=tree, lines=text.splitlines())
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                mod.parents[id(child)] = parent
        self._index_imports(mod)
        self._index_defs(mod)
        self._scan_suppressions(mod)
        self.modules.append(mod)
        self._by_modname[mod.modname] = mod

    def _index_imports(self, mod: Module) -> None:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.import_aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
                    if a.asname:
                        mod.import_aliases[a.asname] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    mod.import_names[a.asname or a.name] = (node.module,
                                                            a.name)

    def _index_defs(self, mod: Module) -> None:
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                mod.functions[node.name] = node
            elif isinstance(node, ast.ClassDef):
                frozen = self._dataclass_frozen(node)
                if frozen is not None:
                    self.dataclasses[(mod.modname, node.name)] = frozen
                    self.static_attrs |= self._static_fields(node)

    @staticmethod
    def _dataclass_frozen(node: ast.ClassDef) -> Optional[bool]:
        """None if not a dataclass; else whether it is frozen=True."""
        for dec in node.decorator_list:
            target, kwargs = dec, []
            if isinstance(dec, ast.Call):
                target, kwargs = dec.func, dec.keywords
            name = target.attr if isinstance(target, ast.Attribute) \
                else getattr(target, "id", "")
            if name != "dataclass":
                continue
            for kw in kwargs:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                    return bool(kw.value.value)
            return False
        return None

    @staticmethod
    def _static_fields(node: ast.ClassDef) -> set:
        """Field names carrying ``metadata=dict(static=True)`` — the
        ``jax.tree_util.register_dataclass`` convention for aux (non-leaf)
        data, which stays a concrete Python value under tracing."""
        out: set = set()
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and isinstance(stmt.value, ast.Call)):
                continue
            call = stmt.value
            fname = dotted_name(call.func)
            if fname.split(".")[-1] != "field":
                continue
            for kw in call.keywords:
                if kw.arg != "metadata":
                    continue
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.keyword) and sub.arg == "static" \
                            or (isinstance(sub, ast.Constant)
                                and sub.value == "static"):
                        out.add(stmt.target.id)
        return out

    def _scan_suppressions(self, mod: Module) -> None:
        """Inline suppressions cover their own line; a standalone comment
        suppression covers the next code line (comment continuations in
        between are skipped)."""
        for i, line in enumerate(mod.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = tuple(r.strip().upper()
                          for r in m.group(1).split(",") if r.strip())
            sup = Suppression(line=i, rules=rules,
                              justification=m.group(2) or "")
            mod.suppressions[i] = sup
            if line.lstrip().startswith("#"):
                j = i + 1
                while j <= len(mod.lines) and (
                        not mod.lines[j - 1].strip()
                        or mod.lines[j - 1].lstrip().startswith("#")):
                    j += 1
                if j <= len(mod.lines) and j not in mod.suppressions:
                    mod.suppressions[j] = sup

    # -- lookup -----------------------------------------------------------

    def module_named(self, modname: str) -> Optional[Module]:
        return self._by_modname.get(modname)

    def lookup(self, modname: str, funcname: str) -> Optional[FnRef]:
        mod = self._by_modname.get(modname)
        if mod and funcname in mod.functions:
            return FnRef(mod, mod.functions[funcname])
        return None

    def resolve_call(self, mod: Module, scope: List[ast.AST],
                     func: ast.expr) -> Optional[FnRef]:
        """Resolve a call's function expression to a project function.

        ``scope`` is the lexical chain of enclosing function defs (outermost
        first); local nested defs shadow module-level names which shadow
        imports — mirroring Python name resolution closely enough for the
        direct-call style this codebase uses.
        """
        if isinstance(func, ast.Name):
            for encl in reversed(scope):
                body = getattr(encl, "body", [])
                if not isinstance(body, list):
                    continue
                for stmt in body:
                    if isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) \
                            and stmt.name == func.id:
                        return FnRef(mod, stmt)
            if func.id in mod.functions:
                return FnRef(mod, mod.functions[func.id])
            if func.id in mod.import_names:
                target_mod, orig = mod.import_names[func.id]
                return self.lookup(target_mod, orig)
            return None
        if isinstance(func, ast.Attribute) and isinstance(func.value,
                                                          ast.Name):
            alias = func.value.id
            if alias in mod.import_aliases:
                return self.lookup(mod.import_aliases[alias], func.attr)
            if alias in mod.import_names:
                # `from repro.core import queue as fq` imports a submodule;
                # fq.insert_batch lives in repro.core.queue
                base, orig = mod.import_names[alias]
                return self.lookup(f"{base}.{orig}", func.attr)
        return None

    # -- suppression check ------------------------------------------------

    def suppression_for(self, mod: Module, line: int,
                        rule: str) -> Optional[Suppression]:
        s = mod.suppressions.get(line)
        if s and s.covers(rule):
            return s
        return None


def dotted_name(node: ast.expr) -> str:
    """'jax.lax.while_loop' for nested Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""
