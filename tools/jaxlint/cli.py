"""jaxlint command line.

    python -m tools.jaxlint src/repro [--select JL1,JL2] [--format json]

Exit status: 0 when no unsuppressed finding (or ``--exit-zero``), 1 when
unsuppressed findings remain, 2 on usage/parse errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from tools.jaxlint import __version__
from tools.jaxlint.config import load_config
from tools.jaxlint.model import (RULE_DESCRIPTIONS, Finding, all_rules,
                                 selected_rules)
from tools.jaxlint.project import Project


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m tools.jaxlint",
        description="repo-specific static analysis: tracer purity (JL1), "
                    "backend contracts (JL2), recompile hygiene (JL3), "
                    "shape conventions (JL4), observability boundary (JL5)")
    p.add_argument("paths", nargs="*", default=["src/repro"],
                   help="files/directories to sweep (default: src/repro)")
    p.add_argument("--select", default=None,
                   help="comma-separated families or rule ids "
                        "(e.g. JL1,JL402); default: all")
    p.add_argument("--ignore", default=None,
                   help="comma-separated families or rule ids to drop")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--config", default="pyproject.toml",
                   help="pyproject.toml carrying [tool.jaxlint] "
                        "(default: ./pyproject.toml)")
    p.add_argument("--no-config", action="store_true",
                   help="ignore [tool.jaxlint] (no excludes, no defaults)")
    p.add_argument("--show-suppressed", action="store_true",
                   help="also print suppressed findings (text format)")
    p.add_argument("--exit-zero", action="store_true",
                   help="always exit 0 (report-only mode)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    p.add_argument("--version", action="version",
                   version=f"jaxlint {__version__}")
    return p


def _match(finding: Finding, selectors: List[str]) -> bool:
    return any(finding.rule == s or finding.rule.startswith(s)
               for s in selectors)


def run(argv: List[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.family}  {rule.name}: {rule.doc}")
        for rid in sorted(RULE_DESCRIPTIONS):
            print(f"  {rid}  {RULE_DESCRIPTIONS[rid]}")
        return 0

    cfg = load_config(None if args.no_config else Path(args.config))
    select = [s.strip().upper() for s in args.select.split(",")] \
        if args.select else (cfg.select or None)
    ignore = [s.strip().upper() for s in args.ignore.split(",")] \
        if args.ignore else []

    project = Project(cfg, root=Path.cwd())
    errors = project.add_paths([Path(p) for p in args.paths])
    if not project.modules and not errors:
        print("jaxlint: no Python files matched", file=sys.stderr)
        return 2

    try:
        rules = selected_rules(select)
    except ValueError as e:
        print(f"jaxlint: {e}", file=sys.stderr)
        return 2

    findings: List[Finding] = []
    for rule in rules:
        findings.extend(rule.check(project))
    if select:
        findings = [f for f in findings if _match(f, select)]
    if ignore:
        findings = [f for f in findings if not _match(f, ignore)]
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]

    if args.format == "json":
        print(json.dumps({
            "version": __version__,
            "findings": [f.to_json() for f in active],
            "suppressed": [f.to_json() for f in suppressed],
            "errors": errors,
            "counts": {
                "active": len(active),
                "suppressed": len(suppressed),
                "files": len(project.modules),
            },
        }, indent=2))
    else:
        for err in errors:
            print(f"error: {err}")
        for f in active:
            print(f.render())
        if args.show_suppressed:
            for f in suppressed:
                print(f.render())
        tail = (f"{len(active)} finding(s), {len(suppressed)} suppressed, "
                f"{len(project.modules)} file(s) swept")
        print(("ok: " if not active and not errors else "") + tail)

    if errors:
        return 2
    if active and not args.exit_zero:
        return 1
    return 0


def main() -> None:  # pragma: no cover - exercised via __main__
    sys.exit(run())
