"""jaxlint — repo-specific static analysis for the Speed-ANN codebase.

Four rule families guard the invariants the dynamic test suite can't see
until runtime (see docs/static-analysis.md):

* **JL1 tracer purity** — Python control flow / concretization on values
  that are traced under ``jax.jit``, ``lax.while_loop``/``scan``/``cond``
  bodies, or ``pallas_call`` kernels (found by a call-graph walk from those
  entry points).
* **JL2 backend contract** — ``@register_backend`` factories must produce
  the batched ``DistFn(graph, ids (B,M), nbrs (B,M,R), queries (B,d))``
  signature, route sentinel id padding through ``pad_ids_to_tile``, and
  declare their quant dtype consistently with the ``_int8``/``_bf16`` name
  suffix the facade validates against.
* **JL3 recompile hygiene** — jit static arguments that are unhashable
  (dict/list/set-typed, non-frozen dataclasses) and jit wrappers created
  inside Python loops (a fresh callable per iteration defeats the trace
  cache).
* **JL4 shape convention** — batch-major functions (``*_batch`` /
  ``batch_*`` / registered backends) must document the leading-B axis, and
  ``.reshape(-1)`` full flattens inside them are flagged as batch-axis
  drops.

Run ``python -m tools.jaxlint src/repro`` from the repo root.  Findings are
suppressed per line with ``# jaxlint: ignore[RULE] -- justification``.
"""
from tools.jaxlint.model import Finding, Rule, all_rules  # noqa: F401

__version__ = "0.1.0"
