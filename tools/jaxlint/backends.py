"""Discovery of ``@register_backend`` factories and their DistFn chains.

Shared between JL2 (contract checks) and JL1 (a registered DistFn's body is
a traced root even though search code reaches it through indirection, so the
call-graph walk seeds from here too).

A factory may return its DistFn directly (a nested ``def dist_fn``), or
delegate to a maker (``return make_int8_dist_fn(metric)``) which returns the
nested def — the resolver follows that chain through project modules up to a
small depth and records every terminal function it can prove.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional

from tools.jaxlint.project import FnRef, Module, Project

_MAX_CHAIN_DEPTH = 4


@dataclasses.dataclass
class BackendReg:
    name: str                      # the registered backend name string
    module: Module
    factory: ast.FunctionDef
    line: int                      # line of the @register_backend decorator
    chain: List[FnRef]             # factory plus any makers it delegates to
    terminals: List[FnRef]         # resolvable DistFn defs/lambdas


def _register_decorator_name(dec: ast.expr) -> Optional[str]:
    """The backend name string if ``dec`` is ``register_backend("x")``."""
    if not isinstance(dec, ast.Call):
        return None
    target = dec.func
    name = target.attr if isinstance(target, ast.Attribute) \
        else getattr(target, "id", "")
    if name != "register_backend":
        return None
    if dec.args and isinstance(dec.args[0], ast.Constant) \
            and isinstance(dec.args[0].value, str):
        return dec.args[0].value
    return ""   # registered, name not statically known


def _returns(node: ast.AST) -> List[ast.Return]:
    """Return statements belonging to ``node`` itself (not nested defs)."""
    out: List[ast.Return] = []
    stack = list(getattr(node, "body", []))
    while stack:
        stmt = stack.pop()
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            out.append(stmt)
        stack.extend(ast.iter_child_nodes(stmt))
    return out


def _scope_chain(mod: Module, node: ast.AST) -> List[ast.AST]:
    chain: List[ast.AST] = [node]
    cur = mod.parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            chain.insert(0, cur)
        cur = mod.parent(cur)
    return chain


def _resolve_terminals(project: Project, fn: FnRef, chain: List[FnRef],
                       terminals: List[FnRef], depth: int) -> None:
    if depth > _MAX_CHAIN_DEPTH:
        return
    mod, node = fn.module, fn.node
    scope = _scope_chain(mod, node)
    for ret in _returns(node):
        val = ret.value
        if isinstance(val, ast.Lambda):
            terminals.append(FnRef(mod, val))
        elif isinstance(val, ast.Name):
            local = project.resolve_call(mod, scope, val)
            if local is not None:
                terminals.append(local)
        elif isinstance(val, ast.Call):
            maker = project.resolve_call(mod, scope, val.func)
            if maker is not None and all(m.node is not maker.node
                                         for m in chain):
                chain.append(maker)
                _resolve_terminals(project, maker, chain, terminals,
                                   depth + 1)


def find_registered_backends(project: Project) -> List[BackendReg]:
    regs: List[BackendReg] = []
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                name = _register_decorator_name(dec)
                if name is None:
                    continue
                factory = FnRef(mod, node)
                chain = [factory]
                terminals: List[FnRef] = []
                _resolve_terminals(project, factory, chain, terminals, 0)
                regs.append(BackendReg(
                    name=name, module=mod, factory=node,
                    line=dec.lineno, chain=chain, terminals=terminals))
    return regs
