"""Configuration loading for jaxlint (``[tool.jaxlint]`` in pyproject.toml).

Recognized keys::

    [tool.jaxlint]
    exclude = ["src/repro/models/**", ...]   # fnmatch globs, repo-relative
    select = ["JL1", "JL2"]                  # default rule selection
    static-attributes = ["n_nodes", ...]     # attrs that stay static under
                                             # jit (shape-derived properties)

The container pins Python 3.10 (no ``tomllib``) and vendoring a TOML
library is out of scope, so a minimal reader for the subset jaxlint needs
(one table of string / bool / string-list values) backs up the stdlib
parser when it is unavailable.
"""
from __future__ import annotations

import ast as _ast
import dataclasses
import re
from pathlib import Path
from typing import List, Optional

# shape-derived metadata that stays a static Python value under tracing
BUILTIN_STATIC_ATTRS = ("shape", "ndim", "dtype", "size")


@dataclasses.dataclass
class Config:
    exclude: List[str] = dataclasses.field(default_factory=list)
    select: List[str] = dataclasses.field(default_factory=list)
    static_attributes: List[str] = dataclasses.field(default_factory=list)

    def all_static_attributes(self) -> frozenset:
        return frozenset(BUILTIN_STATIC_ATTRS) | set(self.static_attributes)


def _parse_toml_table(text: str, table: str) -> dict:
    """Tiny fallback parser: the ``[table]`` section of a TOML document,
    values restricted to strings, booleans, and (possibly multi-line)
    string lists — the subset ``[tool.jaxlint]`` uses."""
    lines = text.splitlines()
    out: dict = {}
    in_table = False
    buf = ""
    key = None
    for raw in lines:
        line = raw.strip()
        if key is None:
            if line.startswith("["):
                in_table = line == f"[{table}]"
                continue
            if not in_table or not line or line.startswith("#"):
                continue
            m = re.match(r"^([A-Za-z0-9_.\-]+)\s*=\s*(.*)$", line)
            if not m:
                continue
            key, buf = m.group(1), m.group(2)
        else:
            buf += " " + line
        # a value is complete when brackets balance (or it isn't a list)
        if buf.lstrip().startswith("[") and buf.count("[") > buf.count("]"):
            continue
        out[key] = _parse_toml_value(buf.strip())
        key, buf = None, ""
    return out


def _parse_toml_value(text: str):
    text = text.split("#", 1)[0].strip() if not text.startswith(
        ("'", '"', "[")) else text
    if text in ("true", "false"):
        return text == "true"
    try:
        # TOML strings/lists-of-strings are a Python-literal subset once
        # trailing commas are tolerated (ast handles those natively)
        return _ast.literal_eval(text)
    except (ValueError, SyntaxError):
        return text


def load_config(pyproject: Optional[Path]) -> Config:
    """Read ``[tool.jaxlint]``; a missing file or section yields defaults."""
    cfg = Config()
    if pyproject is None or not pyproject.is_file():
        return cfg
    text = pyproject.read_text()
    table: dict = {}
    try:
        import tomllib  # Python >= 3.11
        table = tomllib.loads(text).get("tool", {}).get("jaxlint", {})
    except ModuleNotFoundError:
        table = _parse_toml_table(text, "tool.jaxlint")
    cfg.exclude = [str(x) for x in table.get("exclude", [])]
    cfg.select = [str(x) for x in table.get("select", [])]
    cfg.static_attributes = [
        str(x) for x in table.get("static-attributes",
                                  table.get("static_attributes", []))]
    return cfg
