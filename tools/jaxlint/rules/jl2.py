"""JL2 — distance-backend contract checks.

PR 5 fixed the ``DistFn`` contract as BATCH-MAJOR::

    dist_fn(graph, active_ids (B, M), nbr_ids (B, M, R), queries (B, d))
        -> (B, M, R) float32

Every ``@register_backend`` factory must resolve to that signature (JL202)
and take exactly the one ``cfg`` argument the registry calls it with
(JL201).  Sentinel id padding must go through the one audited helper,
``registry.pad_ids_to_tile`` (JL203) — hand-rolled ``jnp.concatenate`` +
``jnp.full(..., n_nodes)`` pads have historically disagreed about which
axis to pad and whether the sentinel is ``N`` or ``N+1``.  Quantized
backends must keep their ``_int8``/``_bf16`` name suffix consistent with
the ``require_codes(graph, dtype)`` check in their implementation (JL204):
``required_quant_dtype`` in ``repro.quant.scheme`` derives the facade-side
validation *from the name alone*, so a mismatch silently skips the
build-time quant check and surfaces as a shape error deep inside jit.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from tools.jaxlint.backends import BackendReg, find_registered_backends
from tools.jaxlint.model import Finding, register_rule
from tools.jaxlint.project import FnRef, Module, Project, dotted_name

_QUANT_SUFFIXES = ("int8", "bf16")


def _finding(project: Project, mod: Module, node: ast.AST, rule: str,
             message: str) -> Finding:
    line = getattr(node, "lineno", 1)
    col = getattr(node, "col_offset", 0)
    sup = project.suppression_for(mod, line, rule)
    return Finding(rule=rule, path=mod.relpath, line=line, col=col,
                   message=message, suppressed=sup is not None,
                   justification=sup.justification if sup else "")


def _positional_params(node: ast.AST) -> List[str]:
    a = node.args
    return [p.arg for p in getattr(a, "posonlyargs", []) + a.args]


def _check_signature(reg: BackendReg, term: FnRef) -> Optional[str]:
    """None if ``term`` matches the batched DistFn contract, else a
    human-readable description of the mismatch."""
    params = _positional_params(term.node)
    if term.node.args.vararg is not None or len(params) != 4:
        return (f"takes {len(params)} positional parameter(s) "
                f"{params}; the batched contract is exactly "
                f"(graph, ids, nbrs, queries)")
    graph, ids, nbrs, queries = params
    if graph != "graph":
        return f"first parameter is '{graph}', expected 'graph'"
    if queries != "queries":
        return f"last parameter is '{queries}', expected 'queries'"
    for p in (ids, nbrs):
        if "id" not in p and "nbr" not in p:
            return (f"parameter '{p}' does not look like a candidate-id "
                    f"axis; expected names like 'active_ids'/'nbr_ids'")
    return None


def _calls_in_chain(reg: BackendReg) -> Iterable[ast.Call]:
    seen: Set[int] = set()
    for ref in reg.chain + reg.terminals:
        if id(ref.node) in seen:
            continue
        seen.add(id(ref.node))
        for node in ast.walk(ref.node):
            if isinstance(node, ast.Call):
                yield node


def _require_codes_dtypes(reg: BackendReg) -> Set[str]:
    """Dtype strings passed to require_codes() anywhere in the chain."""
    out: Set[str] = set()
    for call in _calls_in_chain(reg):
        name = dotted_name(call.func)
        if name.split(".")[-1] != "require_codes":
            continue
        if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
            out.add(str(call.args[1].value))
    return out


def _name_suffix_dtype(name: str) -> Optional[str]:
    for d in _QUANT_SUFFIXES:
        if name.endswith("_" + d):
            return d
    return None


def _check_manual_padding(project: Project, mod: Module) -> List[Finding]:
    """jnp.concatenate / jnp.pad building a sentinel pad by hand (a
    jnp.full of an ``n_nodes``-ish sentinel) outside pad_ids_to_tile."""
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        if fname.split(".")[-1] not in ("concatenate", "pad"):
            continue
        encl = mod.enclosing_function(node)
        if getattr(encl, "name", "") == "pad_ids_to_tile":
            continue
        has_full = any(
            isinstance(c, ast.Call)
            and dotted_name(c.func).split(".")[-1] == "full"
            for c in ast.walk(node))
        mentions_sentinel = any(
            (isinstance(c, ast.Attribute) and c.attr == "n_nodes")
            or (isinstance(c, ast.Name) and c.id == "n_nodes")
            for c in ast.walk(node))
        if has_full and mentions_sentinel:
            out.append(_finding(
                project, mod, node, "JL203",
                "manual sentinel id padding (concatenate + full of "
                "n_nodes); route through registry.pad_ids_to_tile so the "
                "pad axis and sentinel value stay consistent"))
    return out


@register_rule("JL2", "backend-contract",
               "@register_backend factories: batched DistFn signature, "
               "pad_ids_to_tile routing, quant-dtype naming")
def check_jl2(project: Project):
    findings: List[Finding] = []
    regs = find_registered_backends(project)
    for reg in regs:
        deco_node = reg.factory.decorator_list[0] \
            if reg.factory.decorator_list else reg.factory
        # JL201: the registry invokes factory(cfg)
        params = _positional_params(reg.factory)
        if len(params) != 1 or reg.factory.args.vararg is not None:
            findings.append(_finding(
                project, reg.module, deco_node, "JL201",
                f"@register_backend({reg.name!r}) factory "
                f"'{reg.factory.name}' takes {len(params)} parameter(s) "
                f"{params}; the registry calls it as factory(cfg)"))
        # JL202: the resolved DistFn(s) must match the batched contract
        for term in reg.terminals:
            mismatch = _check_signature(reg, term)
            if mismatch:
                findings.append(_finding(
                    project, reg.module, deco_node, "JL202",
                    f"backend {reg.name!r}: DistFn at "
                    f"{term.module.relpath}:{term.node.lineno} {mismatch}"))
        # JL204: quant-dtype suffix <-> require_codes consistency
        suffix = _name_suffix_dtype(reg.name)
        declared = _require_codes_dtypes(reg)
        if suffix is not None and suffix not in declared:
            findings.append(_finding(
                project, reg.module, deco_node, "JL204",
                f"backend {reg.name!r} is named as a {suffix} backend but "
                f"its implementation never calls "
                f"require_codes(graph, \"{suffix}\") "
                f"(found: {sorted(declared) or 'none'})"))
        elif suffix is None and declared:
            findings.append(_finding(
                project, reg.module, deco_node, "JL204",
                f"backend {reg.name!r} requires quantized codes "
                f"{sorted(declared)} but its name carries no _int8/_bf16 "
                f"suffix — required_quant_dtype() derives the facade "
                f"validation from the name, so the build-time check is "
                f"silently skipped"))
    # JL203: manual sentinel padding anywhere in the sweep
    for mod in project.modules:
        findings.extend(_check_manual_padding(project, mod))
    return findings
