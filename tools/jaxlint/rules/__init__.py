"""Rule families. Importing a module registers its checker (see
tools.jaxlint.model.register_rule)."""
