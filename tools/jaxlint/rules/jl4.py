"""JL4 — leading-B shape convention.

Since PR 5 every engine-facing structure and function is batch-major: a
``*_batch`` / ``batch_*`` function takes and returns leaves with a leading
``(B,)`` query axis, and registered DistFns operate on ``(B, M, R)``
candidate grids.  Two drift modes this rule family catches:

* **JL401** — a batch-named function (or a registered backend's DistFn
  chain) whose docstring never states the convention.  The batch axis is
  invisible in the code (jnp broadcasting hides it until shapes collide at
  a call site three layers away), so the docstring *is* the contract.
* **JL402** — a full flatten ``.reshape(-1)`` inside a batch-named function
  in ``core/``: collapsing ``(B, ...)`` to one axis silently fuses queries
  and is the classic way per-query counters go wrong.  Legitimate
  cross-lane flattens (batch-dedup accounting) carry an explicit
  justification suppression.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from tools.jaxlint.backends import find_registered_backends
from tools.jaxlint.model import Finding, register_rule
from tools.jaxlint.project import Module, Project

_BATCH_NAME = re.compile(r"(^batch_)|(_batch$)|(_batch_)")


def _finding(project: Project, mod: Module, node: ast.AST, rule: str,
             message: str) -> Finding:
    line = getattr(node, "lineno", 1)
    sup = project.suppression_for(mod, line, rule)
    return Finding(rule=rule, path=mod.relpath, line=line,
                   col=getattr(node, "col_offset", 0), message=message,
                   suppressed=sup is not None,
                   justification=sup.justification if sup else "")


def _documents_batch_axis(doc: Optional[str]) -> bool:
    if not doc:
        return False
    return "(B" in doc or "batch" in doc.lower()


def _is_batch_named(name: str) -> bool:
    return bool(_BATCH_NAME.search(name))


def _full_flattens(node: ast.AST) -> List[ast.Call]:
    """`.reshape(-1)` calls — a single argument of constant -1 — inside
    ``node``, nested defs excluded (they get their own check)."""
    out: List[ast.Call] = []
    stack = list(node.body)
    while stack:
        cur = stack.pop()
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            continue
        if isinstance(cur, ast.Call) \
                and isinstance(cur.func, ast.Attribute) \
                and cur.func.attr == "reshape" and len(cur.args) == 1:
            a = cur.args[0]
            if isinstance(a, ast.UnaryOp) and isinstance(a.op, ast.USub) \
                    and isinstance(a.operand, ast.Constant) \
                    and a.operand.value == 1:
                out.append(cur)
        stack.extend(ast.iter_child_nodes(cur))
    return out


@register_rule("JL4", "shape-convention",
               "leading-B axis documentation on batch-named functions and "
               "registered backends; batch-axis-dropping flattens in core/")
def check_jl4(project: Project):
    findings: List[Finding] = []
    for mod in project.modules:
        in_core = "/core/" in ("/" + mod.relpath)
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not _is_batch_named(node.name):
                continue
            if not _documents_batch_axis(ast.get_docstring(node)):
                findings.append(_finding(
                    project, mod, node, "JL401",
                    f"batch-named function '{node.name}' does not document "
                    f"the leading-B axis convention (docstring should "
                    f"state the (B, ...) shapes it takes/returns)"))
            if in_core:
                for call in _full_flattens(node):
                    findings.append(_finding(
                        project, mod, call, "JL402",
                        f".reshape(-1) in batch-major '{node.name}' "
                        f"flattens the leading batch axis into the data "
                        f"axis — per-query accounting breaks silently; "
                        f"reshape to (B, -1) or justify with a "
                        f"suppression"))
    # registered DistFns: the convention may be documented anywhere in the
    # factory -> maker -> dist_fn chain (nested dist_fn defs are typically
    # undocumented; their maker's docstring is the contract statement)
    for reg in find_registered_backends(project):
        chain_docs = [ast.get_docstring(r.node) for r in reg.chain
                      if not isinstance(r.node, ast.Lambda)]
        term_docs = [ast.get_docstring(t.node) for t in reg.terminals
                     if not isinstance(t.node, ast.Lambda)]
        if not any(_documents_batch_axis(d) for d in chain_docs + term_docs):
            site = reg.factory.decorator_list[0] \
                if reg.factory.decorator_list else reg.factory
            findings.append(_finding(
                project, reg.module, site, "JL401",
                f"backend {reg.name!r}: neither the factory, its makers, "
                f"nor the DistFn documents the batch-major (B, M, R) "
                f"contract"))
    return findings
