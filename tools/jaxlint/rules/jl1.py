"""JL1 — tracer purity.

The checks themselves (JL101–JL104) live in :mod:`tools.jaxlint.traced`;
this module is the registry shim.  The engine walks the call graph from
every jit / control-flow-primitive / pallas_call / registered-backend entry
point, tracking which parameters and locals hold traced values, and flags
Python-level uses that would concretize a tracer.

Motivating bug class: ``if dists.min() < eps: ...`` inside a jitted search
step either raises ``TracerBoolConversionError`` at first trace — or, worse,
silently bakes in the branch taken during tracing when the value is a
concrete closure constant on one call path and a tracer on another.
"""
from __future__ import annotations

from tools.jaxlint.model import register_rule
from tools.jaxlint.traced import TracedAnalysis


@register_rule("JL1", "tracer-purity",
               "Python control flow / concretization on traced values "
               "reachable from jit, lax control-flow bodies, and "
               "pallas_call kernels")
def check_jl1(project):
    return TracedAnalysis(project).run()
