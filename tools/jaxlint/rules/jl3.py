"""JL3 — recompile hygiene.

``jax.jit`` keys its trace cache on the *hash* of every static argument and
on the identity of the wrapped callable.  Two repo-specific ways to lose:

* **Unhashable / mutable statics (JL301, JL302).**  A static parameter
  annotated ``dict``/``list``/``set`` raises ``TypeError: unhashable`` at
  the first call; a *non-frozen* dataclass hashes by identity, so every
  freshly constructed (but equal) config silently recompiles.  The repo's
  convention is frozen dataclasses (``SearchConfig``, ``IndexSpec``, ...)
  precisely so they are usable as cache keys — JL302 catches the drift.
* **jit-under-loop (JL303).**  ``jax.jit(f)`` (or
  ``functools.partial(jax.jit, ...)``) evaluated inside a ``for``/``while``
  body creates a fresh wrapper per iteration; each wrapper owns its own
  empty cache, so the loop retraces every pass.  Hoist the jit out of the
  loop (or cache the wrapper keyed on its statics, as
  ``AnnEngine._jit_cache`` does).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from tools.jaxlint.model import Finding, register_rule
from tools.jaxlint.project import Module, Project, dotted_name
from tools.jaxlint.traced import _jit_statics, is_jit_expr, jit_target_of

_UNHASHABLE_ANNOTATIONS = {"dict", "list", "set", "Dict", "List", "Set",
                           "MutableMapping", "defaultdict", "bytearray"}


def _finding(project: Project, mod: Module, node: ast.AST, rule: str,
             message: str) -> Finding:
    line = getattr(node, "lineno", 1)
    sup = project.suppression_for(mod, line, rule)
    return Finding(rule=rule, path=mod.relpath, line=line,
                   col=getattr(node, "col_offset", 0), message=message,
                   suppressed=sup is not None,
                   justification=sup.justification if sup else "")


def _annotation_root(ann: Optional[ast.expr]) -> str:
    """'Dict' for Dict[str, int], 'dict' for dict, '' when unannotated."""
    if ann is None:
        return ""
    if isinstance(ann, ast.Subscript):
        ann = ann.value
    name = dotted_name(ann)
    return name.split(".")[-1] if name else ""


def _resolve_dataclass(project: Project, mod: Module,
                       ann: Optional[ast.expr]) -> Optional[bool]:
    """frozen? for an annotation naming a project dataclass, else None."""
    root = _annotation_root(ann)
    if not root:
        return None
    if root in mod.import_names:
        target_mod, orig = mod.import_names[root]
        return project.dataclasses.get((target_mod, orig))
    return project.dataclasses.get((mod.modname, root))


def _check_statics(project: Project, mod: Module, target: ast.AST,
                   site: ast.AST, snames: Set[str],
                   snums: Set[int]) -> List[Finding]:
    out: List[Finding] = []
    args = target.args
    params = list(getattr(args, "posonlyargs", [])) + list(args.args) \
        + list(args.kwonlyargs)
    for i, p in enumerate(params):
        if p.arg not in snames and i not in snums:
            continue
        root = _annotation_root(p.annotation)
        if root in _UNHASHABLE_ANNOTATIONS:
            out.append(_finding(
                project, mod, site, "JL301",
                f"jit static argument '{p.arg}' of "
                f"'{getattr(target, 'name', '<lambda>')}' is annotated "
                f"'{root}' — unhashable statics raise TypeError at call "
                f"time; pass a tuple/frozen type or make it traced"))
            continue
        frozen = _resolve_dataclass(project, mod, p.annotation)
        if frozen is False:
            out.append(_finding(
                project, mod, site, "JL302",
                f"jit static argument '{p.arg}' of "
                f"'{getattr(target, 'name', '<lambda>')}' is a non-frozen "
                f"dataclass ('{_annotation_root(p.annotation)}') — it "
                f"hashes by identity, so every equal-but-new instance "
                f"recompiles; declare the dataclass frozen=True"))
    return out


def _defaults_check(project: Project, mod: Module, target: ast.AST,
                    site: ast.AST, snames: Set[str],
                    snums: Set[int]) -> List[Finding]:
    """Static params whose default is a dict/list/set literal."""
    out: List[Finding] = []
    args = target.args
    pos = list(getattr(args, "posonlyargs", [])) + list(args.args)
    defaults = list(args.defaults)
    offset = len(pos) - len(defaults)
    for j, d in enumerate(defaults):
        i = offset + j
        p = pos[i]
        if p.arg not in snames and i not in snums:
            continue
        if isinstance(d, (ast.Dict, ast.List, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
            out.append(_finding(
                project, mod, site, "JL301",
                f"jit static argument '{p.arg}' of "
                f"'{getattr(target, 'name', '<lambda>')}' defaults to an "
                f"unhashable {type(d).__name__.lower()} literal"))
    return out


@register_rule("JL3", "recompile-hygiene",
               "unhashable/mutable jit statics and jit wrappers created "
               "inside loops")
def check_jl3(project: Project):
    findings: List[Finding] = []
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            # decorated defs: @jax.jit / @functools.partial(jax.jit, ...)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    jit = is_jit_expr(dec)
                    if jit is None:
                        continue
                    snames, snums = _jit_statics(jit)
                    findings.extend(_check_statics(
                        project, mod, node, dec, snames, snums))
                    findings.extend(_defaults_check(
                        project, mod, node, dec, snames, snums))
            # call form: jax.jit(f, static_...)
            elif isinstance(node, ast.Call):
                target = jit_target_of(node)
                if target is not None and isinstance(target, ast.Name):
                    scope = _scope_of(mod, node)
                    resolved = project.resolve_call(mod, scope, target)
                    if resolved is not None:
                        snames, snums = _jit_statics(node)
                        findings.extend(_check_statics(
                            project, mod, resolved.node, node, snames,
                            snums))
                # JL303: a jit wrapper born inside a Python loop
                if is_jit_expr(node) is not None and _in_loop(mod, node):
                    findings.append(_finding(
                        project, mod, node, "JL303",
                        "jax.jit wrapper created inside a loop — each "
                        "iteration builds a fresh callable with an empty "
                        "trace cache, so the loop retraces every pass; "
                        "hoist the jit out of the loop or cache the "
                        "wrapper"))
    return findings


def _scope_of(mod: Module, node: ast.AST):
    chain = []
    cur = mod.parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            chain.insert(0, cur)
        cur = mod.parent(cur)
    return chain


def _in_loop(mod: Module, node: ast.AST) -> bool:
    """Lexically inside a for/while body, within the same function."""
    cur = mod.parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            return False
        if isinstance(cur, (ast.For, ast.While)):
            return True
        cur = mod.parent(cur)
    return False
