"""JL5 — observability boundary.

PR 8 added ``repro.obs``: request tracing, metrics, and the
``jax.profiler`` bridge.  Instrumentation belongs on the HOST side of a
dispatch (the engine/coalescer layer); smuggling it *into* traced code is
the classic way a latency fix becomes a latency regression:

* **JL501** — ``io_callback`` / ``pure_callback`` / ``jax.debug.callback``
  inside a traced (jitted) function.  A callback inserts a host round-trip
  into the compiled program: it serializes the device stream, defeats
  fusion around the call site, and (for ``io_callback``) imposes ordering
  constraints the scheduler must honor on every execution — per step, not
  per request.
* **JL502** — host wall-clock reads (``time.time`` / ``perf_counter`` /
  ``monotonic`` / ``process_time`` and friends, ``datetime.now``) inside a
  traced function.  Under jit these run ONCE, at trace time: the "timing"
  becomes a baked-in constant that measures tracing, not execution — the
  numbers look plausible and are pure fiction.  Time around the dispatch
  with ``block_until_ready`` (as the engine does), or use
  ``jax.profiler`` for on-device timelines.

The *traced set* comes from the same call-graph fixpoint as JL1 (jit
decorations/calls, lax control-flow bodies, pallas_call kernels,
vmap/pmap/shard_map/grad targets, registered backends).  Modules with an
``obs`` package component (``repro.obs.*``) are exempt — they are the
sanctioned boundary where host instrumentation lives; everything they
export to traced code (e.g. the profiler bridge) is host-side by
construction.  Use the standard suppression syntax for a deliberate
exception elsewhere.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from tools.jaxlint.model import Finding, register_rule
from tools.jaxlint.project import Module, Project, dotted_name
from tools.jaxlint.traced import TracedAnalysis

# host-callback primitives (leaf name -> the jax module family they live in)
_CALLBACK_LEAVES = {"io_callback", "pure_callback"}
# time-module functions that read a host clock
_TIME_FNS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
             "monotonic", "monotonic_ns", "process_time", "process_time_ns",
             "thread_time", "thread_time_ns"}
_DATETIME_FNS = {"now", "utcnow", "today"}


def _finding(project: Project, mod: Module, node: ast.AST, rule: str,
             message: str) -> Finding:
    line = getattr(node, "lineno", 1)
    sup = project.suppression_for(mod, line, rule)
    return Finding(rule=rule, path=mod.relpath, line=line,
                   col=getattr(node, "col_offset", 0), message=message,
                   suppressed=sup is not None,
                   justification=sup.justification if sup else "")


def _in_obs_boundary(mod: Module) -> bool:
    """True for modules inside an ``obs`` package (``repro.obs.*``) — the
    sanctioned host-instrumentation layer."""
    return "obs" in mod.modname.split(".")


def _resolves_to_module(mod: Module, root: str, target: str) -> bool:
    """Name ``root`` refers to module ``target`` here (direct import,
    aliased import, or ``from x import target``)."""
    if root == target:
        return True
    if mod.import_aliases.get(root) == target:
        return True
    entry = mod.import_names.get(root)
    return entry is not None and (entry[0] == target
                                  or f"{entry[0]}.{entry[1]}" == target)


def _callback_offense(mod: Module, call: ast.Call) -> Optional[str]:
    """The offending callable's display name if ``call`` is a host
    callback, else None."""
    fname = dotted_name(call.func)
    if not fname:
        return None
    parts = fname.split(".")
    leaf = parts[-1]
    if leaf in _CALLBACK_LEAVES:
        if len(parts) == 1:
            # bare name: honour it only when imported from a jax module
            entry = mod.import_names.get(leaf)
            if entry is not None and entry[0].split(".")[0] == "jax" \
                    and entry[1] in _CALLBACK_LEAVES:
                return f"jax {leaf}"
            return None
        root = parts[0]
        if root == "jax" or mod.import_aliases.get(root, "").startswith(
                "jax") or _resolves_to_module(mod, root, "jax.experimental"):
            return fname
        return None
    if leaf == "callback" and len(parts) >= 2 and parts[-2] == "debug":
        # jax.debug.callback / `from jax import debug; debug.callback(...)`
        root = parts[0]
        if root == "jax" or _resolves_to_module(mod, root, "jax.debug") \
                or mod.import_names.get(root) == ("jax", "debug"):
            return fname
    return None


def _timing_offense(mod: Module, call: ast.Call) -> Optional[str]:
    """The offending clock call's display name, else None."""
    fname = dotted_name(call.func)
    if not fname:
        return None
    parts = fname.split(".")
    leaf = parts[-1]
    if len(parts) == 1:
        # `from time import perf_counter` (possibly aliased)
        entry = mod.import_names.get(leaf)
        if entry is not None and entry[0] == "time" \
                and entry[1] in _TIME_FNS:
            return f"time.{entry[1]}"
        return None
    if leaf in _TIME_FNS and _resolves_to_module(mod, parts[0], "time"):
        return fname
    if leaf in _DATETIME_FNS:
        # datetime.now() / datetime.datetime.now() / dt.datetime.utcnow()
        root = parts[0]
        if root == "datetime" or mod.import_aliases.get(root) == "datetime" \
                or mod.import_names.get(root, ("", ""))[0] == "datetime":
            return fname
    return None


def _own_calls(node: ast.AST) -> List[ast.Call]:
    """Call nodes in ``node``'s own body, nested defs/lambdas excluded
    (traced nested defs are their own entries in the traced set)."""
    out: List[ast.Call] = []
    body = getattr(node, "body", [])
    stack: List[ast.AST] = list(body) if isinstance(body, list) else [body]
    while stack:
        cur = stack.pop()
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            continue
        if isinstance(cur, ast.Call):
            out.append(cur)
        stack.extend(ast.iter_child_nodes(cur))
    return out


@register_rule("JL5", "obs-boundary",
               "host callbacks and wall-clock reads inside traced code "
               "outside the repro.obs instrumentation boundary")
def check_jl5(project: Project):
    analysis = TracedAnalysis(project)
    analysis.run()
    findings: List[Finding] = []
    seen: set[Tuple] = set()
    for fn, _params, _inherited in analysis.state.values():
        mod = fn.module
        if _in_obs_boundary(mod):
            continue
        for call in _own_calls(fn.node):
            cb = _callback_offense(mod, call)
            if cb is not None:
                key = ("JL501", mod.relpath, call.lineno, call.col_offset)
                if key not in seen:
                    seen.add(key)
                    findings.append(_finding(
                        project, mod, call, "JL501",
                        f"host callback `{cb}` inside traced "
                        f"'{fn.name}' — a device-to-host round trip on "
                        f"every execution; instrument at the dispatch "
                        f"layer (repro.obs) instead"))
            tm = _timing_offense(mod, call)
            if tm is not None:
                key = ("JL502", mod.relpath, call.lineno, call.col_offset)
                if key not in seen:
                    seen.add(key)
                    findings.append(_finding(
                        project, mod, call, "JL502",
                        f"host clock `{tm}` inside traced '{fn.name}' — "
                        f"runs once at trace time and bakes in a "
                        f"constant; time around the dispatch with "
                        f"block_until_ready (see repro.obs)"))
    return findings
