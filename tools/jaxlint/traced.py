"""Traced-value analysis: the engine behind the JL1 purity rules.

The model mirrors how tracing actually works:

* **Roots.**  Parameters of jit entry points (``@jax.jit`` decorations and
  ``jax.jit(f)`` call sites, minus ``static_argnums``/``static_argnames``),
  every parameter of ``lax.while_loop``/``scan``/``cond``/``fori_loop``/
  ``switch`` bodies, ``vmap``/``pmap``/``shard_map``/``grad`` targets,
  ``pallas_call`` kernels, and registered distance backends' DistFns (the
  search engine reaches those through indirection no call graph can see, so
  the registry contract seeds them directly).
* **Taint.**  Inside a traced function, locals assigned from traced values
  become traced; shape-derived metadata (``x.shape``/``ndim``/``dtype``/
  ``size`` plus the project's static properties such as ``n_nodes``) stays
  static, exactly as under tracing.  Closure variables keep the taint they
  have in the enclosing function — a closed-over concrete array is a trace
  constant, not a tracer, so untainted closure state never raises findings.
* **Propagation.**  Calls resolved to project functions forward taint from
  argument expressions to parameters, to a fixpoint across modules.

Violations are Python-level uses that would concretize a tracer: ``if`` /
``while`` / ``assert`` on a traced value (``x is None`` checks and shape
predicates are static and exempt), ``int()``/``float()``/``bool()`` /
``.item()``/``.tolist()`` on one, and ``np.*`` calls over one.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.jaxlint.backends import find_registered_backends
from tools.jaxlint.model import Finding
from tools.jaxlint.project import FnRef, Module, Project, dotted_name

# primitives whose function-valued arguments trace with all params traced;
# value = indices of function arguments.  Bare (un-dotted) names are only
# honoured for the unambiguous ones (see _primitive_fn_args).
_CONTROL_PRIMS = {
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "scan": (0,),
    "cond": (1, 2),
    "switch": (1,),
    "map": (0,),
    "pallas_call": (0,),
    "vmap": (0,),
    "pmap": (0,),
    "shard_map": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
    "checkpoint": (0,),
    "remat": (0,),
}
_UNAMBIGUOUS_BARE = {"while_loop", "fori_loop", "pallas_call", "shard_map",
                     "vmap", "pmap"}
_JAX_TOPLEVEL = {"grad", "value_and_grad", "checkpoint", "remat"}
_TREE_MAP_SUFFIXES = ("tree.map", "tree_map", "tree_util.tree_map")


def _fn_params(node: ast.AST) -> List[str]:
    a = node.args
    names = [p.arg for p in getattr(a, "posonlyargs", []) + a.args]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    names += [p.arg for p in a.kwonlyargs]
    return names


def _jit_statics(call_or_dec: ast.AST) -> Tuple[Set[str], Set[int]]:
    """static_argnames/static_argnums of a jit call/partial expression."""
    names: Set[str] = set()
    nums: Set[int] = set()
    if not isinstance(call_or_dec, ast.Call):
        return names, nums
    for kw in call_or_dec.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.add(n.value)
    return names, nums


def jit_target_of(call: ast.Call) -> Optional[ast.expr]:
    """The wrapped-function expression if ``call`` is jax.jit(f, ...) or
    functools.partial(jax.jit, ...)(f) — None otherwise."""
    name = dotted_name(call.func)
    if name in ("jax.jit", "jit") and call.args:
        return call.args[0]
    return None


def is_jit_expr(expr: ast.expr) -> Optional[ast.AST]:
    """``expr`` is jax.jit / partial(jax.jit, ...) usable as a decorator or
    a wrapper; returns the node carrying static kwargs, else None."""
    name = dotted_name(expr)
    if name in ("jax.jit", "jit"):
        return expr
    if isinstance(expr, ast.Call):
        fname = dotted_name(expr.func)
        if fname in ("jax.jit", "jit"):
            return expr
        if fname in ("functools.partial", "partial") and expr.args \
                and dotted_name(expr.args[0]) in ("jax.jit", "jit"):
            return expr
    return None


def _primitive_fn_args(call: ast.Call) -> Iterable[ast.expr]:
    """Function-valued argument expressions of a control-flow primitive."""
    name = dotted_name(call.func)
    if not name:
        return ()
    parts = name.split(".")
    leaf = parts[-1]
    spec = _CONTROL_PRIMS.get(leaf)
    if spec is None:
        return ()
    if "." not in name and leaf not in _UNAMBIGUOUS_BARE:
        return ()   # bare `cond`/`map`/`scan`/... could be anything
    if "." in name and leaf not in _UNAMBIGUOUS_BARE \
            and "lax" not in parts[:-1] \
            and not (parts[0] == "jax" and leaf in _JAX_TOPLEVEL):
        return ()   # tree.map / itertools-style .map etc. are not lax
    out: List[ast.expr] = []
    for i in spec:
        if i < len(call.args):
            arg = call.args[i]
            # lax.switch takes a *list* of branches
            if isinstance(arg, (ast.List, ast.Tuple)):
                out.extend(arg.elts)
            else:
                out.append(arg)
    return out


class TracedAnalysis:
    """Fixpoint propagation of traced parameters plus violation checks."""

    def __init__(self, project: Project):
        self.project = project
        self.static_attrs = project.static_attrs
        # id(fn node) -> (FnRef, traced param names, inherited taint)
        self.state: Dict[int, Tuple[FnRef, Set[str], Set[str]]] = {}
        self.findings: Dict[Tuple, Finding] = {}
        self._work: List[Tuple[FnRef, Set[str], Set[str]]] = []

    # -- public entry ------------------------------------------------------

    def run(self) -> List[Finding]:
        self._seed()
        guard = 0
        while self._work and guard < 100_000:
            guard += 1
            fn, params, inherited = self._work.pop()
            self._analyze(fn, params, inherited)
        return sorted(self.findings.values(),
                      key=lambda f: (f.path, f.line, f.rule))

    # -- seeding -----------------------------------------------------------

    def _enqueue(self, fn: FnRef, params: Set[str],
                 inherited: Set[str] = frozenset()) -> None:
        key = id(fn.node)
        cur = self.state.get(key)
        if cur is not None and params <= cur[1] and inherited <= cur[2]:
            return
        merged_p = (cur[1] | params) if cur else set(params)
        merged_i = (cur[2] | inherited) if cur else set(inherited)
        self.state[key] = (fn, merged_p, merged_i)
        self._work.append((fn, merged_p, merged_i))

    def _seed(self) -> None:
        for mod in self.project.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._seed_decorated(mod, node)
                elif isinstance(node, ast.Call):
                    self._seed_call(mod, node)
        for reg in find_registered_backends(self.project):
            for term in reg.terminals:
                self._enqueue(term, set(_fn_params(term.node)))

    def _seed_decorated(self, mod: Module, node: ast.AST) -> None:
        for dec in node.decorator_list:
            jit = is_jit_expr(dec)
            if jit is None:
                continue
            snames, snums = _jit_statics(jit)
            params = _fn_params(node)
            traced = {p for i, p in enumerate(params)
                      if p not in snames and i not in snums}
            self._enqueue(FnRef(mod, node), traced)

    def _seed_call(self, mod: Module, call: ast.Call) -> None:
        scope = self._scope_chain(mod, call)
        target = jit_target_of(call)
        if target is not None:
            snames, snums = _jit_statics(call)
            self._seed_fn_expr(mod, scope, target, snames, snums)
        # partial(jax.jit, ...) produces a jit-to-be; the eventual target is
        # usually syntactically adjacent only in decorator form (handled
        # above), so bare partials are left to JL3's loop check.
        for fexpr in _primitive_fn_args(call):
            self._seed_fn_expr(mod, scope, fexpr, set(), set())

    def _seed_fn_expr(self, mod: Module, scope: List[ast.AST],
                      fexpr: ast.expr, snames: Set[str],
                      snums: Set[int]) -> None:
        if isinstance(fexpr, ast.Lambda):
            fn = FnRef(mod, fexpr)
        else:
            resolved = self.project.resolve_call(mod, scope, fexpr)
            if resolved is None:
                return
            fn = resolved
        params = _fn_params(fn.node)
        traced = {p for i, p in enumerate(params)
                  if p not in snames and i not in snums}
        self._enqueue(fn, traced)

    # -- scope helpers -----------------------------------------------------

    def _scope_chain(self, mod: Module, node: ast.AST) -> List[ast.AST]:
        chain: List[ast.AST] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                chain.insert(0, cur)
            cur = mod.parent(cur)
        return chain

    # -- taint -------------------------------------------------------------

    def _effective_refs(self, mod: Module, expr: ast.expr,
                        taint: Set[str]) -> List[ast.Name]:
        """Traced-name references in ``expr`` that are *data* uses — i.e.
        excluding shape/metadata access, `is None` tests, len/isinstance,
        and call positions."""
        refs: List[ast.Name] = []
        for n in ast.walk(expr):
            if isinstance(n, ast.Name) and n.id in taint \
                    and not self._static_context(mod, n):
                refs.append(n)
        return refs

    def _static_context(self, mod: Module, name: ast.Name) -> bool:
        # climb the attribute chain: graph.nbrs.shape[0] is static because
        # `shape` appears along it; stop at the first non-Attribute parent
        node: ast.AST = name
        parent = mod.parent(node)
        while isinstance(parent, ast.Attribute) and parent.value is node:
            if parent.attr in self.static_attrs:
                return True
            node, parent = parent, mod.parent(parent)
        if isinstance(parent, ast.Subscript) and parent.value is node:
            # x[...] reads data; but climb no further — the subscript result
            # is a data value (handled by whoever contains the Subscript)
            return False
        if isinstance(parent, ast.Call):
            if parent.func is node and node is name:
                # a bare name in callee position is a function object, not
                # data; a method call (x.sum()) on traced data is a data use
                return True
            fname = dotted_name(parent.func)
            if fname in ("len", "isinstance", "type", "getattr", "hasattr"):
                return True
        if isinstance(parent, ast.Compare):
            sides = [parent.left] + list(parent.comparators)
            if node in sides and all(isinstance(op, (ast.Is, ast.IsNot))
                                     for op in parent.ops):
                others = [s for s in sides if s is not node]
                if all(isinstance(s, ast.Constant) and s.value is None
                       for s in others):
                    return True
        return False

    def _compute_taint(self, mod: Module, node: ast.AST,
                       taint: Set[str]) -> Set[str]:
        """Forward may-taint over the function's own statements (nested
        defs excluded; two passes cover loop-carried assignments)."""
        taint = set(taint)
        stmts = self._own_statements(node)
        for _ in range(2):
            for stmt in stmts:
                if isinstance(stmt, (ast.Assign, ast.AnnAssign,
                                     ast.AugAssign)):
                    value = stmt.value
                    if value is None:
                        continue
                    if self._effective_refs(mod, value, taint):
                        targets = stmt.targets if isinstance(
                            stmt, ast.Assign) else [stmt.target]
                        for t in targets:
                            for n in ast.walk(t):
                                if isinstance(n, ast.Name):
                                    taint.add(n.id)
                elif isinstance(stmt, ast.For):
                    if self._effective_refs(mod, stmt.iter, taint):
                        for n in ast.walk(stmt.target):
                            if isinstance(n, ast.Name):
                                taint.add(n.id)
        return taint

    def _own_statements(self, node: ast.AST) -> List[ast.stmt]:
        out: List[ast.stmt] = []
        body = getattr(node, "body", [])
        if not isinstance(body, list):   # Lambda: a single expression
            return out
        stack = list(body)
        while stack:
            stmt = stack.pop()
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, ast.stmt):
                out.append(stmt)
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.stmt):
                    stack.append(child)
        return out

    # -- analysis ----------------------------------------------------------

    def _analyze(self, fn: FnRef, params: Set[str],
                 inherited: Set[str]) -> None:
        mod, node = fn.module, fn.node
        taint = self._compute_taint(mod, node, params | inherited)
        scope = self._scope_chain(mod, node)
        if not isinstance(node, ast.Lambda) and node not in scope:
            scope.append(node)

        body = node.body if isinstance(node.body, list) else [node.body]
        stack: List[ast.AST] = list(body)
        while stack:
            cur = stack.pop()
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # lexical child: traced params come from its own seeds (if
                # any); closure taint is inherited from here
                key = id(cur)
                own = self.state.get(key)
                self._enqueue(FnRef(mod, cur),
                              own[1] if own else set(), taint)
                continue
            if isinstance(cur, ast.Lambda):
                key = id(cur)
                own = self.state.get(key)
                self._enqueue(FnRef(mod, cur),
                              own[1] if own else set(), taint)
                continue
            if isinstance(cur, (ast.If, ast.While)):
                self._check_branch(fn, cur.test, taint,
                                   "while" if isinstance(cur, ast.While)
                                   else "if")
            elif isinstance(cur, ast.IfExp):
                self._check_branch(fn, cur.test, taint, "conditional")
            elif isinstance(cur, ast.Assert):
                refs = self._effective_refs(mod, cur.test, taint)
                if refs:
                    self._emit("JL102", fn, cur,
                               f"`assert` on traced value(s) "
                               f"{self._names(refs)} in '{fn.name}' — "
                               f"asserts vanish under tracing; use "
                               f"checkify or a host_callback check")
            elif isinstance(cur, ast.Call):
                self._check_call(fn, cur, taint, scope)
            stack.extend(ast.iter_child_nodes(cur))

    def _check_branch(self, fn: FnRef, test: ast.expr, taint: Set[str],
                      kind: str) -> None:
        refs = self._effective_refs(fn.module, test, taint)
        if refs:
            self._emit("JL101", fn, test,
                       f"data-dependent Python `{kind}` on traced value(s) "
                       f"{self._names(refs)} in '{fn.name}' — use "
                       f"jnp.where / lax.cond / lax.while_loop")

    def _check_call(self, fn: FnRef, call: ast.Call, taint: Set[str],
                    scope: List[ast.AST]) -> None:
        mod = fn.module
        fname = dotted_name(call.func)
        arg_exprs = list(call.args) + [kw.value for kw in call.keywords]

        # JL103: int()/float()/bool() over a traced value
        if fname in ("int", "float", "bool", "complex"):
            refs: List[ast.Name] = []
            for a in arg_exprs:
                refs.extend(self._effective_refs(mod, a, taint))
            if refs:
                self._emit("JL103", fn, call,
                           f"`{fname}()` concretizes traced value(s) "
                           f"{self._names(refs)} in '{fn.name}' — this "
                           f"raises TracerError under jit")
        # JL103: .item() / .tolist() on a traced value
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in ("item", "tolist"):
            refs = self._effective_refs(mod, call.func.value, taint)
            if refs:
                self._emit("JL103", fn, call,
                           f"`.{call.func.attr}()` concretizes traced "
                           f"value(s) {self._names(refs)} in '{fn.name}'")
        # JL104: numpy call over a traced value
        root = fname.split(".")[0] if fname else ""
        is_np = root in ("np", "numpy") or \
            mod.import_aliases.get(root, "") == "numpy"
        if is_np and "." in fname:
            refs = []
            for a in arg_exprs:
                refs.extend(self._effective_refs(mod, a, taint))
            if refs:
                self._emit("JL104", fn, call,
                           f"`{fname}` on traced value(s) "
                           f"{self._names(refs)} in '{fn.name}' — numpy "
                           f"forces a host transfer/concretization; use "
                           f"jnp")

        # seeds that only become visible inside traced code (local lambdas
        # passed to primitives are already caught by the global scan, but
        # closure taint must flow in, so re-seed here with current taint)
        for fexpr in _primitive_fn_args(call):
            if isinstance(fexpr, ast.Lambda):
                self._enqueue(FnRef(mod, fexpr),
                              set(_fn_params(fexpr)), taint)
            else:
                resolved = self.project.resolve_call(mod, scope, fexpr)
                if resolved is not None:
                    self._enqueue(resolved, set(_fn_params(resolved.node)),
                                  taint if resolved.module is mod else set())

        # jax.tree.map(f, *trees): f traces over leaves of tainted trees
        if fname and fname.endswith(_TREE_MAP_SUFFIXES) and call.args:
            tainted_tree = any(self._effective_refs(mod, a, taint)
                               for a in call.args[1:])
            if tainted_tree:
                self._seed_fn_expr(mod, scope, call.args[0], set(), set())

        # propagate taint through calls to project functions
        resolved = self.project.resolve_call(mod, scope, call.func)
        if resolved is None:
            return
        callee_params = _fn_params(resolved.node)
        tainted_params: Set[str] = set()
        for i, a in enumerate(call.args):
            if isinstance(a, ast.Starred):
                continue
            if i < len(callee_params) \
                    and self._effective_refs(mod, a, taint):
                tainted_params.add(callee_params[i])
        for kw in call.keywords:
            if kw.arg and kw.arg in callee_params \
                    and self._effective_refs(mod, kw.value, taint):
                tainted_params.add(kw.arg)
        if tainted_params:
            self._enqueue(resolved, tainted_params)

    # -- emission ----------------------------------------------------------

    @staticmethod
    def _names(refs: List[ast.Name]) -> str:
        return ", ".join(sorted({f"'{r.id}'" for r in refs}))

    def _emit(self, rule: str, fn: FnRef, node: ast.AST,
              message: str) -> None:
        mod = fn.module
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        sup = self.project.suppression_for(mod, line, rule)
        f = Finding(rule=rule, path=mod.relpath, line=line, col=col,
                    message=message, suppressed=sup is not None,
                    justification=sup.justification if sup else "")
        self.findings.setdefault((rule, mod.relpath, line, col, message), f)
