"""Finding/rule data model and the pluggable rule registry.

A *rule* is a callable ``check(project) -> Iterable[Finding]`` registered
under a family id (``JL1`` .. ``JL5``).  The CLI selects families (or full
rule ids) with ``--select`` and renders the findings; per-line
``# jaxlint: ignore[...]`` comments mark findings as suppressed (they are
still reported with ``--show-suppressed`` but never fail the run).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List

# rule id -> one-line description, kept in sync with docs/static-analysis.md
RULE_DESCRIPTIONS: Dict[str, str] = {
    "JL101": "data-dependent Python `if`/`while` on a traced value",
    "JL102": "`assert` on a traced value",
    "JL103": "concretization of a traced value (int/float/bool/.item/.tolist)",
    "JL104": "numpy call on a traced value (forces host transfer)",
    "JL201": "@register_backend factory must take exactly one argument",
    "JL202": "registered DistFn breaks the batched (graph, ids, nbrs, "
             "queries) contract",
    "JL203": "manual sentinel id padding; route through pad_ids_to_tile",
    "JL204": "backend name suffix / require_codes quant dtype mismatch",
    "JL301": "jit static argument is dict/list/set-typed (unhashable)",
    "JL302": "jit static argument is a non-frozen dataclass",
    "JL303": "jax.jit created inside a loop (retraces every iteration)",
    "JL401": "batch-major function missing leading-B axis documentation",
    "JL402": "full flatten (.reshape(-1)) inside a batch-major core function",
    "JL501": "host callback (io_callback/pure_callback/debug.callback) "
             "inside traced code outside the repro.obs boundary",
    "JL502": "host wall-clock read (time.*/datetime.now) inside traced code "
             "outside the repro.obs boundary",
}

FAMILIES = ("JL1", "JL2", "JL3", "JL4", "JL5")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic, pinned to a source location."""
    rule: str            # full id, e.g. "JL101"
    path: str            # repo-relative posix path
    line: int            # 1-based
    col: int             # 0-based, ast convention
    message: str
    suppressed: bool = False
    justification: str = ""  # text after `--` in the suppression comment

    @property
    def family(self) -> str:
        return self.rule[:3]

    def to_json(self) -> dict:
        d = {
            "rule": self.rule,
            "family": self.family,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "suppressed": self.suppressed,
        }
        if self.suppressed:
            d["justification"] = self.justification
        return d

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} {self.message}{tag}")


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered rule family checker."""
    family: str
    name: str
    check: Callable  # check(project) -> Iterable[Finding]
    doc: str


_RULES: Dict[str, Rule] = {}


def register_rule(family: str, name: str, doc: str = ""):
    """Decorator: register ``check(project)`` under a family id.

    New rule families plug in here — see docs/static-analysis.md ("adding a
    new rule")."""
    def deco(fn):
        _RULES[family] = Rule(family=family, name=name, check=fn,
                              doc=doc or (fn.__doc__ or "").strip())
        return fn
    return deco


def all_rules() -> List[Rule]:
    # import for the registration side effect; rule modules register on load
    from tools.jaxlint.rules import jl1, jl2, jl3, jl4, jl5  # noqa: F401
    return [_RULES[f] for f in sorted(_RULES)]


def selected_rules(select: Iterable[str] | None) -> List[Rule]:
    """``--select`` values (families like JL1 or full ids like JL402) ->
    the rule-family checkers to run.  Full ids select their family; the CLI
    filters findings back down to the requested ids afterwards."""
    rules = all_rules()
    if not select:
        return rules
    fams = {s[:3] for s in select}
    unknown = fams - {r.family for r in rules}
    if unknown:
        raise ValueError(
            f"unknown rule selector(s) {sorted(unknown)}; "
            f"families: {[r.family for r in rules]}")
    return [r for r in rules if r.family in fams]
