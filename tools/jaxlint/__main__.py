from tools.jaxlint.cli import main

main()
